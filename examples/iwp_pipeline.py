"""Ice Wedge Polygons use case (paper §III-B): tiling + inference.

Each very-high-resolution "satellite image" is processed in two stages with
different resource shapes — exactly the paper's heterogeneous pattern:
  tiling    — CPU-slot Python function: split into 360x360 tiles;
  inference — SPMD function on a device sub-mesh: a small conv net scores
              every tile (the paper's GPU stage), tiles sharded over the
              task's private mesh.

Many images flow through concurrently; per-image dataflow edges are futures.

    PYTHONPATH=src python examples/iwp_pipeline.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import (DataFlowKernel, PilotDescription, RPEXExecutor,
                        python_app, spmd_app)
from repro.compat import shard_map

TILE = 90          # reduced 360 -> 90 for the CPU container
TILES_PER_IMG = 8


@python_app
def load_and_tile(image_id):
    """Stage 1 (CPU): load the scene and cut it into tiles."""
    rng = np.random.default_rng(image_id)
    scene = rng.standard_normal((TILE * 2, TILE * 4)).astype("float32")
    tiles = (scene.reshape(2, TILE, 4, TILE).transpose(0, 2, 1, 3)
             .reshape(TILES_PER_IMG, TILE, TILE))
    return {"image_id": image_id, "tiles": tiles}


@spmd_app(slots=4, mesh=(4, 1), jit=False)
def infer(mesh, payload):
    """Stage 2 (accelerator sub-mesh): score tiles, sharded over 'data'."""
    tiles = jnp.asarray(payload["tiles"])          # (8, T, T)
    kernel = jnp.ones((5, 5)) / 25.0

    def per_shard(t):                              # t: (2, T, T) local tiles
        sm = jax.vmap(lambda im: jax.scipy.signal.convolve2d(
            im, kernel, mode="same"))(t)
        score = jax.nn.sigmoid(sm.mean(axis=(1, 2)))
        return score

    f = shard_map(per_shard, mesh=mesh,
                      in_specs=P("data"), out_specs=P("data"))
    return {"image_id": payload["image_id"],
            "scores": np.asarray(f(tiles))}


@python_app
def collect(results):
    found = {r["image_id"]: float(np.max(r["scores"])) for r in results}
    return found


def main(n_images=12):
    rpex = RPEXExecutor(PilotDescription(n_slots=8))
    t0 = time.time()
    with DataFlowKernel(executors={"rpex": rpex}):
        per_image = [infer(load_and_tile(i)) for i in range(n_images)]
        summary = collect(per_image).result()
    rpex.shutdown()
    print(f"[iwp] {n_images} images in {time.time()-t0:.1f}s; "
          f"max polygon scores: "
          f"{ {k: round(v, 3) for k, v in list(summary.items())[:4]} } ...")
    assert len(summary) == n_images
    return summary


if __name__ == "__main__":
    main()
