"""End-to-end driver example: pre-train a ~smolLM-family model for a few
hundred steps through the workflow runtime (checkpointed, restartable).

    PYTHONPATH=src python examples/train_smollm.py            # ~100M-ish
    PYTHONPATH=src python examples/train_smollm.py --tiny     # CI-sized

The full run uses a width-reduced SmolLM (not the 360M flagship — this
container is a single CPU) trained on the deterministic synthetic corpus;
loss must drop monotonically-ish over the run.
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()
    if args.tiny:
        steps = args.steps or 40
        argv = ["--arch", "smollm-360m", "--reduced", "--steps", str(steps),
                "--segment", "10", "--batch", "8", "--seq", "128",
                "--ckpt-dir", "/tmp/repro_smollm_tiny"]
    else:
        steps = args.steps or 200
        argv = ["--arch", "smollm-360m", "--reduced", "--steps", str(steps),
                "--segment", "20", "--batch", "16", "--seq", "256",
                "--ckpt-dir", "/tmp/repro_smollm"]
    losses = train_main(argv)
    assert losses[-1] < losses[0], "loss did not improve"
    print(f"[example] trained {steps} steps: "
          f"{losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
