"""Colmena use case (paper §III-A): ML-steered ensemble simulations.

A *Thinker* maintains a surrogate model of an unknown objective and decides
which simulation to run next; a *Task Server* (the DFK + RPEX) dispatches
heterogeneous tasks: 1-slot pre/post-processing Python functions and
multi-slot SPMD "simulations".  The steering loop is genuinely sequential-
in-information but pipelined: K simulations are kept in flight, and results
steer subsequent submissions — Colmena's architecture on this runtime.

    PYTHONPATH=src python examples/colmena_ensemble.py
"""
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import (DataFlowKernel, PilotDescription, RPEXExecutor,
                        python_app, spmd_app)
from repro.compat import shard_map

TRUE_OPT = 1.7


@python_app
def pre_process(x):
    """Prepare a simulation input deck (1 CPU slot)."""
    return {"x": float(x), "deck": [float(x) ** i for i in range(4)]}


@spmd_app(slots=2, jit=False)
def simulate(mesh, deck):
    """The 'MPI simulation': distributed evaluation of an expensive
    objective at deck['x'] (noisy double-well)."""
    x = deck["x"]
    grid = jnp.linspace(x - 0.1, x + 0.1, 4096)
    f = shard_map(
        lambda g: jax.lax.pmean(jnp.mean(-(g - TRUE_OPT) ** 2
                                         - 0.05 * jnp.sin(3 * g) ** 2),
                                "data"),
        mesh=mesh, in_specs=P("data"), out_specs=P())
    val = f(grid)
    return {"x": x, "y": float(val)}


@python_app
def post_process(result, history):
    """Collect the result into the Thinker's history (1 CPU slot)."""
    return history + [(result["x"], result["y"])]


class Thinker:
    """Tiny Bayesian-flavored steering: sample-around-best with decay."""

    def __init__(self, seed=0):
        self.rng = np.random.default_rng(seed)
        self.best = (0.0, -math.inf)
        self.t = 0

    def suggest(self):
        self.t += 1
        sigma = max(0.05, 2.0 / self.t)
        return float(self.best[0] + self.rng.normal(0, sigma))

    def observe(self, history):
        for x, y in history:
            if y > self.best[1]:
                self.best = (x, y)


def main(iterations=24, in_flight=4):
    rpex = RPEXExecutor(PilotDescription(n_slots=8))
    thinker = Thinker()
    t0 = time.time()
    with DataFlowKernel(executors={"rpex": rpex}):
        live = []
        submitted = 0
        history = []
        while submitted < iterations or live:
            while submitted < iterations and len(live) < in_flight:
                x = thinker.suggest()
                fut = post_process(simulate(pre_process(x)), history)
                live.append(fut)
                submitted += 1
            fut = live.pop(0)
            history = fut.result()
            thinker.observe(history[-1:])
    rpex.shutdown()
    print(f"[colmena] {iterations} sims in {time.time()-t0:.1f}s; "
          f"best x={thinker.best[0]:.3f} (true {TRUE_OPT}) "
          f"y={thinker.best[1]:.4f}")
    assert abs(thinker.best[0] - TRUE_OPT) < 0.8
    return thinker.best


if __name__ == "__main__":
    main()
