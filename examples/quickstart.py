"""Quickstart: heterogeneous workflow on a pilot in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a pilot over the visible devices, defines three app kinds (Python,
SPMD-with-collectives, bash), wires them into a dataflow graph through
futures, and runs them under the RPEX executor — the paper's full stack
(DFK -> Task Translator -> Pilot/Agent -> SPMD function executor).
"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import (DataFlowKernel, PilotDescription, RPEXExecutor,
                        bash_app, python_app, spmd_app)
from repro.compat import shard_map


@python_app
def make_params(scale):
    return {"scale": scale}


@spmd_app(slots=4, mesh=(4, 1), jit=False)
def parallel_norm(mesh, params, n):
    """An 'MPI function': collective sum over the task's private sub-mesh."""
    x = jnp.arange(float(n)) * params["scale"]
    return shard_map(lambda a: jax.lax.psum(jnp.sum(a * a), "data"),
                         mesh=mesh, in_specs=P("data"), out_specs=P())(x)


@python_app
def report(sq_norm):
    return f"||x||^2 = {float(sq_norm):.1f}"


@bash_app
def archive(msg):
    return f"echo archived: {msg}"


def main():
    rpex = RPEXExecutor(PilotDescription(n_slots=8))
    with DataFlowKernel(executors={"rpex": rpex}):
        params = make_params(2.0)          # python task
        norm = parallel_norm(params, 16)   # SPMD task, depends on params
        msg = report(norm)                 # python task, depends on norm
        arch = archive(msg)                # bash task, depends on msg
        print(msg.result())
        print(arch.result().strip())
    rpex.shutdown()
    print("executor stats:", dict(rpex.pilot.executor.stats))


if __name__ == "__main__":
    main()
