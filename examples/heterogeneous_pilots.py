"""Heterogeneous tasks on heterogeneous resources — the paper's central
claim, end to end.

One RPEXExecutor owns two pilots with distinct descriptions: a "cpu" pilot
that accepts pure-Python pre/post-processing tasks and a "device" pilot
that accepts SPMD tasks.  The translator stamps every task's resource
kind; the TaskManager late-binds each task to the least-loaded compatible
pilot.  The workflow below is the Colmena shape: per item a Python
pre-process, an SPMD simulation on a device sub-mesh, and a Python
collector, with dataflow dependencies between them.

Run: PYTHONPATH=src python examples/heterogeneous_pilots.py
"""
import jax.numpy as jnp

from repro.core import (DataFlowKernel, PilotDescription, RPEXExecutor,
                        python_app, spmd_app)


@python_app
def pre(i):
    return {"sim_id": i, "scale": 1.0 + 0.1 * i}


@spmd_app(slots=2, jit=False)
def simulate(mesh, spec):
    x = jnp.ones((64, 64)) * spec["scale"]
    y = jnp.tanh(x @ x.T / 64.0)
    return {"sim_id": spec["sim_id"], "energy": float(y.sum())}


@python_app
def collect(results):
    return sorted((r["sim_id"], round(r["energy"], 3)) for r in results)


def main():
    rpex = RPEXExecutor([
        PilotDescription(n_slots=4, kinds=("python", "bash"), name="cpu"),
        PilotDescription(n_slots=8, kinds=("spmd",), name="device"),
    ])
    with DataFlowKernel(executors={"rpex": rpex}):
        sims = [simulate(pre(i)) for i in range(6)]
        table = collect(sims).result()

    print("collected:", table)
    for uid, t in rpex.tmgr.tasks.items():
        print(f"  {uid:<16} kind={t.kind:<7} res_kind={t.res_kind:<7} "
              f"-> {t.pilot_uid}")
    print("per-pilot utilization:", rpex.utilization())
    rpex.shutdown()


if __name__ == "__main__":
    main()
