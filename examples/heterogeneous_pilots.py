"""Heterogeneous tasks on heterogeneous resources — the paper's central
claim, end to end.

One RPEXExecutor owns two pilots with distinct descriptions: a "cpu" pilot
that accepts pure-Python pre/post-processing tasks and a "device" pilot
that accepts SPMD tasks.  The translator stamps every task's resource
kind; the TaskManager late-binds each task to a compatible pilot chosen
by the executor's placement policy — here LocalityAware, so whenever
several compatible pilots could take a task (e.g. the elastic cpu pilots
of part 2), the one already holding its input data wins
(docs/placement.md).  The workflow below is the Colmena shape: per item a
Python pre-process, an SPMD simulation on a device sub-mesh, and a Python
collector, with dataflow dependencies between them.

Part 2 demos elasticity: the same executor given PoolScaler *templates*
spawns an extra pilot when a burst of pre-processing tasks backs up the
queue (PILOT_START) — the placement policy picks the template whose kinds
match the starving queue (here the python backlog spawns the cpu
template, never the device one) — steals the backlog onto it (STOLEN),
and drains + retires it once the burst passes (PILOT_RETIRE) — watch the
event stream printed at the end.

Run: PYTHONPATH=src python examples/heterogeneous_pilots.py
"""
import time

import jax.numpy as jnp

from repro.core import (DataFlowKernel, PilotDescription, RPEXExecutor,
                        ScalerConfig, python_app, spmd_app)


@python_app
def pre(i):
    return {"sim_id": i, "scale": 1.0 + 0.1 * i}


@spmd_app(slots=2, jit=False)
def simulate(mesh, spec):
    x = jnp.ones((64, 64)) * spec["scale"]
    y = jnp.tanh(x @ x.T / 64.0)
    return {"sim_id": spec["sim_id"], "energy": float(y.sum())}


@python_app
def collect(results):
    return sorted((r["sim_id"], round(r["energy"], 3)) for r in results)


@python_app
def crunch(i):
    time.sleep(0.1)        # a burst of these overloads the cpu pilot
    return i


def main():
    rpex = RPEXExecutor(
        [
            PilotDescription(n_slots=4, kinds=("python", "bash"),
                             name="cpu"),
            PilotDescription(n_slots=8, kinds=("spmd",), name="device"),
        ],
        # consumers follow the pilots that hold their input data
        placement="locality",
        # elastic: spawn up to 2 extra pilots when queue wait builds,
        # retire them after ~0.5s idle (knobs: docs/elasticity.md); with
        # several templates the placement policy spawns the one whose
        # kinds cover the starving queue (docs/placement.md)
        scaler=ScalerConfig(
            templates=[
                PilotDescription(n_slots=4, kinds=("python", "bash"),
                                 name="elastic-cpu"),
                PilotDescription(n_slots=8, kinds=("spmd",),
                                 name="elastic-dev"),
            ],
            min_pilots=2, max_pilots=4,
            scale_up_wait_s=0.15, scale_down_idle_s=0.5,
            spawn_cooldown_s=0.3),
    )
    with DataFlowKernel(executors={"rpex": rpex}):
        sims = [simulate(pre(i)) for i in range(6)]
        table = collect(sims).result()
        print("collected:", table)
        for uid, t in rpex.tmgr.tasks.items():
            print(f"  {uid:<16} kind={t.kind:<7} res_kind={t.res_kind:<7} "
                  f"-> {t.pilot_uid}")

        # part 2: a burst that outgrows the cpu pilot -> autoscale cycle
        burst = [crunch(i) for i in range(24)]
        assert sorted(f.result() for f in burst) == list(range(24))

        # wait for the idle retire *inside* the context: exiting it shuts
        # the executor (and the scaler) down
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            if any(e["event"] == "PILOT_RETIRE"
                   for e in rpex.pool.events()):
                break
            time.sleep(0.05)

    print("per-pilot utilization:", rpex.utilization())
    print("scaler decisions:")
    for d in rpex.scaler.decisions:
        print("  ", d)
    print("elastic cycle events:")
    for e in rpex.pool.events():
        if e["event"] in ("PILOT_START", "STOLEN", "PILOT_RETIRE"):
            print(f"  {e['event']:<12} {e.get('uid', '')} "
                  f"pilot={e.get('pilot', e.get('dst', ''))}")
    print("rp overhead from event stream: "
          f"{rpex.rp_overhead() * 1000:.1f} ms")
    rpex.shutdown()


if __name__ == "__main__":
    main()
