#!/usr/bin/env bash
# Tier-1 verification: the whole test suite must collect and pass.
# Usage: scripts/ci.sh [extra pytest args...]
#   CI_COVERAGE=1  — run under `coverage run --source=src/repro`
#   CI_BENCH=1     — append the throughput benchmark smoke
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

if [[ "${CI_COVERAGE:-0}" == "1" ]]; then
    coverage run --source=src/repro -m pytest -q "$@"
else
    python -m pytest -q "$@"
fi

# runtime micro-benchmark smoke (fast settings; the full run is
# `python benchmarks/exp3_throughput.py`)
if [[ "${CI_BENCH:-0}" == "1" ]]; then
    python benchmarks/exp3_throughput.py --tasks 200 --stream-tasks 50
fi
