#!/usr/bin/env bash
# Tier-1 verification: the whole test suite must collect and pass.
# Usage: scripts/ci.sh [extra pytest args...]
#   CI_COVERAGE=1  — run under `coverage run --source=src/repro`
#   CI_BENCH=1     — append the throughput benchmark smoke
#   CI_ANALYSIS=1  — run the concurrency analyzer gate first
#   CI_ANALYSIS_ONLY=1 — with CI_ANALYSIS=1, stop after the gate
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# concurrency static analysis (lock discipline, event protocol, state
# machine) gated against the committed baseline in src/repro/analysis/
if [[ "${CI_ANALYSIS:-0}" == "1" ]]; then
    python -m repro.analysis
    if [[ "${CI_ANALYSIS_ONLY:-0}" == "1" ]]; then
        exit 0
    fi
fi

if [[ "${CI_COVERAGE:-0}" == "1" ]]; then
    coverage run --source=src/repro -m pytest -q "$@"
else
    python -m pytest -q "$@"
fi

# runtime micro-benchmark smoke (fast settings; the full runs are
# `python benchmarks/exp3_throughput.py` / `exp5_statepath.py` /
# `exp6_locality.py` / `exp7_preempt.py` / `exp8_procpool.py` /
# `exp9_costmodel.py` / `exp10_resilience.py` / `exp11_dataplane.py`)
if [[ "${CI_BENCH:-0}" == "1" ]]; then
    python benchmarks/exp3_throughput.py --tasks 200 --stream-tasks 50
    python benchmarks/exp5_statepath.py --tasks 500 --records 5000 \
        --lookups 500 --producers 128 --repeats 2
    python benchmarks/exp6_locality.py --chains 4 --depth 4 --repeats 1
    python benchmarks/exp7_preempt.py --repeats 1 --long-steps 8 --shorts 4
    # proc-vs-inproc gate self-skips below 2 visible cores (exp8 prints
    # the reason and still emits BENCH_procpool.json)
    python benchmarks/exp8_procpool.py --noop-tasks 200 --burn-tasks 24 \
        --repeats 2 --min-proc-speedup 1.3
    python benchmarks/exp9_costmodel.py --repeats 1 --probes 4 \
        --min-makespan-ratio 1.3
    python benchmarks/exp10_resilience.py --tasks 60 --ckpt-steps 8 \
        --repeats 1 --max-degradation-ratio 5
    python benchmarks/exp11_dataplane.py --payload-mb 2 --edges 6 \
        --repeats 1 --require-placement
fi
