"""Runtime lock-order watchdog (RPX007-RPX009) — opt-in instrumentation.

``install()`` replaces ``threading.Lock/RLock/Condition`` with factories
that wrap locks *allocated from repro source files* (everything else —
stdlib internals, futures, third-party — gets the real thing, so the
interpreter's own locking is never perturbed).  Each wrapped lock is
identified by its allocation site (``module.py:lineno``), so every
instance allocated by the same constructor line is one node in the order
graph — exactly the granularity the static analyzer reasons at.

While installed, the watchdog records per-thread acquisition stacks:

  * every acquisition made while other instrumented locks are held adds
    ordered edges (held → new) to a global order graph;
  * hold times are tracked per site (``Condition.wait`` windows are
    excluded — the lock is genuinely released while waiting);
  * ``TaskRecord.transition`` is validated against the declared
    STATE_MACHINE (violations recorded, reported as RPX007).

``check()`` turns the recorded graph into findings: a cycle is RPX008
(two threads really interleaved those locks in opposite orders during
the run — a latent deadlock the static pass may not see across object
boundaries), and a hold beyond the wall-time ceiling is RPX009.

Activation:  set ``REPRO_LOCK_WATCHDOG=1`` before importing
``repro.core`` (the package installs the watchdog on import); set
``REPRO_LOCK_WATCHDOG_OUT=path.json`` to write the order-graph report at
interpreter exit — the CI chaos soak uses this to emit
``BENCH_lockorder.json``.  The tier-1 conftest adds a session check that
fails the suite on any watchdog finding.
"""
from __future__ import annotations

import atexit
import json
import os
import sys
import threading
import time
from typing import Dict, List, Optional, Tuple

from . import Finding

_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock
_REAL_CONDITION = threading.Condition

DEFAULT_HOLD_CEILING_S = 2.0


class LockWatchdog:
    """Global acquisition recorder shared by every instrumented lock."""

    def __init__(self):
        self._mu = _REAL_LOCK()                   # guards the maps below
        self._tls = threading.local()             # per-thread held stack
        self.edges: Dict[Tuple[str, str], int] = {}
        self.max_hold: Dict[str, float] = {}
        self.acquisitions: Dict[str, int] = {}
        self.threads: set = set()
        self.transition_violations: List[dict] = []

    # ------------------------ per-thread held stack --------------------- #
    def _stack(self) -> List[List]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def on_acquire(self, site: str):
        st = self._stack()
        t = time.monotonic()
        new_edges = []
        for held_site, _, depth in st:
            if held_site != site:
                new_edges.append((held_site, site))
        for entry in st:
            if entry[0] == site:                  # RLock re-entry
                entry[2] += 1
                return
        st.append([site, t, 1])
        with self._mu:
            self.threads.add(threading.get_ident())
            self.acquisitions[site] = self.acquisitions.get(site, 0) + 1
            for e in new_edges:
                self.edges[e] = self.edges.get(e, 0) + 1

    def on_release(self, site: str):
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i][0] == site:
                st[i][2] -= 1
                if st[i][2] == 0:
                    held_s = time.monotonic() - st[i][1]
                    del st[i]
                    with self._mu:
                        if held_s > self.max_hold.get(site, 0.0):
                            self.max_hold[site] = held_s
                return

    # Condition.wait: the underlying lock is released for the duration —
    # close the hold window on entry, open a fresh one on wakeup
    def on_wait_release(self, site: str):
        self.on_release(site)

    def on_wait_reacquire(self, site: str):
        self.on_acquire(site)

    def on_transition(self, frm: str, to: str, uid: str):
        with self._mu:
            if len(self.transition_violations) < 200:
                self.transition_violations.append(
                    {"uid": uid, "from": frm, "to": to})

    # ------------------------------ reporting --------------------------- #
    def _cycles(self) -> List[List[str]]:
        adj: Dict[str, set] = {}
        for (a, b) in self.edges:
            adj.setdefault(a, set()).add(b)
            adj.setdefault(b, set())
        index: Dict[str, int] = {}
        low: Dict[str, int] = {}
        on_stack: set = set()
        stack: List[str] = []
        out: List[List[str]] = []
        counter = [0]
        for root in sorted(adj):
            if root in index:
                continue
            work = [(root, iter(sorted(adj[root])))]
            index[root] = low[root] = counter[0]
            counter[0] += 1
            stack.append(root)
            on_stack.add(root)
            while work:
                node, it = work[-1]
                advanced = False
                for w in it:
                    if w not in index:
                        index[w] = low[w] = counter[0]
                        counter[0] += 1
                        stack.append(w)
                        on_stack.add(w)
                        work.append((w, iter(sorted(adj[w]))))
                        advanced = True
                        break
                    elif w in on_stack:
                        low[node] = min(low[node], index[w])
                if advanced:
                    continue
                work.pop()
                if work:
                    low[work[-1][0]] = min(low[work[-1][0]], low[node])
                if low[node] == index[node]:
                    scc = []
                    while True:
                        w = stack.pop()
                        on_stack.discard(w)
                        scc.append(w)
                        if w == node:
                            break
                    if len(scc) > 1:
                        out.append(sorted(scc))
        return out

    def snapshot(self) -> dict:
        with self._mu:
            edges = sorted(self.edges.items())
            max_hold = dict(self.max_hold)
            acq = dict(self.acquisitions)
            nthreads = len(self.threads)
            violations = list(self.transition_violations)
        return {
            "locks": len({s for e, _ in edges for s in e} | set(max_hold)),
            "edge_count": len(edges),
            "edges": [{"src": a, "dst": b, "count": n}
                      for (a, b), n in edges],
            "cycles": self._cycles(),
            "max_hold_ms": {s: round(v * 1e3, 3)
                            for s, v in sorted(max_hold.items())},
            "max_hold_ms_overall": round(
                max(max_hold.values(), default=0.0) * 1e3, 3),
            "acquisitions": acq,
            "threads": nthreads,
            "transition_violations": violations,
        }

    def check(self, hold_ceiling_s: float = DEFAULT_HOLD_CEILING_S,
              ) -> List[Finding]:
        return check_snapshot(self.snapshot(), hold_ceiling_s)

    def write_report(self, path: str):
        snap = self.snapshot()
        with open(path, "w") as fh:
            json.dump(snap, fh, indent=2)
        return snap


def check_snapshot(snap: dict,
                   hold_ceiling_s: float = DEFAULT_HOLD_CEILING_S,
                   ) -> List[Finding]:
    """Findings from a watchdog snapshot (live or a saved JSON report)."""
    findings: List[Finding] = []
    for cyc in snap.get("cycles", ()):
        findings.append(Finding(
            "RPX008", "<runtime>", 0,
            f"runtime lock-order cycle observed between "
            f"{{{', '.join(cyc)}}} — two threads acquired these locks in "
            f"conflicting orders",
            f"RPX008:{'->'.join(cyc)}"))
    for site, ms in sorted(snap.get("max_hold_ms", {}).items()):
        if ms > hold_ceiling_s * 1e3:
            findings.append(Finding(
                "RPX009", site, 0,
                f"lock allocated at {site} was held for {ms:.0f}ms "
                f"(> {hold_ceiling_s * 1e3:.0f}ms ceiling)",
                f"RPX009:{site}"))
    for v in snap.get("transition_violations", ())[:20]:
        findings.append(Finding(
            "RPX007", "<runtime>", 0,
            f"task {v['uid']} transitioned {v['from']} -> {v['to']}, "
            f"an edge STATE_MACHINE does not declare",
            f"RPX007:runtime:{v['from']}->{v['to']}"))
    return findings


# ----------------------------- lock wrappers ---------------------------- #

class _WrappedLock:
    """Instrumented Lock/RLock: records acquire/release on the global
    watchdog, proxies everything else to the real primitive."""

    def __init__(self, real, site: str, wd: LockWatchdog):
        self._real = real
        self._site = site
        self._wd = wd

    def acquire(self, blocking: bool = True, timeout: float = -1):
        ok = self._real.acquire(blocking, timeout)
        if ok:
            self._wd.on_acquire(self._site)
        return ok

    def release(self):
        self._wd.on_release(self._site)
        self._real.release()

    def locked(self):
        return self._real.locked()

    def _is_owned(self):                          # Condition compatibility
        f = getattr(self._real, "_is_owned", None)
        if f is not None:
            return f()
        if self._real.acquire(False):
            self._real.release()
            return False
        return True

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def __repr__(self):
        return f"<watchdog {self._real!r} @{self._site}>"


class _WrappedCondition:
    """Instrumented Condition: acquire/release tracked like a lock;
    ``wait``/``wait_for`` close the hold window while parked (the lock
    really is released) and reopen it on wakeup."""

    def __init__(self, real, site: str, wd: LockWatchdog):
        self._real = real
        self._site = site
        self._wd = wd

    def acquire(self, *a, **kw):
        ok = self._real.acquire(*a, **kw)
        if ok:
            self._wd.on_acquire(self._site)
        return ok

    def release(self):
        self._wd.on_release(self._site)
        self._real.release()

    def wait(self, timeout: Optional[float] = None):
        self._wd.on_wait_release(self._site)
        try:
            return self._real.wait(timeout)
        finally:
            self._wd.on_wait_reacquire(self._site)

    def wait_for(self, predicate, timeout: Optional[float] = None):
        self._wd.on_wait_release(self._site)
        try:
            return self._real.wait_for(predicate, timeout)
        finally:
            self._wd.on_wait_reacquire(self._site)

    def notify(self, n: int = 1):
        self._real.notify(n)

    def notify_all(self):
        self._real.notify_all()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def __repr__(self):
        return f"<watchdog {self._real!r} @{self._site}>"


# ------------------------------ installation ---------------------------- #

_installed: Optional[LockWatchdog] = None


def _alloc_site(depth: int = 2) -> Tuple[str, bool]:
    """(site id, instrument?) from the allocating frame.  Only locks whose
    *direct* allocator is a repro source file are wrapped: stdlib helpers
    that build locks internally (``threading.Event``, ``queue.Queue``,
    ``concurrent.futures``) must get real primitives — their fork/reset
    paths call ``__init__`` on them in ways a proxy cannot honor."""
    f = sys._getframe(depth)
    fn = f.f_code.co_filename
    norm = fn.replace(os.sep, "/")
    if "/repro/" in norm and "/repro/analysis/" not in norm:
        sub = norm.rsplit("/repro/", 1)[-1]
        return f"{sub}:{f.f_lineno}", True
    return f"{os.path.basename(fn)}:{f.f_lineno}", False


def install(watchdog: Optional[LockWatchdog] = None) -> LockWatchdog:
    """Patch ``threading.Lock/RLock/Condition`` with instrumenting
    factories.  Idempotent; returns the active watchdog."""
    global _installed
    if _installed is not None:
        return _installed
    wd = watchdog or LockWatchdog()

    def make_lock():
        site, instr = _alloc_site()
        real = _REAL_LOCK()
        return _WrappedLock(real, site, wd) if instr else real

    def make_rlock():
        site, instr = _alloc_site()
        real = _REAL_RLOCK()
        return _WrappedLock(real, site, wd) if instr else real

    def make_condition(lock=None):
        site, instr = _alloc_site()
        inner = lock
        if isinstance(inner, (_WrappedLock,)):
            # the Condition tracks through its own wrapper; hand the
            # real primitive to the real Condition underneath
            inner = inner._real
        real = _REAL_CONDITION(inner) if inner is not None \
            else _REAL_CONDITION()
        return _WrappedCondition(real, site, wd) if instr else real

    threading.Lock = make_lock
    threading.RLock = make_rlock
    threading.Condition = make_condition
    _installed = wd

    # task-lifecycle validation rides the same opt-in switch
    try:
        from repro.core import futures as _futures
        machine = {k.value: {t.value for t in v}
                   for k, v in getattr(_futures, "STATE_MACHINE",
                                       {}).items()}

        def _validate(frm, to, uid):
            if machine and to not in machine.get(frm, ()):
                wd.on_transition(frm, to, uid)
        _futures._validate_transition = _validate
    except Exception:                             # pragma: no cover
        pass
    return wd


def uninstall():
    """Restore the real primitives (the validation hook included).
    Already-created wrapped locks keep working — their real lock is
    inside — so this is safe mid-run."""
    global _installed
    threading.Lock = _REAL_LOCK
    threading.RLock = _REAL_RLOCK
    threading.Condition = _REAL_CONDITION
    try:
        from repro.core import futures as _futures
        _futures._validate_transition = None
    except Exception:                             # pragma: no cover
        pass
    _installed = None


def active() -> Optional[LockWatchdog]:
    return _installed


def maybe_install_from_env() -> Optional[LockWatchdog]:
    """Called by ``repro.core`` on import: install when
    ``REPRO_LOCK_WATCHDOG`` is set; arrange the exit report when
    ``REPRO_LOCK_WATCHDOG_OUT`` names a file."""
    if not os.environ.get("REPRO_LOCK_WATCHDOG"):
        return None
    wd = install()
    out = os.environ.get("REPRO_LOCK_WATCHDOG_OUT")
    if out:
        atexit.register(lambda: wd.write_report(out))
    return wd
