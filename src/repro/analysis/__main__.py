"""``python -m repro.analysis`` — run the static passes, gate on the
committed baseline.

Default scope: the lock-discipline pass over ``src/repro/core``; the
event-protocol and state-machine passes over core + benchmarks + tests
(emitters and consumers both live there).  Exit status is the number of
non-baselined findings capped at 1 — CI fails on any.

    python -m repro.analysis                      # gate against baseline
    python -m repro.analysis --no-baseline        # raw findings
    python -m repro.analysis --list-keys          # keys for baselining
    python -m repro.analysis --graph              # dump the lock graph
    python -m repro.analysis --json out.json      # machine-readable dump
    python -m repro.analysis --check-watchdog-report BENCH_lockorder.json

The last form validates a watchdog JSON report (written by a run with
``REPRO_LOCK_WATCHDOG=1`` + ``REPRO_LOCK_WATCHDOG_OUT=...``): non-zero
on a runtime lock-order cycle, a hold-ceiling breach, or a state-machine
violation.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List

from . import Finding, apply_baseline, load_baseline
from .events import analyze_events, analyze_state_machine
from .locks import analyze_lock_discipline
from .watchdog import DEFAULT_HOLD_CEILING_S, check_snapshot

_HERE = Path(__file__).resolve()
REPO_ROOT = _HERE.parents[3]
DEFAULT_BASELINE = _HERE.parent / "baseline.txt"


def _read_sources(root: Path, rel_dirs) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for rel in rel_dirs:
        d = root / rel
        if not d.is_dir():
            continue
        for p in sorted(d.glob("*.py")):
            out[str(p.relative_to(root))] = p.read_text()
    return out


def run_static(root: Path):
    """(findings, lock graph) for the repo at ``root``."""
    lock_sources = _read_sources(root, ["src/repro/core"])
    event_sources = _read_sources(
        root, ["src/repro/core", "benchmarks", "tests"])
    findings: List[Finding] = []
    lk, graph = analyze_lock_discipline(lock_sources)
    findings.extend(lk)
    findings.extend(analyze_events(event_sources))
    findings.extend(analyze_state_machine(event_sources))
    return findings, graph


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--root", default=str(REPO_ROOT),
                    help="repo root (default: autodetected)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, baseline ignored")
    ap.add_argument("--list-keys", action="store_true",
                    help="print stable finding keys (baseline format)")
    ap.add_argument("--graph", action="store_true",
                    help="dump the static lock acquisition graph")
    ap.add_argument("--json", metavar="PATH",
                    help="write findings + graph as JSON")
    ap.add_argument("--check-watchdog-report", metavar="PATH",
                    help="validate a runtime watchdog JSON report instead "
                         "of running the static passes")
    ap.add_argument("--hold-ceiling", type=float,
                    default=DEFAULT_HOLD_CEILING_S,
                    help="watchdog held-lock wall-time ceiling, seconds")
    args = ap.parse_args(argv)

    if args.check_watchdog_report:
        snap = json.loads(Path(args.check_watchdog_report).read_text())
        findings = check_snapshot(snap, args.hold_ceiling)
        print(f"watchdog report: {snap.get('locks', 0)} locks, "
              f"{snap.get('edge_count', 0)} order edges, "
              f"{len(snap.get('cycles', []))} cycles, "
              f"max hold {snap.get('max_hold_ms_overall', 0.0):.0f}ms, "
              f"{len(snap.get('transition_violations', []))} "
              f"state-machine violations")
        for f in findings:
            print(f.render())
        print(f"{len(findings)} watchdog finding(s)")
        return 1 if findings else 0

    root = Path(args.root)
    findings, graph = run_static(root)

    if args.graph:
        print("# static lock acquisition graph")
        for lid, info in sorted(graph.locks.items()):
            print(f"  lock {info.display:40s} {info.kind:10s} "
                  f"({lid[0]}:{info.line})")
        for src, dst in sorted(graph.edge_pairs()):
            def disp(l):
                i = graph.locks.get(l)
                return i.display if i else f"{l[0]}.{l[1]}"
            print(f"  edge {disp(src)} -> {disp(dst)}")

    baseline = {} if args.no_baseline else load_baseline(Path(args.baseline))
    new, suppressed, stale = apply_baseline(findings, baseline)

    if args.list_keys:
        for f in sorted(findings, key=lambda f: f.key):
            print(f.key)
        return 0

    if args.json:
        Path(args.json).write_text(json.dumps({
            "findings": [vars(f) for f in findings],
            "new": [vars(f) for f in new],
            "suppressed": suppressed,
            "stale_baseline": stale,
            "graph": {
                "locks": [{"owner": k[0], "attr": k[1],
                           "kind": v.kind, "display": v.display}
                          for k, v in sorted(graph.locks.items())],
                "edges": [{"src": list(s), "dst": list(d)}
                          for s, d in sorted(graph.edge_pairs())],
            }}, indent=2))

    for f in sorted(new, key=lambda f: (f.code, f.path, f.line)):
        print(f.render())
    if stale:
        print(f"# stale baseline entries (fix landed? remove them): "
              f"{', '.join(sorted(stale))}", file=sys.stderr)
    print(f"{len(findings)} finding(s): {len(new)} new, "
          f"{len(suppressed)} baselined, {len(stale)} stale baseline "
          f"entr{'y' if len(stale) == 1 else 'ies'}")
    return 1 if new else 0


if __name__ == "__main__":
    raise SystemExit(main())
