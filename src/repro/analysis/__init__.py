"""repro.analysis — self-hosted concurrency correctness tooling.

The runtime's correctness rests on hand-maintained discipline: a dozen-plus
lock/condition-variable sites across the core, and a journaled event
vocabulary that replay, compaction, and listener dispatch must agree on.
This package machine-checks those invariants:

  locks.py     AST lock-discipline analyzer — lock inventory, inter-lock
               acquisition graph (nested ``with``/``acquire`` scopes plus
               cross-method edges through self-calls), cycle detection,
               blocking calls under a lock, ``Condition.wait`` outside a
               predicate loop.
  events.py    Event-protocol checker — emitted vs consumed vs declared
               (the ``EVENTS`` registry in store.py) journal event names,
               plus the declared task-lifecycle state machine checked
               against every ``transition(TaskState.X)`` site.
  watchdog.py  Runtime lock-order watchdog — opt-in instrumented-lock mode
               (``REPRO_LOCK_WATCHDOG=1``) that records per-thread
               acquisition sequences, merges them into an order graph, and
               fails on a cycle or a held-lock wall-time ceiling.

Rule codes are stable (docs/analysis.md has the catalog):

  RPX001  static lock-order cycle / self-deadlock on a non-reentrant lock
  RPX002  blocking call while holding a lock
  RPX003  Condition.wait() not wrapped in a predicate (while) loop
  RPX004  event emitted but never consumed by replay/compaction/listeners
  RPX005  event consumed but never emitted
  RPX006  event name not declared in the EVENTS registry
  RPX007  task-state transition outside the declared state machine
  RPX008  runtime lock-order cycle (watchdog)
  RPX009  held-lock wall time exceeded the ceiling (watchdog)

``python -m repro.analysis`` runs the static passes over the runtime's own
source; ``baseline.txt`` (committed) lists the intentional exceptions, one
justified key per line.  CI fails on any non-baselined finding.
"""
from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class Finding:
    """One analyzer finding.

    ``key`` is the stable baseline identity: rule code plus the semantic
    site (module/qualname/lock or event name) — never a line number, so a
    committed baseline survives unrelated edits."""
    code: str
    path: str
    line: int
    message: str
    key: str

    def render(self) -> str:
        return f"{self.code} {self.path}:{self.line}: {self.message}"


def load_baseline(path: Path) -> Dict[str, str]:
    """Parse the committed baseline: ``<finding key>  # justification``
    per line; blank lines and full-line comments ignored.  Every entry
    must carry a justification — an unexplained suppression is itself an
    error (reported by the caller via ``validate``)."""
    entries: Dict[str, str] = {}
    if not path.exists():
        return entries
    for raw in path.read_text().splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        key, _, why = line.partition("#")
        entries[key.strip()] = why.strip()
    return entries


def apply_baseline(findings: List[Finding], baseline: Dict[str, str],
                   ) -> Tuple[List[Finding], List[str], List[str]]:
    """Split findings into (new, suppressed-keys, stale-baseline-keys).

    Stale entries (baselined keys no finding matches any more) are
    surfaced so the baseline shrinks as fixes land instead of rotting."""
    keys = {f.key for f in findings}
    new = [f for f in findings if f.key not in baseline]
    suppressed = [k for k in baseline if k in keys]
    stale = [k for k in baseline if k not in keys]
    return new, suppressed, stale


__all__ = ["Finding", "load_baseline", "apply_baseline"]
