"""Event-protocol + task-lifecycle checker (RPX004-RPX007).

The journal's event vocabulary has three parties that must agree:

  * emitters   — ``record_event("NAME", ...)`` call sites and the literal
                 ``{"event": "NAME", ...}`` records the store itself
                 writes (STATE, the _SNAPSHOT compaction header);
  * consumers  — ``_replay``, ``_maybe_compact``, checkpoint replay,
                 listener dispatch, benchmarks and tests that filter
                 ``e["event"] == "NAME"``;
  * the registry — ``EVENTS`` in store.py, the single declared source of
                 truth.

Rules:

RPX004  emitted but never consumed anywhere in the scanned scope —
        either dead telemetry or a consumer someone forgot to write
        (forensic-only events are baselined with a justification).
RPX005  consumed but never emitted — a typo'd or stale filter that can
        never match (this is the "replay silently dropped the stream"
        class of bug).
RPX006  an event name used (either side) that the EVENTS registry does
        not declare.
RPX007  a ``transition(TaskState.X)`` site targets a state the declared
        STATE_MACHINE (futures.py) has no inbound edge for, or the
        machine itself drifts from the TaskState enum.

Consumption detection is dataflow-lite: direct comparisons against
``<expr>["event"]`` / ``<expr>.get("event")`` or variables assigned from
them count as *strict* consumption (drives RPX005/RPX006); registry
names inside containers compared against event-set variables (the
``{"A", "B"} <= kinds`` test idiom) count as *loose* consumption
(suppresses RPX004 only).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from . import Finding


@dataclass
class EventUsage:
    registry: Dict[str, str] = field(default_factory=dict)   # attr -> value
    emitted: Dict[str, List[Tuple[str, int]]] = field(default_factory=dict)
    consumed: Dict[str, List[Tuple[str, int]]] = field(default_factory=dict)
    consumed_loose: Set[str] = field(default_factory=set)

    def note(self, table: Dict[str, List[Tuple[str, int]]],
             name: str, path: str, line: int):
        table.setdefault(name, []).append((path, line))


def _const_str(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _events_attr(node: ast.expr, registry: Dict[str, str]) -> Optional[str]:
    """Resolve ``EVENTS.X`` to its registered string value."""
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "EVENTS":
        return registry.get(node.attr, f"<EVENTS.{node.attr}?>")
    return None


def _name_of(node: ast.expr, registry: Dict[str, str]) -> Optional[str]:
    return _const_str(node) if _const_str(node) is not None \
        else _events_attr(node, registry)


def _container_names(node: ast.expr,
                     registry: Dict[str, str]) -> List[str]:
    out = []
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for el in node.elts:
            n = _name_of(el, registry)
            if n is not None:
                out.append(n)
    return out


def _is_event_expr(node: ast.expr) -> bool:
    """``<expr>["event"]`` or ``<expr>.get("event", ...)``."""
    if isinstance(node, ast.Subscript):
        sl = node.slice
        return _const_str(sl) == "event"
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
            and node.func.attr == "get" and node.args:
        return _const_str(node.args[0]) == "event"
    return False


def extract_registry(sources: Dict[str, str]) -> Dict[str, str]:
    """Find ``class EVENTS`` and return its ``attr -> value`` mapping."""
    for path, src in sources.items():
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError:
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and node.name == "EVENTS":
                reg = {}
                for stmt in node.body:
                    if isinstance(stmt, ast.Assign):
                        v = _const_str(stmt.value)
                        if v is None:
                            continue
                        for tgt in stmt.targets:
                            if isinstance(tgt, ast.Name):
                                reg[tgt.id] = v
                return reg
    return {}


class _EventWalker(ast.NodeVisitor):
    def __init__(self, path: str, usage: EventUsage):
        self.path = path
        self.u = usage
        # names assigned from e["event"] (scalars) / event comprehensions
        self.scalar_vars: Set[str] = set()
        self.collection_vars: Set[str] = set()

    def visit_Assign(self, node: ast.Assign):
        val = node.value
        is_scalar = _is_event_expr(val)
        is_coll = False
        if isinstance(val, (ast.SetComp, ast.ListComp, ast.GeneratorExp)):
            is_coll = _is_event_expr(val.elt)
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                if is_scalar:
                    self.scalar_vars.add(tgt.id)
                if is_coll:
                    self.collection_vars.add(tgt.id)
        self.generic_visit(node)

    def _side_is_event(self, node: ast.expr) -> bool:
        if _is_event_expr(node):
            return True
        return isinstance(node, ast.Name) and node.id in self.scalar_vars

    def _side_is_event_collection(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name) and node.id in self.collection_vars:
            return True
        if isinstance(node, (ast.SetComp, ast.ListComp, ast.GeneratorExp)):
            return _is_event_expr(node.elt)
        return False

    def visit_Compare(self, node: ast.Compare):
        sides = [node.left] + list(node.comparators)
        strict = any(self._side_is_event(s) for s in sides)
        loose = any(self._side_is_event_collection(s) for s in sides)
        if strict or loose:
            for s in sides:
                n = _name_of(s, self.u.registry)
                names = [n] if n is not None else _container_names(
                    s, self.u.registry)
                for name in names:
                    if strict:
                        self.u.note(self.u.consumed, name,
                                    self.path, node.lineno)
                    else:
                        self.u.consumed_loose.add(name)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        f = node.func
        attr = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else None)
        if attr == "record_event" and node.args:
            n = _name_of(node.args[0], self.u.registry)
            if n is not None:
                self.u.note(self.u.emitted, n, self.path, node.lineno)
        self.generic_visit(node)

    def visit_Dict(self, node: ast.Dict):
        # store-internal emissions: {"event": "STATE", ...} record literals
        for k, v in zip(node.keys, node.values):
            if k is not None and _const_str(k) == "event":
                n = _name_of(v, self.u.registry)
                if n is not None:
                    self.u.note(self.u.emitted, n, self.path, node.lineno)
        self.generic_visit(node)


def collect_event_usage(sources: Dict[str, str],
                        registry: Optional[Dict[str, str]] = None,
                        ) -> EventUsage:
    usage = EventUsage(registry if registry is not None
                       else extract_registry(sources))
    for path, src in sources.items():
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError:
            continue
        _EventWalker(path, usage).visit(tree)
    return usage


def analyze_events(sources: Dict[str, str],
                   registry: Optional[Dict[str, str]] = None,
                   ) -> List[Finding]:
    """Protocol-drift pass over ``{path: source}`` (core + benchmarks +
    tests: emitters and consumers both live in scope)."""
    u = collect_event_usage(sources, registry)
    findings: List[Finding] = []
    declared = set(u.registry.values())
    if not u.registry:
        findings.append(Finding(
            "RPX006", "", 0,
            "no EVENTS registry found (expected `class EVENTS` in "
            "store.py) — every event name is undeclared",
            "RPX006:<no-registry>"))
    consumed_any = set(u.consumed) | u.consumed_loose
    for name in sorted(set(u.emitted) - consumed_any):
        path, line = u.emitted[name][0]
        findings.append(Finding(
            "RPX004", path, line,
            f"event {name!r} is emitted but never consumed by replay, "
            f"compaction, listeners, benchmarks, or tests",
            f"RPX004:{name}"))
    for name in sorted(set(u.consumed) - set(u.emitted)):
        path, line = u.consumed[name][0]
        findings.append(Finding(
            "RPX005", path, line,
            f"event {name!r} is consumed (filtered/compared) but no "
            f"emitter exists — the filter can never match",
            f"RPX005:{name}"))
    if u.registry:
        for name in sorted((set(u.emitted) | set(u.consumed)) - declared):
            sites = u.emitted.get(name) or u.consumed.get(name)
            path, line = sites[0]
            findings.append(Finding(
                "RPX006", path, line,
                f"event {name!r} is not declared in the EVENTS registry",
                f"RPX006:{name}"))
    return findings


# --------------------------- state machine ------------------------------ #

def _extract_state_machine(sources: Dict[str, str],
                           ) -> Tuple[Set[str], Dict[str, Set[str]],
                                      Optional[str]]:
    """(enum members, machine edges, defining path) from the module that
    declares TaskState + STATE_MACHINE."""
    members: Set[str] = set()
    machine: Dict[str, Set[str]] = {}
    where: Optional[str] = None
    for path, src in sources.items():
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError:
            continue
        for node in tree.body:
            if isinstance(node, ast.ClassDef) and node.name == "TaskState":
                for stmt in node.body:
                    if isinstance(stmt, ast.Assign):
                        for tgt in stmt.targets:
                            if isinstance(tgt, ast.Name):
                                members.add(tgt.id)
                where = path
            if isinstance(node, ast.Assign):
                tgts = [t.id for t in node.targets
                        if isinstance(t, ast.Name)]
                if "STATE_MACHINE" in tgts and isinstance(node.value,
                                                          ast.Dict):
                    for k, v in zip(node.value.keys, node.value.values):
                        ks = _taskstate_attr(k)
                        if ks is None:
                            continue
                        targets = set()
                        if isinstance(v, (ast.Tuple, ast.List, ast.Set)):
                            for el in v.elts:
                                t = _taskstate_attr(el)
                                if t is not None:
                                    targets.add(t)
                        machine[ks] = targets
                    where = path
    return members, machine, where


def _taskstate_attr(node: Optional[ast.expr]) -> Optional[str]:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "TaskState":
        return node.attr
    return None


class _TransitionWalker(ast.NodeVisitor):
    def __init__(self, path: str, sites: List[Tuple[str, int, str, str]]):
        self.path = path
        self.sites = sites
        self.qual_stack: List[str] = []

    def visit_ClassDef(self, node: ast.ClassDef):
        self.qual_stack.append(node.name)
        self.generic_visit(node)
        self.qual_stack.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self.qual_stack.append(node.name)
        self.generic_visit(node)
        self.qual_stack.pop()

    def visit_Call(self, node: ast.Call):
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "transition" \
                and node.args:
            state = _taskstate_attr(node.args[0])
            if state is not None:
                qual = ".".join(self.qual_stack) or "<module>"
                self.sites.append((self.path, node.lineno, qual, state))
        self.generic_visit(node)


def analyze_state_machine(sources: Dict[str, str]) -> List[Finding]:
    members, machine, where = _extract_state_machine(sources)
    findings: List[Finding] = []
    if not members:
        return findings                       # no TaskState in scope
    if not machine:
        findings.append(Finding(
            "RPX007", where or "", 0,
            "TaskState exists but no STATE_MACHINE declares its legal "
            "transitions",
            "RPX007:machine:<missing>"))
        return findings
    for m in sorted(members - set(machine)):
        findings.append(Finding(
            "RPX007", where or "", 0,
            f"state {m} has no outgoing-edge entry in STATE_MACHINE",
            f"RPX007:machine:{m}"))
    for m in sorted(set(machine) - members):
        findings.append(Finding(
            "RPX007", where or "", 0,
            f"STATE_MACHINE declares unknown state {m}",
            f"RPX007:machine:{m}"))
    inbound = {t for targets in machine.values() for t in targets}
    sites: List[Tuple[str, int, str, str]] = []
    for path, src in sources.items():
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError:
            continue
        _TransitionWalker(path, sites).visit(tree)
    for path, line, qual, state in sites:
        module = path.rsplit("/", 1)[-1].removesuffix(".py")
        if state not in members:
            findings.append(Finding(
                "RPX007", path, line,
                f"{qual} transitions to undeclared state {state}",
                f"RPX007:{module}:{qual}:{state}"))
        elif state not in inbound:
            findings.append(Finding(
                "RPX007", path, line,
                f"{qual} transitions to {state}, which STATE_MACHINE "
                f"gives no inbound edge",
                f"RPX007:{module}:{qual}:{state}"))
    return findings
