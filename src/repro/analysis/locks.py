"""Lock-discipline static analyzer (RPX001-RPX003).

Pure-AST pass over the runtime's own source.  Three rules:

RPX001  The inter-lock acquisition graph has a cycle.  Edges come from
        nested ``with``/``acquire`` scopes inside one method and from
        cross-method propagation through self-calls: if ``m1`` holds A
        and calls ``self.m2`` which (transitively) acquires B, that is an
        A→B edge even though no single method nests the two.  A self-edge
        on a non-reentrant ``Lock``/``Condition`` is reported as an
        immediate self-deadlock.
RPX002  A blocking call runs while a lock is held: ``pickle.*``,
        file/pipe I/O (``read``/``write``/``flush``/``send``/``recv``
        methods, ``open``, ``os.fsync``/``os.replace``), ``time.sleep``,
        anything in ``subprocess``, ``Future.result()``, ``Thread.join``,
        or a (non-releasing) ``Event.wait``.  Deliberate exceptions are
        baselined with a justification, not silenced in code.
RPX003  ``Condition.wait()`` outside a ``while`` predicate loop — a bare
        ``if``-guarded or unguarded wait misses spurious wakeups and
        notify races.  ``wait_for`` carries its own predicate and is
        exempt.

The analyzer is deliberately conservative (it over-approximates "held"):
a finding means "this pattern is present", not "this deadlocks on every
path" — the committed baseline is where human judgment about documented
exceptions lives.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from . import Finding

# threading factory -> lock kind (Events matter only for the blocking-
# wait rule; Semaphores participate in ordering like plain locks)
_FACTORIES = {"Lock": "Lock", "RLock": "RLock", "Condition": "Condition",
              "Event": "Event", "Semaphore": "Semaphore",
              "BoundedSemaphore": "Semaphore"}

_IO_METHODS = {"read", "readline", "readlines", "write", "writelines",
               "flush", "recv", "recv_bytes", "send", "send_bytes",
               "sendall"}
_PICKLE_FNS = {"dumps", "loads", "dump", "load"}
_OS_BLOCKING = {"fsync", "replace", "rename", "read", "write"}

# lock identity: (owner, attr) — owner is "module.Class" for self
# attributes, "module.Class.method" for function-local locks
LockId = Tuple[str, str]


@dataclass
class LockInfo:
    kind: str
    line: int
    display: str                      # "Class._lock" — stable across moves


@dataclass
class _Edge:
    src: LockId
    dst: LockId
    path: str
    line: int
    qual: str
    via: Optional[str] = None         # callee qualname for self-call edges


@dataclass
class LockGraph:
    locks: Dict[LockId, LockInfo] = field(default_factory=dict)
    edges: List[_Edge] = field(default_factory=list)

    def edge_pairs(self) -> Set[Tuple[LockId, LockId]]:
        return {(e.src, e.dst) for e in self.edges}


def _lock_factory_kind(node: ast.expr) -> Optional[str]:
    if not isinstance(node, ast.Call):
        return None
    f = node.func
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
            and f.value.id == "threading":
        return _FACTORIES.get(f.attr)
    if isinstance(f, ast.Name):
        return _FACTORIES.get(f.id)
    return None


def _is_self_attr(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


class _ModuleLocks:
    """Inventory pass: every Lock/RLock/Condition/Event attribute assigned
    to ``self`` anywhere in a class, plus Condition-wraps-lock aliases."""

    def __init__(self, module: str, tree: ast.Module):
        self.module = module
        # (cls, attr) -> LockInfo ; aliases: cv attr -> underlying lock
        self.attrs: Dict[Tuple[str, str], LockInfo] = {}
        self.alias: Dict[Tuple[str, str], str] = {}
        for cls in [n for n in tree.body if isinstance(n, ast.ClassDef)]:
            for node in ast.walk(cls):
                if not isinstance(node, ast.Assign):
                    continue
                kind = _lock_factory_kind(node.value)
                if kind is None:
                    continue
                for tgt in node.targets:
                    attr = _is_self_attr(tgt)
                    if attr is None:
                        continue
                    self.attrs[(cls.name, attr)] = LockInfo(
                        kind, node.lineno, f"{cls.name}.{attr}")
                    # Condition(self._lock): the cv *is* that lock for
                    # ordering purposes
                    if kind == "Condition" and node.value.args:
                        under = _is_self_attr(node.value.args[0])
                        if under is not None:
                            self.alias[(cls.name, attr)] = under

    def resolve(self, cls: str, attr: str) -> Optional[Tuple[str, LockInfo]]:
        """Canonical attr (through Condition aliases) + info, or None."""
        seen = set()
        while (cls, attr) in self.alias and (cls, attr) not in seen:
            seen.add((cls, attr))
            attr = self.alias[(cls, attr)]
        info = self.attrs.get((cls, attr))
        return (attr, info) if info is not None else None

    def resolve_unique(self, attr: str) -> Optional[Tuple[str, str,
                                                          LockInfo]]:
        """Resolve a lock attribute reached through a non-``self``
        receiver (``w.send_lock``): only when the attribute name is
        unambiguous across the module's classes — ``_lock`` exists on
        half the runtime and is never resolved this way."""
        hits = [(c, a, i) for (c, a), i in self.attrs.items() if a == attr]
        return hits[0] if len(hits) == 1 else None


@dataclass
class _MethodFacts:
    qual: str                                   # "Class.method"
    acquires: Set[LockId] = field(default_factory=set)
    # (held-locks-at-site, callee-qual, line)
    self_calls: List[Tuple[Tuple[LockId, ...], str, int]] = \
        field(default_factory=list)


class _MethodWalker:
    """Single-method pass: tracks the held-lock stack through nested
    ``with`` scopes and explicit acquire/release pairs, records
    acquisition edges, blocking calls under a lock, and unguarded waits."""

    def __init__(self, module: str, path: str, cls: str, qual: str,
                 inv: _ModuleLocks, graph: LockGraph,
                 findings: List[Finding], facts: _MethodFacts):
        self.module, self.path, self.cls, self.qual = module, path, cls, qual
        self.inv, self.graph, self.findings, self.facts = \
            inv, graph, findings, facts
        self.held: List[LockId] = []
        self.while_depth = 0
        # function-local lock/cv vars: name -> (LockId, kind)
        self.local: Dict[str, Tuple[LockId, str]] = {}

    # ------------------------------ helpers ----------------------------- #
    def _lock_of(self, node: ast.expr) -> Optional[Tuple[LockId, str]]:
        """Resolve an expression to (LockId, kind) if it names a lock."""
        attr = _is_self_attr(node)
        if attr is not None:
            r = self.inv.resolve(self.cls, attr)
            if r is not None:
                canon, info = r
                return ((f"{self.module}.{self.cls}", canon), info.kind)
            return None
        if isinstance(node, ast.Name) and node.id in self.local:
            return self.local[node.id]
        if isinstance(node, ast.Attribute):
            # non-self receiver (w.send_lock): attribute-name-unique only
            r = self.inv.resolve_unique(node.attr)
            if r is not None:
                cls, canon, info = r
                return ((f"{self.module}.{cls}", canon), info.kind)
        return None

    def _display(self, lid: LockId) -> str:
        owner, attr = lid
        return f"{owner.split('.', 1)[-1]}.{attr}"

    def _push(self, lid: LockId, kind: str, line: int):
        if lid in self.held:
            if kind in ("Lock", "Condition"):
                d = self._display(lid)
                self.findings.append(Finding(
                    "RPX001", self.path, line,
                    f"{self.qual} re-acquires non-reentrant {d} "
                    f"while already holding it (self-deadlock)",
                    f"RPX001:{self.module}:{self.qual}:self:{d}"))
            # re-entry adds no ordering edge either way
            self.held.append(lid)
            return
        for h in self.held:
            if h != lid:
                self.graph.edges.append(_Edge(
                    h, lid, self.path, line, self.qual))
        self.held.append(lid)
        self.facts.acquires.add(lid)

    def _pop(self, lid: LockId):
        if lid in self.held:
            # remove the innermost occurrence (re-entrant pairs nest)
            for i in range(len(self.held) - 1, -1, -1):
                if self.held[i] == lid:
                    del self.held[i]
                    break

    def _blocking(self, path: str, line: int, what: str):
        locks = ", ".join(self._display(h) for h in dict.fromkeys(self.held))
        self.findings.append(Finding(
            "RPX002", path, line,
            f"{self.qual} calls {what} while holding {locks}",
            f"RPX002:{self.module}:{self.qual}:{what}"))

    # ---------------------------- statements ---------------------------- #
    def walk(self, stmts: Sequence[ast.stmt]):
        for s in stmts:
            self.stmt(s)

    def stmt(self, s: ast.stmt):
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return                    # closure: runs later, not under held
        if isinstance(s, ast.ClassDef):
            return
        if isinstance(s, (ast.With, ast.AsyncWith)):
            acquired: List[LockId] = []
            for item in s.items:
                self.scan_expr(item.context_expr)
                r = self._lock_of(item.context_expr)
                if r is not None:
                    lid, kind = r
                    self._push(lid, kind, s.lineno)
                    acquired.append(lid)
            self.walk(s.body)
            for lid in reversed(acquired):
                self._pop(lid)
            return
        if isinstance(s, ast.While):
            self.scan_expr(s.test)
            self.while_depth += 1
            self.walk(s.body)
            self.while_depth -= 1
            self.walk(s.orelse)
            return
        if isinstance(s, (ast.For, ast.AsyncFor)):
            self.scan_expr(s.iter)
            self.walk(s.body)
            self.walk(s.orelse)
            return
        if isinstance(s, ast.If):
            self.scan_expr(s.test)
            self.walk(s.body)
            self.walk(s.orelse)
            return
        if isinstance(s, ast.Try):
            self.walk(s.body)
            for h in s.handlers:
                self.walk(h.body)
            self.walk(s.orelse)
            self.walk(s.finalbody)
            return
        # leaf statements: remember local lock vars, then scan expressions
        if isinstance(s, ast.Assign):
            kind = _lock_factory_kind(s.value)
            if kind is not None:
                for tgt in s.targets:
                    if isinstance(tgt, ast.Name):
                        lid = (f"{self.module}.{self.qual}", tgt.id)
                        self.local[tgt.id] = (lid, kind)
                        self.graph.locks[lid] = LockInfo(
                            kind, s.lineno, f"{self.qual}:{tgt.id}")
                return
        for child in ast.iter_child_nodes(s):
            if isinstance(child, ast.expr):
                self.scan_expr(child)

    # --------------------------- expressions ---------------------------- #
    def scan_expr(self, e: ast.expr):
        for node in ast.walk(e):
            if isinstance(node, ast.Call):
                self.call(node)

    def call(self, c: ast.Call):
        f = c.func
        # --- explicit acquire/release on a known lock ---
        if isinstance(f, ast.Attribute):
            r = self._lock_of(f.value)
            if r is not None and f.attr == "acquire":
                self._push(r[0], r[1], c.lineno)
                return
            if r is not None and f.attr == "release":
                self._pop(r[0])
                return
            if f.attr == "wait" and r is not None:
                lid, kind = r
                if kind == "Condition":
                    if self.while_depth == 0:
                        d = self._display(lid)
                        self.findings.append(Finding(
                            "RPX003", self.path, c.lineno,
                            f"{self.qual} calls {d}.wait() outside a while "
                            f"predicate loop (misses spurious wakeups)",
                            f"RPX003:{self.module}:{self.qual}:{d}"))
                    return
                if kind == "Event" and self.held:
                    self._blocking(self.path, c.lineno,
                                   f"{self._display(lid)}.wait()")
                    return
        if not self.held:
            return
        # --- blocking calls under a held lock ---
        what = self._blocking_name(f)
        if what is not None:
            self._blocking(self.path, c.lineno, what)

    def _blocking_name(self, f: ast.expr) -> Optional[str]:
        if isinstance(f, ast.Name):
            return "open()" if f.id == "open" else None
        if not isinstance(f, ast.Attribute):
            return None
        base = f.value
        if isinstance(base, ast.Name):
            if base.id in ("pickle", "serializer", "json", "marshal") \
                    and f.attr in _PICKLE_FNS:
                return f"{base.id}.{f.attr}"
            if base.id == "time" and f.attr == "sleep":
                return "time.sleep"
            if base.id == "os" and f.attr in _OS_BLOCKING:
                return f"os.{f.attr}"
            if base.id == "subprocess":
                return f"subprocess.{f.attr}"
        if f.attr == "result":
            return ".result()"
        if f.attr in _IO_METHODS:
            try:
                recv = ast.unparse(base)
            except Exception:            # pragma: no cover
                recv = "?"
            return f"{recv}.{f.attr}()"
        return None


def _methods(cls: ast.ClassDef):
    for node in cls.body:
        if isinstance(node, ast.FunctionDef):
            yield node
        elif isinstance(node, (ast.If, ast.Try)):
            for sub in ast.walk(node):
                if isinstance(sub, ast.FunctionDef):
                    yield sub


def analyze_lock_discipline(sources: Dict[str, str],
                            ) -> Tuple[List[Finding], LockGraph]:
    """Run the lock-discipline pass over ``{display_path: source}``.

    Returns (findings, graph); findings carry stable baseline keys."""
    findings: List[Finding] = []
    graph = LockGraph()
    facts: Dict[str, _MethodFacts] = {}              # "mod:Cls.m" -> facts
    # pass 1: inventory + per-method walks
    per_module: List[Tuple[str, str, ast.Module, _ModuleLocks]] = []
    for path, src in sources.items():
        module = path.rsplit("/", 1)[-1].removesuffix(".py")
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError as e:
            findings.append(Finding(
                "RPX000", path, e.lineno or 0, f"syntax error: {e.msg}",
                f"RPX000:{module}"))
            continue
        inv = _ModuleLocks(module, tree)
        for (cls, attr), info in inv.attrs.items():
            if (cls, attr) not in inv.alias:        # canonical locks only
                graph.locks[(f"{module}.{cls}", attr)] = info
        per_module.append((path, module, tree, inv))

    for path, module, tree, inv in per_module:
        for cls in [n for n in tree.body if isinstance(n, ast.ClassDef)]:
            for fn in _methods(cls):
                qual = f"{cls.name}.{fn.name}"
                mf = _MethodFacts(qual)
                facts[f"{module}:{qual}"] = mf
                w = _MethodWalker(module, path, cls.name, qual, inv,
                                  graph, findings, mf)
                w.walk(fn.body)
                # collect self-call sites with the held set they run under
                _collect_self_calls(module, cls.name, fn, inv, mf)

    # pass 2: cross-method edge propagation through self-calls
    _propagate(facts, graph, sources)

    # pass 3: cycles
    findings.extend(_cycles(graph))
    return findings, graph


class _SelfCallWalker(_MethodWalker):
    """Re-walk recording (held, callee) pairs for every self-call —
    separated from the main walk so findings are not duplicated."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.findings = []            # discard: already reported

    def call(self, c: ast.Call):
        f = c.func
        if isinstance(f, ast.Attribute):
            r = self._lock_of(f.value)
            if r is not None and f.attr == "acquire":
                self._push(r[0], r[1], c.lineno)
                return
            if r is not None and f.attr == "release":
                self._pop(r[0])
                return
            callee = None
            if isinstance(f.value, ast.Name) and f.value.id == "self":
                callee = f"{self.cls}.{f.attr}"
            if callee is not None:
                self.facts.self_calls.append(
                    (tuple(dict.fromkeys(self.held)), callee, c.lineno))


def _collect_self_calls(module: str, cls: str, fn: ast.FunctionDef,
                        inv: _ModuleLocks, mf: _MethodFacts):
    g = LockGraph()                   # scratch: edges discarded
    w = _SelfCallWalker(module, "", cls, mf.qual, inv, g, [], mf)
    w.walk(fn.body)


def _propagate(facts: Dict[str, _MethodFacts], graph: LockGraph,
               sources: Dict[str, str]):
    """Fixpoint: trans_acquires(m) = acquires(m) ∪ ⋃ trans(callees);
    then every self-call made while holding H yields H→L edges for each
    transitively acquired L."""
    trans: Dict[str, Set[LockId]] = {k: set(v.acquires)
                                     for k, v in facts.items()}
    changed = True
    while changed:
        changed = False
        for key, mf in facts.items():
            module = key.split(":", 1)[0]
            for _, callee, _ in mf.self_calls:
                ck = f"{module}:{callee}"
                if ck in trans and not trans[ck] <= trans[key]:
                    trans[key] |= trans[ck]
                    changed = True
    path_of = {p.rsplit("/", 1)[-1].removesuffix(".py"): p for p in sources}
    for key, mf in facts.items():
        module = key.split(":", 1)[0]
        for held, callee, line in mf.self_calls:
            if not held:
                continue
            ck = f"{module}:{callee}"
            for lid in trans.get(ck, ()):
                for h in held:
                    if h == lid:
                        # re-entry through a self-call: only safe on an
                        # RLock — surfaced by the cycle pass as a
                        # self-edge below
                        kind = graph.locks.get(lid)
                        if kind is not None and kind.kind != "RLock":
                            graph.edges.append(_Edge(
                                h, lid, path_of.get(module, module), line,
                                mf.qual, via=callee))
                        continue
                    graph.edges.append(_Edge(
                        h, lid, path_of.get(module, module), line,
                        mf.qual, via=callee))


def _cycles(graph: LockGraph) -> List[Finding]:
    """Tarjan SCCs over the acquisition graph; every SCC larger than one
    lock (or a self-edge) is a deadlock-risk cycle."""
    adj: Dict[LockId, Set[LockId]] = {}
    for e in graph.edges:
        adj.setdefault(e.src, set()).add(e.dst)
        adj.setdefault(e.dst, set())
    index: Dict[LockId, int] = {}
    low: Dict[LockId, int] = {}
    on_stack: Set[LockId] = set()
    stack: List[LockId] = []
    sccs: List[List[LockId]] = []
    counter = [0]

    def strongconnect(v: LockId):
        work = [(v, iter(sorted(adj.get(v, ()))))]
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack.add(v)
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(sorted(adj.get(w, ())))))
                    advanced = True
                    break
                elif w in on_stack:
                    low[node] = min(low[node], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
            if low[node] == index[node]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    scc.append(w)
                    if w == node:
                        break
                sccs.append(scc)

    for v in sorted(adj):
        if v not in index:
            strongconnect(v)

    def disp(lid: LockId) -> str:
        info = graph.locks.get(lid)
        return info.display if info else f"{lid[0]}.{lid[1]}"

    findings: List[Finding] = []
    self_edges = {(e.src, e.dst) for e in graph.edges if e.src == e.dst}
    for scc in sccs:
        if len(scc) < 2 and (scc[0], scc[0]) not in self_edges:
            continue
        names = sorted(disp(l) for l in scc)
        members = {l for l in scc}
        sites = sorted({(e.path, e.line, e.qual) for e in graph.edges
                        if e.src in members and e.dst in members})
        where = "; ".join(f"{q} ({p}:{ln})" for p, ln, q in sites[:4])
        path, line = (sites[0][0], sites[0][1]) if sites else ("", 0)
        findings.append(Finding(
            "RPX001", path, line,
            f"lock-order cycle between {{{', '.join(names)}}} — "
            f"acquisition sites: {where}",
            f"RPX001:{'->'.join(names)}"))
    return findings
