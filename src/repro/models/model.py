"""LM assembly: embeddings + stack + loss; step-function factories.

``make_train_step`` / ``make_prefill_step`` / ``make_decode_step`` produce
plain functions over (params, batch, ...) suitable for ``jax.jit`` with
explicit in/out shardings — these are the *task bodies* the workflow runtime
(repro.core) schedules, and the functions the multi-pod dry-run lowers.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.partition import NULL_CTX, PartitionRules, ShardCtx

from . import transformer
from .layers import mlp, rms_norm, softcap


# ----------------------------- embeddings ------------------------------ #

def embed_inputs(cfg, params, batch, sctx: ShardCtx = NULL_CTX):
    """Token (+ stub-frontend) embedding.  Returns (B, S_total, D) embeds."""
    emb = params["embed"]
    tok = batch["tokens"]
    x = jnp.take(emb, tok, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.frontend == "vision_stub" and "patches" in batch:
        p = batch["patches"].astype(x.dtype)
        w = params["connector"]
        p = jnp.einsum("bnd,df->bnf", p, w["wi"])
        p = jax.nn.gelu(p)
        p = jnp.einsum("bnf,fd->bnd", p, w["wo"])
        x = jnp.concatenate([p, x], axis=1)
    return sctx.act(x, ("batch", "seq", None))


def lm_logits(cfg, params, hidden):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", hidden, head.astype(hidden.dtype))
    return softcap(logits, cfg.logit_softcap)


# ------------------------------- loss ---------------------------------- #

def _xent_block(cfg, params, hidden, targets, mask):
    logits = lm_logits(cfg, params, hidden).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum(), mask.sum()


def lm_loss(cfg, params, hidden, targets, mask, *, chunk: int = 2048):
    """Cross-entropy, chunked along sequence so (B, chunk, V) is the largest
    logits buffer ever live (a production necessity at V=256k)."""
    B, S, D = hidden.shape
    if S <= chunk or S % chunk:
        nll, denom = _xent_block(cfg, params, hidden, targets, mask)
        return nll / jnp.maximum(denom, 1.0)
    nb = S // chunk
    h = hidden.reshape(B, nb, chunk, D).swapaxes(0, 1)
    t = targets.reshape(B, nb, chunk).swapaxes(0, 1)
    m = mask.reshape(B, nb, chunk).swapaxes(0, 1)

    def body(carry, xs):
        hb, tb, mb = xs
        nll, denom = _xent_block(cfg, params, hb, tb, mb)
        return (carry[0] + nll, carry[1] + denom), None

    (nll, denom), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                   (h, t, m))
    return nll / jnp.maximum(denom, 1.0)


# --------------------------- step factories ----------------------------- #

def loss_fn(cfg, params, batch, sctx: ShardCtx = NULL_CTX, use_pallas=False):
    x = embed_inputs(cfg, params, batch, sctx)
    hidden, _, aux = transformer.forward(
        cfg, params, x, mode="train", sctx=sctx, use_pallas=use_pallas)
    targets, mask = batch["targets"], batch["loss_mask"]
    if cfg.frontend == "vision_stub" and "patches" in batch:
        # patches occupy the prefix; loss only over text positions
        nfe = batch["patches"].shape[1]
        hidden = hidden[:, nfe:]
    loss = lm_loss(cfg, params, hidden, targets, mask)
    if cfg.num_experts:
        loss = loss + 0.01 * aux
    return loss, {"loss": loss, "aux": aux}


def make_loss_and_grad(cfg, sctx: ShardCtx = NULL_CTX, use_pallas=False):
    def f(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, sctx, use_pallas),
            has_aux=True)(params)
        return grads, metrics
    return f


def make_train_step(cfg, optimizer, sctx: ShardCtx = NULL_CTX,
                    use_pallas=False, microbatches: int = 1,
                    grad_dtype: str = "float32"):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches`` > 1 enables gradient accumulation: the global batch is
    split along dim 0 and scanned, so layer-boundary activation checkpoints
    are only live for one microbatch (the knob that fits 94-layer models in
    HBM).  Gradients accumulate in ``grad_dtype`` (bf16 for the >=100B archs
    where the f32 buffer alone would blow the per-chip budget).
    """
    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch, sctx, use_pallas),
                has_aux=True)(params)
        else:
            dt = jnp.dtype(grad_dtype)
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            if sctx.mesh is not None:
                from repro.models import transformer as _T
                gspecs = _T.param_pspecs(cfg, sctx.mesh, sctx.rules)
                pin = lambda t: jax.tree.map(
                    lambda x, s: jax.lax.with_sharding_constraint(
                        x, jax.NamedSharding(sctx.mesh, s)), t, gspecs)
            else:
                pin = lambda t: t

            def acc_step(carry, mbatch):
                gacc, lacc = carry
                (loss, metrics), g = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, mbatch, sctx, use_pallas),
                    has_aux=True)(params)
                gacc = pin(jax.tree.map(lambda a, b: a + b.astype(dt),
                                        gacc, g))
                return (gacc, lacc + loss), metrics

            g0 = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params))
            (gsum, lsum), mstack = jax.lax.scan(
                acc_step, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = jax.tree.map(lambda m: m.mean(), mstack)
            metrics["loss"] = loss
        params, opt_state, gnorm = optimizer.update(params, grads, opt_state)
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, metrics
    return train_step


def auto_microbatches(cfg, shape, n_batch_shards: int,
                      target_bytes: float = 4e9) -> int:
    """Pick grad-accumulation depth so layer-boundary checkpoints fit.

    carry bytes = local_batch * seq * d_model * 2 (bf16) * n_groups.
    """
    from repro.models.transformer import program_period
    if shape.kind != "train":
        return 1
    local_b = max(1, shape.global_batch // max(1, n_batch_shards))
    groups = cfg.num_layers // program_period(cfg)
    carry = local_b * shape.seq_len * cfg.d_model * 2 * groups
    need = max(1, int(-(-carry // target_bytes)))
    mu = 1
    while mu < need and mu < local_b and local_b % (mu * 2) == 0:
        mu *= 2
    return mu


def make_prefill_step(cfg, sctx: ShardCtx = NULL_CTX, use_pallas=False):
    """(params, batch) -> (last-token logits, cache)."""
    def prefill_step(params, batch):
        x = embed_inputs(cfg, params, batch, sctx)
        hidden, cache, _ = transformer.forward(
            cfg, params, x, mode="prefill", sctx=sctx, use_pallas=use_pallas)
        logits = lm_logits(cfg, params, hidden[:, -1:])
        return logits, cache
    return prefill_step


def make_decode_step(cfg, sctx: ShardCtx = NULL_CTX, use_pallas=False):
    """(params, token (B,1), cache, pos) -> (logits (B,1,V), new cache)."""
    def decode_step(params, token, cache, pos):
        x = embed_inputs(cfg, params, {"tokens": token}, sctx)
        hidden, cache, _ = transformer.forward(
            cfg, params, x, mode="decode", sctx=sctx, cache=cache, pos=pos,
            use_pallas=use_pallas)
        return lm_logits(cfg, params, hidden), cache
    return decode_step


# ------------------------------ input specs ----------------------------- #

def input_specs(cfg, shape, *, abstract: bool = True) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a given cell.

    For train/prefill the dict is the `batch`; for decode it is
    {token, cache, pos}.  Frontend stubs contribute precomputed patch
    embeddings (the assignment's modality-stub contract).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.dtype("int32")
    bf16 = jnp.dtype(cfg.dtype)
    nfe = cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0
    s_text = S - nfe

    def sd(shape_, dt):
        return jax.ShapeDtypeStruct(shape_, dt)

    if shape.kind in ("train", "prefill"):
        spec = {"tokens": sd((B, s_text), i32)}
        if nfe:
            spec["patches"] = sd((B, nfe, cfg.d_model), bf16)
        if shape.kind == "train":
            spec["targets"] = sd((B, s_text), i32)
            spec["loss_mask"] = sd((B, s_text), jnp.dtype("float32"))
        return spec
    # decode: one new token against a seq_len cache
    return {
        "token": sd((B, 1), i32),
        "cache": transformer.cache_specs(cfg, B, S, cfg.dtype),
        "pos": sd((), i32),
    }


def input_axes(cfg, shape) -> Dict[str, Any]:
    """Logical sharding axes matching :func:`input_specs`."""
    if shape.kind in ("train", "prefill"):
        ax = {"tokens": ("batch", "seq")}
        if cfg.frontend == "vision_stub":
            ax["patches"] = ("batch", "seq", None)
        if shape.kind == "train":
            ax["targets"] = ("batch", "seq")
            ax["loss_mask"] = ("batch", "seq")
        return ax
    return {
        "token": ("batch", None),
        "cache": transformer.cache_axes(cfg),
        "pos": (),
    }
