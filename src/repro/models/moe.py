"""Capacity-based top-k Mixture-of-Experts (GShard/Switch lineage).

Two dispatch modes (select via ``cfg.moe_dispatch`` — a §Perf hillclimb knob):

* ``einsum``  — classic TPU one-hot dispatch/combine einsums. Baseline;
  matches what TPU MoE systems of the paper's era actually ran.  Its one-hot
  matmuls are counted (and executed) as real MXU FLOPs.
* ``gather``  — zero-FLOP dispatch: token->slot indices built with a cumsum +
  scatter, tokens moved by gather, combined by gather.  Removes the dispatch
  einsum FLOPs entirely (beyond-paper optimization).

Tokens are processed in groups so the dispatch tensors stay VMEM-sized.
Experts are sharded on the ``model`` mesh axis (EP); token groups on
``data`` — the cross product is the all-to-all the XLA partitioner inserts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_params_spec(cfg):
    # "expert_ff" is unsharded by default (FSDP handles the d dim); the
    # decode-optimized rule set maps it to the data axis (2D expert-TP, so
    # weights are never all-gathered at serving time).
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    spec = {
        "router": ((d, e), ("embed_w", None)),
        "wi": ((e, d, f), ("expert", "expert_embed", "expert_ff")),
        "wo": ((e, f, d), ("expert", "expert_ff", "expert_embed")),
    }
    if cfg.gated_mlp:
        spec["wg"] = ((e, d, f), ("expert", "expert_embed", "expert_ff"))
    return spec


def _route(x, router_w, cfg):
    """x: (G, T, D) -> gates (G, T, k), idx (G, T, k)."""
    logits = jnp.einsum("gtd,de->gte", x, router_w.astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch): E * sum(frac_tokens * frac_prob)
    E = cfg.num_experts
    me = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    ce = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return gates, idx, aux


def _positions(idx, E, C):
    """Slot position of each (token, k) assignment within its expert.

    idx: (G, T, k) int. Returns pos (G, T, k) int (>= C means dropped).
    Priority: slot order then token order (GShard).
    """
    G, T, K = idx.shape
    flat = idx.transpose(0, 2, 1).reshape(G, K * T)          # k-major priority
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)        # (G, KT, E)
    pos_flat = jnp.cumsum(onehot, axis=1) - 1                # (G, KT, E)
    pos_flat = jnp.take_along_axis(pos_flat, flat[..., None], axis=2)[..., 0]
    return pos_flat.reshape(G, K, T).transpose(0, 2, 1)      # (G, T, k)


def _expert_ffn(xe, w, gated):
    """xe: (G, E, C, D) -> (G, E, C, D) through per-expert MLP."""
    h = jnp.einsum("gecd,edf->gecf", xe, w["wi"])
    if gated:
        g = jnp.einsum("gecd,edf->gecf", xe, w["wg"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("gecf,efd->gecd", h, w["wo"])


def moe_ffn(x, w, cfg, sctx, group_size: int = 4096):
    """x: (B, S, D) -> (B, S, D).  Returns (out, aux_loss)."""
    B, S, D = x.shape
    T = B * S
    g = max(1, T // min(group_size, T))
    Tg = T // g
    xg = x.reshape(g, Tg, D)
    xg = sctx.act(xg, ("batch", None, None))

    E, K = cfg.num_experts, cfg.num_experts_per_tok
    C = int(-(-Tg * K * cfg.capacity_factor // E))           # ceil
    C = max(4, (C + 3) // 4 * 4)
    C = min(C, Tg * K)

    gates, idx, aux = _route(xg, w["router"], cfg)
    pos = _positions(idx, E, C)                              # (G, T, k)
    keep = (pos < C)
    gates = gates * keep

    if cfg.moe_dispatch == "einsum":
        # dispatch (G, T, E, C) one-hot; combine = dispatch * per-token gate
        oh_e = jax.nn.one_hot(idx, E, dtype=xg.dtype)                  # (G,T,k,E)
        oh_c = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                              dtype=xg.dtype)[..., :-1]                # (G,T,k,C)
        disp = jnp.einsum("gtke,gtkc->gtec", oh_e, oh_c)
        disp = sctx.act(disp, ("batch", None, "expert", None))
        xe = jnp.einsum("gtec,gtd->gecd", disp, xg)
        xe = sctx.act(xe, ("batch", "expert", None, None))
        ye = _expert_ffn(xe, w, cfg.gated_mlp)
        ye = sctx.act(ye, ("batch", "expert", None, None))
        comb = jnp.einsum("gtke,gtkc,gtk->gtec", oh_e, oh_c,
                          gates.astype(xg.dtype))
        comb = sctx.act(comb, ("batch", None, "expert", None))
        out = jnp.einsum("gtec,gecd->gtd", comb, ye)
    else:  # gather dispatch: zero-FLOP data movement
        tok = jnp.broadcast_to(jnp.arange(Tg)[None, :, None], idx.shape)
        slot_src = jnp.full((g, E, C), Tg, jnp.int32)        # Tg = "no token"
        slot_src = slot_src.at[
            jnp.arange(g)[:, None, None],
            jnp.where(keep, idx, E - 1),
            jnp.where(keep, pos, C - 1)].set(jnp.where(keep, tok, Tg))
        xpad = jnp.concatenate([xg, jnp.zeros((g, 1, D), xg.dtype)], axis=1)
        xe = jnp.take_along_axis(
            xpad, slot_src.reshape(g, E * C)[..., None],
            axis=1).reshape(g, E, C, D)
        xe = sctx.act(xe, ("batch", "expert", None, None))
        ye = _expert_ffn(xe, w, cfg.gated_mlp)
        ypad = ye.reshape(g, E * C, D)
        flat_slot = idx * C + jnp.where(keep, pos, 0)        # (G, T, k)
        yk = jnp.take_along_axis(ypad, flat_slot.reshape(g, Tg * K)[..., None],
                                 axis=1).reshape(g, Tg, K, D)
        out = jnp.einsum("gtkd,gtk->gtd", yk, (gates * keep).astype(yk.dtype))

    out = out.reshape(B, S, D)
    return sctx.act(out, ("batch", "seq", None)), aux
