"""Transformer/hybrid assembly: param specs, init, scan-over-layers forward.

The layer stack is expressed as a *program* [(mixer, ffn)] and compiled as a
``lax.scan`` over its repeating period (gemma2: period 2 local/global; jamba:
period 8 = 7 mamba + 1 attn with MoE on odd positions; everything else:
period 1).  Scan keeps HLO size O(1) in depth — a 94-layer qwen3 lowers as a
single group body — which is what makes 80 dry-run compiles tractable.

Weights for sub-layer position j are stacked (G, ...) where G = L / period.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.partition import NULL_CTX, PartitionRules, ShardCtx

from .attention import AttnCache, attention_layer, attn_params_spec
from .layers import mlp, rms_norm, softcap
from .mamba2 import MambaCache, mamba_layer, mamba_params_spec
from .moe import moe_ffn, moe_params_spec


# ------------------------ differentiable barrier ------------------------ #

@jax.custom_vjp
def _pin(x):
    """``optimization_barrier`` with a VJP: the stock primitive has no
    differentiation rule, so pin the forward residual and the backward
    cotangent explicitly (the barrier must survive AD for remat to work)."""
    return jax.lax.optimization_barrier(x)


def _pin_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _pin_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_pin.defvjp(_pin_fwd, _pin_bwd)


# --------------------------- layer program ----------------------------- #

def layer_program(cfg) -> List[Tuple[str, str]]:
    return [(cfg.layer_kind(i), cfg.ffn_kind(i)) for i in range(cfg.num_layers)]


def program_period(cfg) -> int:
    prog = layer_program(cfg)
    L = len(prog)
    for p in range(1, L + 1):
        if L % p == 0 and all(prog[i] == prog[i % p] for i in range(L)):
            return p
    return L


# ----------------------------- param specs ------------------------------ #

def _dense_ffn_spec(cfg):
    d, f = cfg.d_model, cfg.d_ff
    s = {"wi": ((d, f), ("embed_w", "mlp")), "wo": ((f, d), ("mlp", "embed_w"))}
    if cfg.gated_mlp:
        s["wg"] = ((d, f), ("embed_w", "mlp"))
    return s


def sublayer_spec(cfg, mixer: str, ffn: str):
    d = cfg.d_model
    spec: Dict[str, Any] = {"norm1": ((d,), ("embed_w",))}
    if mixer in ("attn", "local_attn"):
        spec["mixer"] = attn_params_spec(cfg)
    else:
        spec["mixer"] = mamba_params_spec(cfg)
    if ffn != "none":
        spec["norm2"] = ((d,), ("embed_w",))
        spec["ffn"] = moe_params_spec(cfg) if ffn == "moe" else _dense_ffn_spec(cfg)
    return spec


def param_specs(cfg):
    """Full spec tree; leaves are (shape, logical_axes)."""
    d, V = cfg.d_model, cfg.vocab_size
    p = program_period(cfg)
    G = cfg.num_layers // p
    spec: Dict[str, Any] = {
        "embed": ((V, d), ("vocab", "embed_w")),
        "final_norm": ((d,), ("embed_w",)),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = ((d, V), ("embed_w", "vocab"))
    if cfg.frontend == "vision_stub":
        spec["connector"] = {"wi": ((d, d), ("embed_w", "mlp")),
                             "wo": ((d, d), ("mlp", "embed_w"))}
    prog = layer_program(cfg)
    layers = []
    for j in range(p):
        sub = sublayer_spec(cfg, *prog[j])
        sub = jax.tree.map(
            lambda leaf: ((G,) + leaf[0], ("layers",) + leaf[1]),
            sub, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], tuple))
        layers.append(sub)
    spec["layers"] = layers
    return spec


def _is_spec_leaf(x):
    return (isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)
            and all(isinstance(i, int) for i in x[0]))


def abstract_params(cfg, dtype=None):
    dtype = dtype or cfg.dtype
    return jax.tree.map(
        lambda leaf: jax.ShapeDtypeStruct(leaf[0], jnp.dtype(dtype)),
        param_specs(cfg), is_leaf=_is_spec_leaf)


def param_axes(cfg):
    return jax.tree.map(lambda leaf: leaf[1], param_specs(cfg),
                        is_leaf=_is_spec_leaf)


def param_pspecs(cfg, mesh, rules: Optional[PartitionRules] = None):
    rules = rules or PartitionRules()
    return jax.tree.map(
        lambda leaf: rules.spec_for(leaf[1], leaf[0], mesh),
        param_specs(cfg), is_leaf=_is_spec_leaf)


def init_params(cfg, key, dtype=None):
    """Real initialization (smoke tests / the end-to-end trainer)."""
    dtype = dtype or cfg.dtype
    specs = param_specs(cfg)
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec_leaf)
    keys = jax.random.split(key, len(leaves))

    def mk(leaf, k):
        shape, axes = leaf
        core = axes[1:] if axes[:1] == ("layers",) else axes
        if core == ("embed_w",):                    # norm scale, stored as delta
            return jnp.zeros(shape, dtype)
        if core == ("ssm_heads",):                  # A_log / dt_bias / D
            return jax.random.uniform(k, shape, jnp.float32, 0.5, 1.5)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        if core and core[0] in ("heads",):          # wo: (H, hd, D) fan_in = H*hd
            fan_in = shape[-3] * shape[-2] if len(shape) >= 3 else fan_in
        scale = 1.0 / math.sqrt(max(1, fan_in))
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    vals = [mk(l, k) for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


# ------------------------------- caches -------------------------------- #

def cache_specs(cfg, batch: int, max_seq: int, dtype="bfloat16"):
    """Abstract decode cache tree (matches ``layers`` structure)."""
    p = program_period(cfg)
    G = cfg.num_layers // p
    prog = layer_program(cfg)
    dt = jnp.dtype(dtype)
    out = []
    for j in range(p):
        mixer, _ = prog[j]
        if mixer in ("attn", "local_attn"):
            kv = jax.ShapeDtypeStruct(
                (G, batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dt)
            out.append(AttnCache(kv, kv))
        else:
            out.append(MambaCache(
                jax.ShapeDtypeStruct(
                    (G, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32),
                jax.ShapeDtypeStruct(
                    (G, batch, cfg.conv_width - 1,
                     cfg.inner_dim + 2 * cfg.ssm_state), dt)))
    return out


def cache_axes(cfg):
    """Logical axes matching cache_specs leaves."""
    p = program_period(cfg)
    prog = layer_program(cfg)
    out = []
    for j in range(p):
        mixer, _ = prog[j]
        if mixer in ("attn", "local_attn"):
            ax = ("layers", "batch", "seq_kv", "kv_heads", "head_dim")
            out.append(AttnCache(ax, ax))
        else:
            out.append(MambaCache(
                ("layers", "batch", "ssm_heads", None, "state"),
                ("layers", "batch", None, "ssm_inner")))
    return out


def init_cache(cfg, batch: int, max_seq: int, dtype="bfloat16"):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_seq, dtype),
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ------------------------------- forward ------------------------------- #

def _apply_sublayer(cfg, kind, ffn, w, x, *, sctx, positions, cache, pos,
                    use_pallas):
    h = rms_norm(x, w["norm1"], cfg.norm_eps)
    if kind in ("attn", "local_attn"):
        mix, new_cache = attention_layer(
            cfg, w["mixer"], h, local=(kind == "local_attn"), sctx=sctx,
            positions=positions, cache=cache, pos=pos, use_pallas=use_pallas)
    else:
        mix, new_cache = mamba_layer(cfg, w["mixer"], h, sctx=sctx,
                                     cache=cache, use_pallas=use_pallas)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = rms_norm(x, w["norm2"], cfg.norm_eps)
        if ffn == "moe":
            out, aux = moe_ffn(h, w["ffn"], cfg, sctx)
        else:
            out = mlp(h, w["ffn"], cfg.gated_mlp)
            out = sctx.act(out, ("batch", "seq", None))
        x = x + out
    return x, new_cache, aux


def forward(cfg, params, embeds, *, mode: str = "train",
            sctx: ShardCtx = NULL_CTX, positions=None, cache=None, pos=None,
            use_pallas=False, remat: Optional[str] = None):
    """Run the layer stack.  embeds: (B, S, D).

    mode: "train" (no caches), "prefill" (emit caches), "decode" (cache
    in/out, S == 1, ``pos`` = write index).
    Returns (hidden (B,S,D), new_cache_or_None, aux_loss scalar).
    """
    prog = layer_program(cfg)
    p = program_period(cfg)
    remat = cfg.remat if remat is None else remat
    x = embeds

    policy = {"full": jax.checkpoint_policies.nothing_saveable,
              "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
              "none": None}[remat]

    def make_sub(j):
        kind, ffn = prog[j]

        def sub(x, wj, cj):
            return _apply_sublayer(
                cfg, kind, ffn, wj, x, sctx=sctx, positions=positions,
                cache=cj, pos=pos, use_pallas=use_pallas)
        # two-level remat: the outer checkpoint on the scanned group keeps
        # scan residuals to one carry per group; the inner per-sublayer
        # checkpoint keeps the group's backward to one sublayer's interior
        # at a time (crucial for jamba's 8-sublayer groups).
        if mode == "train" and policy is not None and p > 1:
            return jax.checkpoint(sub, policy=policy)
        return sub

    subs = [make_sub(j) for j in range(p)]

    def group_body(x, ws, cs):
        # Barrier pins the scan residual to the bf16 carry itself: without
        # it XLA CSEs rms_norm's f32 upcast into the saved residual,
        # doubling layer-boundary checkpoint memory.
        x = _pin(x)
        new_caches = []
        aux_total = jnp.zeros((), jnp.float32)
        for j in range(p):
            cj = cs[j] if cs is not None else None
            x, nc, aux = subs[j](x, ws[j], cj)
            new_caches.append(nc)
            aux_total = aux_total + aux
        return x, tuple(new_caches), aux_total

    if mode == "train" and policy is not None:
        group_body = jax.checkpoint(group_body, policy=policy)

    ws_stacked = tuple(params["layers"])   # tuple over j of stacked trees

    if mode == "train":
        def body(c, w):
            c, _, aux = group_body(c, w, None)
            return c, aux
        x, auxs = jax.lax.scan(body, x, ws_stacked)
        new_cache = None
    elif mode == "prefill":
        def body(c, w):
            c, ncs, aux = group_body(c, w, None)
            return c, (ncs, aux)
        x, (new_cache, auxs) = jax.lax.scan(body, x, ws_stacked)
        new_cache = list(new_cache)
    elif mode == "decode":
        def body(c, wc):
            w, cs = wc
            c, ncs, aux = group_body(c, w, cs)
            return c, (ncs, aux)
        x, (new_cache, auxs) = jax.lax.scan(body, x, (ws_stacked, tuple(cache)))
        new_cache = list(new_cache)
    else:
        raise ValueError(mode)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache, jnp.sum(auxs)
