from . import attention, layers, mamba2, model, moe, transformer  # noqa
