"""GQA attention: blockwise flash-style (train/prefill) + KV-cache decode.

The blockwise implementation is the pure-JAX statement of the flash
algorithm (online softmax over KV blocks via ``lax.scan``): it is the
compile-anywhere path used by the dry-run, and the oracle the Pallas TPU
kernel in ``repro.kernels`` is validated against.  Memory is O(S * block_k)
instead of O(S^2), which is what makes the 32k-prefill cells lowerable.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .layers import apply_rope, softcap
from ..compat import shard_map

NEG_INF = -1e30


def _mesh_axes(mesh):
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    db = 1
    for a in batch_axes:
        db *= mesh.shape[a]
    m = mesh.shape.get("model", 1)
    return batch_axes, db, m


def _mask_block(q_pos, kv_pos, *, causal: bool, window: int):
    """(Sq, Bk) boolean mask for one KV block."""
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), dtype=bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window:
        m &= kv_pos[None, :] > (q_pos[:, None] - window)
    return m


def _block_scores(qg, kblk, q_pos, kv_pos, *, causal, window, cap, scale):
    """Masked (possibly soft-capped) scores for one KV block, f32."""
    s = jnp.einsum("bshgd,bkhd->bshgk", qg, kblk,
                   preferred_element_type=jnp.float32) * scale
    if cap:
        s = softcap(s, cap)
    mask = _mask_block(q_pos, kv_pos, causal=causal, window=window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    return s, mask


def _blockify(k, block_k):
    B, Skv, Hkv, D = k.shape
    nblk = (Skv + block_k - 1) // block_k
    pad = nblk * block_k - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return k.reshape(B, nblk, block_k, Hkv, D), nblk, pad


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def blockwise_attention(q, k, v, q_offset, causal: bool = True,
                        window: int = 0, attn_softcap: float = 0.0,
                        block_k: int = 512, block_q: int = 512):
    """Flash attention in pure JAX: q (B,Sq,Hq,D) x k/v (B,Skv,Hkv,D).

    Double-blocked (q x kv nested scans): transients are O(block_q*block_k),
    never O(S^2) or O(S*block).  Backward is a custom VJP that saves only
    (q,k,v,out,lse) and recomputes block scores — the flash algorithm stated
    in jnp, and the oracle the Pallas TPU kernel is validated against.

    ``q_offset`` (int32 scalar array, traced) is the absolute position of
    q[:, 0] — nonzero under sequence-parallel attention where each model
    shard owns a contiguous q chunk.
    """
    out, _ = _flash_fwd(q, k, v, q_offset, causal, window, attn_softcap,
                        block_k, block_q)
    return out


def _qblockify(q, block_q):
    B, Sq, Hkv, G, D = q.shape
    nq = (Sq + block_q - 1) // block_q
    pad = nq * block_q - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    return q.reshape(B, nq, block_q, Hkv, G, D), nq, pad


def _flash_fwd(q, k, v, q_offset, causal, window, cap, block_k, block_q):
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    qb, nq, qpad = _qblockify(q.reshape(B, Sq, Hkv, G, D), block_q)
    kb, nk, _ = _blockify(k, block_k)
    vb, _, _ = _blockify(v, block_k)

    def q_step(_, qs):
        qblk, qi = qs                                  # (B,bq,Hkv,G,D)
        q_pos = q_offset + qi * block_q + jnp.arange(block_q)

        def kv_step(carry, blk):
            m_i, l_i, acc = carry
            kblk, vblk, ki = blk
            kv_pos = ki * block_k + jnp.arange(block_k)
            s, _ = _block_scores(qblk, kblk, q_pos, kv_pos, causal=causal,
                                 window=window, cap=cap, scale=scale)
            s = jnp.where((kv_pos < Skv)[None, None, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m_i, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_i - m_new)
            l_new = l_i * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bshgk,bkhd->bshgd", p, vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, block_q, Hkv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, block_q, Hkv, G), jnp.float32)
        a0 = jnp.zeros((B, block_q, Hkv, G, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nk)))
        l = jnp.maximum(l, 1e-30)
        out_blk = (acc / l[..., None]).astype(q.dtype)
        lse_blk = m + jnp.log(l)
        return None, (out_blk, lse_blk)

    _, (ob, lseb) = jax.lax.scan(q_step, None, (qb.swapaxes(0, 1),
                                                jnp.arange(nq)))
    out = ob.swapaxes(0, 1).reshape(B, nq * block_q, Hq, D)[:, :Sq]
    lse = lseb.swapaxes(0, 1).reshape(B, nq * block_q, Hkv, G)[:, :Sq]
    return out, (q, k, v, q_offset, out, lse)


def _flash_fwd_vjp(q, k, v, q_offset, causal, window, cap, block_k, block_q):
    out, res = _flash_fwd(q, k, v, q_offset, causal, window, cap, block_k,
                          block_q)
    return out, res


def _flash_bwd(causal, window, cap, block_k, block_q, res, dout):
    q, k, v, q_offset, out, lse = res
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    og = (out.astype(jnp.float32) * dout.astype(jnp.float32)) \
        .reshape(B, Sq, Hkv, G, D).sum(axis=-1)            # delta (B,Sq,Hkv,G)
    qb, nq, qpad = _qblockify(q.reshape(B, Sq, Hkv, G, D), block_q)
    dogb, _, _ = _qblockify(dout.reshape(B, Sq, Hkv, G, D), block_q)
    deltab = jnp.pad(og, ((0, 0), (0, qpad), (0, 0), (0, 0))) \
        .reshape(B, nq, block_q, Hkv, G)
    lseb = jnp.pad(lse, ((0, 0), (0, qpad), (0, 0), (0, 0)),
                   constant_values=NEG_INF).reshape(B, nq, block_q, Hkv, G)
    kb, nk, kpad = _blockify(k, block_k)
    vb, _, _ = _blockify(v, block_k)

    # outer scan over KV blocks (ys -> dk/dv blocks); inner over q blocks
    # (carry accumulates dq into a full-size f32 buffer by slice updates).
    def kv_step(dq_full, blk):
        kblk, vblk, ki = blk
        kv_pos = ki * block_k + jnp.arange(block_k)

        def q_step(carry, qs):
            dq_full = carry
            qblk, dogblk, lse_blk, delta_blk, qi = qs
            lq = qi * block_q + jnp.arange(block_q)
            q_pos = q_offset + lq
            sraw = jnp.einsum("bshgd,bkhd->bshgk", qblk, kblk,
                              preferred_element_type=jnp.float32) * scale
            s = softcap(sraw, cap) if cap else sraw
            mask = _mask_block(q_pos, kv_pos, causal=causal, window=window)
            mask &= (kv_pos < Skv)[None, :]
            mask &= (lq < Sq)[:, None]
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            p = jnp.exp(s - lse_blk[..., None])
            dvb = jnp.einsum("bshgk,bshgd->bkhd", p,
                             dogblk.astype(jnp.float32))
            dp = jnp.einsum("bshgd,bkhd->bshgk", dogblk, vblk,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - delta_blk[..., None])
            if cap:
                ds = ds * (1.0 - jnp.square(s / cap))      # tanh chain rule
            ds = jnp.where(mask[None, :, None, None, :], ds, 0.0) * scale
            dq_blk = jnp.einsum("bshgk,bkhd->bshgd", ds, kblk)
            dkb = jnp.einsum("bshgk,bshgd->bkhd", ds,
                             qblk.astype(jnp.float32))
            start = qi * block_q
            prev = jax.lax.dynamic_slice_in_dim(dq_full, start, block_q, 1)
            dq_full = jax.lax.dynamic_update_slice_in_dim(
                dq_full, prev + dq_blk.reshape(B, block_q, Hq, D), start, 1)
            return dq_full, (dkb, dvb)

        dq_full, (dkbs, dvbs) = jax.lax.scan(
            q_step, dq_full,
            (qb.swapaxes(0, 1), dogb.swapaxes(0, 1), lseb.swapaxes(0, 1),
             deltab.swapaxes(0, 1), jnp.arange(nq)))
        return dq_full, (dkbs.sum(axis=0), dvbs.sum(axis=0))

    dq0 = jnp.zeros((B, nq * block_q, Hq, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(
        kv_step, dq0, (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nk)))
    dk = dks.swapaxes(0, 1).reshape(B, nk * block_k, Hkv, D)[:, :Skv]
    dv = dvs.swapaxes(0, 1).reshape(B, nk * block_k, Hkv, D)[:, :Skv]
    d_offset = np.zeros((), jax.dtypes.float0)        # int arg: no gradient
    return (dq[:, :Sq].astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype), d_offset)


blockwise_attention.defvjp(_flash_fwd_vjp, _flash_bwd)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     attn_softcap: float = 0.0):
    """Single-token attention against a cache.

    q: (B, 1, Hq, D); caches: (B, S, Hkv, D); pos: scalar index of the new
    token (cache already contains it at ``pos``).
    """
    B, _, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    if attn_softcap:
        s = softcap(s, attn_softcap)
    kv_pos = jnp.arange(S)
    mask = kv_pos <= pos
    if window:
        mask &= kv_pos > (pos - window)
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------- sharded attention wrappers ---------------------- #

def sharded_flash_attention(mesh, q, k, v, *, window: int = 0,
                            attn_softcap: float = 0.0, rules=None):
    """shard_map'd flash attention; picks the TP strategy per shape.

    Strategy (with M = size of the model axis, when not already consumed by
    the batch rule — rule variants like pure-DP hand it to batch instead):
      A. Hkv %% M == 0            -> shard KV heads (q folds consistently)
      B. Hq %% M == 0 and each q-head shard maps to ONE kv head
                                  -> shard q heads, slice the kv head locally
                                     (dk/dv psum'd back via the slice VJP)
      C. otherwise                -> sequence-parallel q (each model shard
                                     owns a contiguous q chunk; k/v
                                     replicated; dk/dv psum over model)
    Batch shards over whatever axes the partition rules resolve for it.
    """
    from repro.sharding.partition import PartitionRules
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    rules = rules or PartitionRules()
    bres = tuple(rules.spec_for(("batch",), (B,), mesh))
    bspec = bres[0] if bres else None
    b_axes = (tuple(bspec) if isinstance(bspec, tuple)
              else ((bspec,) if bspec else ()))
    M = 1 if "model" in b_axes else mesh.shape.get("model", 1)
    zero = jnp.zeros((), jnp.int32)

    if M <= 1:
        strategy = "local"
    elif Hkv % M == 0:
        strategy = "kv_heads"
    elif Hq % M == 0 and G % (Hq // M) == 0:
        strategy = "q_heads"
    elif S % M == 0:
        strategy = "seq"
    else:
        strategy = "local"

    if strategy == "local" and bspec is None:
        return blockwise_attention(q, k, v, zero, True, window, attn_softcap)

    if strategy in ("local", "kv_heads"):
        hspec = "model" if strategy == "kv_heads" else None
        fn = shard_map(
            lambda q_, k_, v_: blockwise_attention(
                q_, k_, v_, zero, True, window, attn_softcap),
            mesh=mesh,
            in_specs=(P(bspec, None, hspec, None),) * 3,
            out_specs=P(bspec, None, hspec, None), check_vma=False)
        return fn(q, k, v)

    if strategy == "q_heads":
        Hq_l = Hq // M

        def local(q_, k_, v_):
            m = jax.lax.axis_index("model")
            kv_idx = (m * Hq_l) // G       # the single kv head this shard uses
            k1 = jax.lax.dynamic_slice_in_dim(k_, kv_idx, 1, axis=2)
            v1 = jax.lax.dynamic_slice_in_dim(v_, kv_idx, 1, axis=2)
            return blockwise_attention(q_, k1, v1, zero, True, window,
                                       attn_softcap)

        fn = shard_map(
            local, mesh=mesh,
            in_specs=(P(bspec, None, "model", None),
                      P(bspec, None, None, None), P(bspec, None, None, None)),
            out_specs=P(bspec, None, "model", None), check_vma=False)
        return fn(q, k, v)

    # strategy == "seq": sequence-parallel q chunks
    S_l = S // M

    def local(q_, k_, v_):
        off = jax.lax.axis_index("model") * S_l
        return blockwise_attention(q_, k_, v_, off, True, window,
                                   attn_softcap)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, "model", None, None),
                  P(bspec, None, None, None), P(bspec, None, None, None)),
        out_specs=P(bspec, "model", None, None), check_vma=False)
    return fn(q, k, v)


def sharded_decode_attention(mesh, q, k_cache, v_cache, kx, vx, pos, *,
                             window: int = 0, attn_softcap: float = 0.0,
                             rules=None):
    """shard_map'd single-token decode: writes (kx, vx) at ``pos`` then
    attends.  The strategy is DERIVED from the partition rules' resolution
    of the cache's logical axes ("batch","seq_kv","kv_heads","head_dim") —
    so rule-set variants (e.g. sharding the KV sequence on the model axis
    when KV heads don't divide it) propagate here automatically:

      - sharded seq dim  -> flash-style cross-shard merge (pmax/psum);
      - sharded head_dim -> psum over those axes for the scores.

    Returns (out (B,1,Hq,D), new_k_cache, new_v_cache).
    """
    from repro.sharding.partition import PartitionRules
    B, Sc, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    rules = rules or PartitionRules()
    spec = tuple(rules.spec_for(("batch", "seq_kv", "kv_heads", "head_dim"),
                                k_cache.shape, mesh))
    spec = spec + (None,) * (4 - len(spec))
    bspec, seqspec, hspec, dspec = spec
    scale = D ** -0.5

    seq_axes = (tuple(seqspec) if isinstance(seqspec, tuple)
                else ((seqspec,) if seqspec else ()))
    d_axes = (tuple(dspec) if isinstance(dspec, tuple)
              else ((dspec,) if dspec else ()))

    def local(q_, kc, vc, kx_, vx_, pos_):
        S_l = kc.shape[1]
        if seq_axes:
            off = jax.lax.axis_index(seq_axes) * S_l
        else:
            off = jnp.zeros((), jnp.int32)
        idx = pos_ - off
        owns = (idx >= 0) & (idx < S_l)
        idxc = jnp.clip(idx, 0, S_l - 1)
        kc = jnp.where(owns, jax.lax.dynamic_update_slice_in_dim(
            kc, kx_.astype(kc.dtype), idxc, 1), kc)
        vc = jnp.where(owns, jax.lax.dynamic_update_slice_in_dim(
            vc, vx_.astype(vc.dtype), idxc, 1), vc)
        Bl, _, Hkv_l, D_l = kc.shape
        qg = q_.reshape(Bl, Hkv_l, q_.shape[2] // Hkv_l, D_l)
        s = jnp.einsum("bhgd,bshd->bhgs", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        if d_axes:
            s = jax.lax.psum(s, d_axes)
        if attn_softcap:
            s = softcap(s, attn_softcap)
        kv_pos = off + jnp.arange(S_l)
        mask = kv_pos <= pos_
        if window:
            mask &= kv_pos > (pos_ - window)
        s = jnp.where(mask[None, None, None, :], s, NEG_INF)
        m_l = s.max(axis=-1)
        if seq_axes:
            m_g = jax.lax.pmax(m_l, seq_axes)
        else:
            m_g = m_l
        p = jnp.exp(s - m_g[..., None])
        l_l = p.sum(axis=-1)
        acc = jnp.einsum("bhgs,bshd->bhgd", p.astype(vc.dtype), vc)
        acc = acc.astype(jnp.float32)
        if seq_axes:
            l_g = jax.lax.psum(l_l, seq_axes)
            acc = jax.lax.psum(acc, seq_axes)
        else:
            l_g = l_l
        out = (acc / jnp.maximum(l_g[..., None], 1e-30)).astype(q_.dtype)
        return out.reshape(Bl, 1, q_.shape[2], D_l), kc, vc

    cache_spec = P(bspec, seqspec, hspec, dspec)
    new_spec = P(bspec, None, hspec, dspec)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(new_spec, cache_spec, cache_spec, new_spec, new_spec, P()),
        out_specs=(new_spec, cache_spec, cache_spec), check_vma=False)
    return fn(q, k_cache, v_cache, kx, vx, pos)


# ------------------------- full attention layer ------------------------ #

def attn_params_spec(cfg):
    d, Hq, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ((d, Hq, hd), ("embed_w", "heads", "head_dim")),
        "wk": ((d, Hkv, hd), ("embed_w", "kv_heads", "head_dim")),
        "wv": ((d, Hkv, hd), ("embed_w", "kv_heads", "head_dim")),
        "wo": ((Hq, hd, d), ("heads", "head_dim", "embed_w")),
    }


class AttnCache(NamedTuple):
    k: jnp.ndarray        # (B, S, Hkv, D)
    v: jnp.ndarray


def attention_layer(cfg, w, x, *, local: bool, sctx, positions=None,
                    cache: Optional[AttnCache] = None, pos=None,
                    use_pallas: bool = False):
    """Pre-norm attention mixer.  Returns (out, new_cache).

    Train/prefill: cache is None -> blockwise flash over x itself, and (for
    prefill) the produced K/V are returned as the new cache.
    Decode: cache given, x is (B, 1, D), ``pos`` scalar write index.
    """
    window = cfg.sliding_window if local else 0
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, w["wq"])
    kx = jnp.einsum("bsd,dhk->bshk", x, w["wk"])
    vx = jnp.einsum("bsd,dhk->bshk", x, w["wv"])
    if positions is None:
        positions = (jnp.arange(S) if pos is None else (pos + jnp.zeros((S,), jnp.int32)))
        positions = jnp.broadcast_to(positions, (B, S))
    q = apply_rope(q, positions, cfg.rope_theta)
    kx = apply_rope(kx, positions, cfg.rope_theta)
    q = sctx.act(q, ("batch", "seq", "heads", "head_dim"))

    if cache is None:
        if use_pallas:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, kx, vx, causal=True, window=window,
                                       attn_softcap=cfg.attn_softcap)
        elif sctx.mesh is not None:
            out = sharded_flash_attention(sctx.mesh, q, kx, vx, window=window,
                                          attn_softcap=cfg.attn_softcap,
                                          rules=sctx.rules)
        else:
            out = blockwise_attention(q, kx, vx, jnp.zeros((), jnp.int32),
                                      True, window, cfg.attn_softcap)
        new_cache = AttnCache(kx, vx)
    else:
        if sctx.mesh is not None:
            out, kc, vc = sharded_decode_attention(
                sctx.mesh, q, cache.k, cache.v, kx, vx, pos, window=window,
                attn_softcap=cfg.attn_softcap, rules=sctx.rules)
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache.k, kx.astype(cache.k.dtype), pos, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache.v, vx.astype(cache.v.dtype), pos, axis=1)
            out = decode_attention(q, kc, vc, pos, window=window,
                                   attn_softcap=cfg.attn_softcap)
        new_cache = AttnCache(kc, vc)
    out = jnp.einsum("bshk,hkd->bsd", out, w["wo"])
    return sctx.act(out, ("batch", "seq", None)), new_cache
