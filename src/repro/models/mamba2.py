"""Mamba2 (SSD — state-space duality) mixer: chunked train scan + O(1) decode.

Train/prefill uses the chunked SSD algorithm (arXiv:2405.21060 §6): the
sequence is split into chunks of length Q; within a chunk the contribution is
a (masked, decay-weighted) attention-like quadratic term; across chunks a
recurrence over per-chunk states (B,H,P,N) carries history.  This is the
pure-JAX oracle; ``repro.kernels.ssd`` provides the Pallas TPU kernel for the
intra-chunk term.

Decode keeps the SSM state (B,H,P,N) + a rolling conv window; each step is
O(1) in context length — this is what makes the 500k-context cells runnable.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from ..compat import shard_map


def mamba_params_spec(cfg):
    d, inner, nh, N = cfg.d_model, cfg.inner_dim, cfg.ssm_heads, cfg.ssm_state
    cw = cfg.conv_width
    return {
        "in_proj": ((d, 2 * inner + 2 * N + nh), ("embed_w", "ssm_inner")),
        "out_proj": ((inner, d), ("ssm_inner", "embed_w")),
        "conv_w": ((cw, inner + 2 * N), (None, "ssm_inner")),
        "A_log": ((nh,), ("ssm_heads",)),
        "D": ((nh,), ("ssm_heads",)),
        "dt_bias": ((nh,), ("ssm_heads",)),
    }


class MambaCache(NamedTuple):
    h: jnp.ndarray         # (B, H, P, N) ssm state
    conv: jnp.ndarray      # (B, conv_width-1, inner + 2N) rolling conv input


def _split_proj(cfg, zxbcdt):
    inner, N, nh = cfg.inner_dim, cfg.ssm_state, cfg.ssm_heads
    z, xBC, dt = jnp.split(zxbcdt, [inner, 2 * inner + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, conv_w, prev: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d. xBC: (B, S, C); conv_w: (W, C)."""
    W = conv_w.shape[0]
    if prev is None:
        prev = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[2]), xBC.dtype)
    xp = jnp.concatenate([prev, xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1], :] * conv_w[i][None, None, :]
              for i in range(W))
    return jax.nn.silu(out), xp[:, -(W - 1):, :]


def ssd_chunked(x, dt, A, B_, C_, chunk: int, use_pallas: bool = False,
                h0: Optional[jnp.ndarray] = None):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); A: (H,) (negative);
    B_, C_: (B, S, N).  Returns y: (B, S, H, P), final state (B, H, P, N).
    """
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.ssd(x, dt, A, B_, C_, chunk=chunk, h0=h0)
    from repro.kernels.ref import ssd_reference
    return ssd_reference(x, dt, A, B_, C_, chunk=chunk, h0=h0)


def sharded_ssd(mesh, x, dt, A, B_, C_, chunk: int, use_pallas: bool = False,
                rules=None):
    """shard_map'd SSD: batch per the partition rules, heads on model;
    fully local (the SSD recurrence has no cross-batch/head coupling)."""
    from jax.sharding import PartitionSpec as P

    from repro.sharding.partition import PartitionRules
    rules = rules or PartitionRules()
    B, S, H, _ = x.shape
    bres = tuple(rules.spec_for(("batch",), (B,), mesh))
    bspec = bres[0] if bres else None
    b_axes = (tuple(bspec) if isinstance(bspec, tuple)
              else ((bspec,) if bspec else ()))
    M = 1 if "model" in b_axes else mesh.shape.get("model", 1)
    hspec = "model" if (M > 1 and H % M == 0) else None
    if bspec is None and hspec is None:
        return ssd_chunked(x, dt, A, B_, C_, chunk, use_pallas)
    fn = shard_map(
        lambda x_, dt_, A_, b_, c_: ssd_chunked(x_, dt_, A_, b_, c_, chunk,
                                                use_pallas),
        mesh=mesh,
        in_specs=(P(bspec, None, hspec, None), P(bspec, None, hspec),
                  P(hspec), P(bspec, None, None), P(bspec, None, None)),
        out_specs=(P(bspec, None, hspec, None), P(bspec, hspec, None, None)),
        check_vma=False)
    return fn(x, dt, A, B_, C_)


def mamba_layer(cfg, w, x, *, sctx, cache: Optional[MambaCache] = None,
                use_pallas: bool = False):
    """Pre-norm Mamba2 mixer. x: (B, S, D). Returns (out, new_cache)."""
    B, S, D = x.shape
    inner, N, nh, P = cfg.inner_dim, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, w["in_proj"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + w["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(w["A_log"].astype(jnp.float32))             # (H,)

    if cache is None:
        xBC, conv_tail = _causal_conv(xBC, w["conv_w"])
        xs, B_, C_ = jnp.split(xBC, [inner, inner + N], axis=-1)
        xh = xs.reshape(B, S, nh, P)
        xh = sctx.act(xh, ("batch", "seq", "ssm_heads", None))
        if sctx.mesh is not None:
            y, hT = sharded_ssd(sctx.mesh, xh, dt, A, B_, C_, cfg.ssm_chunk,
                                use_pallas, rules=sctx.rules)
        else:
            y, hT = ssd_chunked(xh, dt, A, B_, C_, cfg.ssm_chunk, use_pallas)
        y = y + xh * w["D"].astype(y.dtype)[None, None, :, None]
        new_cache = MambaCache(hT.astype(jnp.float32), conv_tail)
    else:
        # single-token recurrence: h <- exp(dt*A) h + dt * (B outer x)
        xBC, conv_tail = _causal_conv(xBC, w["conv_w"], prev=cache.conv)
        xs, B_, C_ = jnp.split(xBC, [inner, inner + N], axis=-1)
        xh = xs.reshape(B, 1, nh, P)[:, 0]                    # (B, H, P)
        dt1 = dt[:, 0]                                        # (B, H)
        decay = jnp.exp(dt1 * A[None, :])                     # (B, H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt1, B_[:, 0].astype(jnp.float32),
                         xh.astype(jnp.float32))
        h = cache.h * decay[..., None, None] + dBx            # (B, H, P, N)
        y = jnp.einsum("bhpn,bn->bhp", h, C_[:, 0].astype(jnp.float32))
        y = y[:, None].astype(x.dtype)                        # (B, 1, H, P)
        y = y + xh[:, None] * w["D"].astype(y.dtype)[None, None, :, None]
        new_cache = MambaCache(h, conv_tail)

    y = y.reshape(B, S, inner)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, w["out_proj"])
    return sctx.act(out, ("batch", "seq", None)), new_cache
