"""Shared layer primitives: RMSNorm, RoPE, MLP, softcap, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, weight, eps: float = 1e-6):
    """RMSNorm with f32 *accumulation* but no full-tensor f32 materialization
    (the reduction accumulates in f32 via ``dtype=``; keeping x in bf16
    halves layer-boundary checkpoint traffic — see transformer.group_body)."""
    dt = x.dtype
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                   dtype=jnp.float32)
    scale = (jax.lax.rsqrt(var + eps)
             * (1.0 + weight.astype(jnp.float32))).astype(dt)
    return x * scale


def softcap(x, cap: float):
    """Gemma2-style logit soft capping."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ------------------------------ RoPE ---------------------------------- #
# Interleaved (even/odd pair) rotary embedding: pairs are adjacent in the
# head_dim axis, so sharding head_dim into even-sized chunks never splits a
# rotation pair (required when TP falls back to head_dim sharding).

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                          # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                   # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ------------------------------ MLP ----------------------------------- #

def mlp(x, w, gated: bool):
    """w: {'wi': (D,F), 'wg': (D,F) if gated, 'wo': (F,D)}."""
    h = jnp.einsum("...d,df->...f", x, w["wi"])
    if gated:
        g = jnp.einsum("...d,df->...f", x, w["wg"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, w["wo"])
