"""Sharded AdamW with dtype knobs, global-norm clipping, and LR schedule.

Optimizer state mirrors parameter sharding (it is built by tree-mapping over
params), so FSDP/TP placement extends to m/v for free.  ``state_dtype``
selects fp32 (default) or bf16 moments — the knob that lets the 398B-param
Jamba fit 16 GB/chip optimizer state on a single 256-chip pod (§Perf).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(1, warmup)
        prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclass(frozen=True)
class AdamW:
    lr: Callable = cosine_schedule(3e-4, 100, 10_000)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"

    def init(self, params) -> AdamState:
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(zeros, params),
                         jax.tree.map(zeros, params))

    def abstract_state(self, abstract_params) -> AdamState:
        dt = jnp.dtype(self.state_dtype)
        z = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
        return AdamState(jax.ShapeDtypeStruct((), jnp.dtype("int32")),
                         jax.tree.map(z, abstract_params),
                         jax.tree.map(z, abstract_params))

    def state_axes(self, axes_tree) -> AdamState:
        return AdamState((), axes_tree, axes_tree)

    def update(self, params, grads, state: AdamState):
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(gf)))
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        lr = self.lr(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        dt = jnp.dtype(self.state_dtype)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
            mhat = m32 / bc1
            vhat = v32 / bc2
            step_ = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:                       # decoupled decay on matrices
                step_ = step_ + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step_
            return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.m)
        flat_v = jax.tree.leaves(state.v)
        # Chain leaf updates through optimization_barrier: forces XLA to
        # schedule them sequentially, so peak temp = ONE leaf's f32
        # upcasts instead of all leaves' at once (matters at 100B+ params).
        out = []
        token = None
        for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
            if token is not None:
                p, g = jax.lax.optimization_barrier((p, g, token))[:2]
            o = upd(p, g, m, v)
            out.append(o)
            token = o[0]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return new_p, AdamState(step, new_m, new_v), gnorm
