from .adamw import AdamW, AdamState, cosine_schedule  # noqa
