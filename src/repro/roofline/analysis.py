"""Roofline analysis: collective parsing + three-term model.

``collective_census`` parses optimized (post-SPMD) HLO text and estimates the
bytes each chip moves over ICI per collective, using standard ring-algorithm
costs:

  all-gather(out B, groups of g):      each chip receives B*(g-1)/g
  reduce-scatter(out B, groups of g):  each chip moves   B*(g-1)   (operand = B*g)
  all-reduce(B, groups of g):          2*B*(g-1)/g  (RS + AG)
  all-to-all(B, groups of g):          B*(g-1)/g
  collective-permute(B):               B

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict, List

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per the assignment)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def collective_census(hlo_text: str) -> Dict:
    ops: Dict[str, Dict] = {}
    total_bytes = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # paired with -start; count once
        out_type, op = m.group(1), m.group(2)
        B = _shape_bytes(out_type)
        g = _group_size(line)
        if op == "all-gather":
            moved = B * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            moved = B * (g - 1)
        elif op == "all-reduce":
            moved = 2.0 * B * (g - 1) / max(g, 1)
        elif op == "all-to-all":
            moved = B * (g - 1) / max(g, 1)
        else:  # collective-permute
            moved = B
        d = ops.setdefault(op, {"count": 0, "out_bytes": 0, "moved_bytes": 0.0})
        d["count"] += 1
        d["out_bytes"] += B
        d["moved_bytes"] += moved
        total_bytes += moved
    return {"ops": ops, "moved_bytes_per_device": total_bytes}


def roofline_terms(artifact: Dict) -> Dict:
    """Three roofline terms (seconds) from a dry-run artifact.

    cost_analysis() is for the per-device partitioned module, so terms use
    per-chip peak rates directly.
    """
    flops_dev = artifact["cost"].get("flops_per_device") or 0.0
    bytes_dev = artifact["cost"].get("bytes_per_device") or 0.0
    coll_dev = artifact["collectives"]["moved_bytes_per_device"]
    n = artifact["n_chips"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    model_flops = artifact.get("model_flops_global") or 0.0
    hlo_flops_global = flops_dev * n
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0
    bound_s = max(compute_s, memory_s, collective_s)
    # achievable MFU if perfectly overlapped = useful flop-time / bound time
    mfu_bound = (model_flops / n / PEAK_FLOPS) / bound_s if bound_s else 0.0
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_over_hlo_flops": useful,
        "roofline_fraction": mfu_bound,
    }
