"""Trip-count-aware HLO cost model.

``xla::HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) counts a
``while`` body ONCE, so any scan-over-layers model under-reports FLOPs /
bytes / collectives by the layer count.  This walker parses the optimized
HLO text, builds the computation call graph (fusion ``calls=``, ``while``
``body=/condition=``, ``call to_apply=``), multiplies by
``known_trip_count`` (falling back to the loop-condition constant), and
accumulates:

  * ``flops``  — dot/convolution MXU FLOPs (2*M*N*K), trip-count scaled
  * ``bytes``  — fusion/op-level I/O bytes (a proxy for HBM traffic:
                 fusion internals stay in registers/VMEM)
  * ``collectives`` — census with ring-cost moved-bytes per chip

Unit-tested against hand-computable programs in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{\s*$")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_TYPE_PREFIX = re.compile(r"^((?:\([^=]*?\))|(?:[\w\[\],{}/ ]+?))\s+([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"?(\d+)"?')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_GROUPS = re.compile(r"replica_groups=\{\{([^}]*(?:\},\{[^}]*)*)\}\}")
_GROUPS_V2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        cur = None
        for line in text.splitlines():
            m = _COMP_START.match(line)
            if m and ("->" in line):
                cur = m.group(1)
                self.computations[cur] = []
                if line.startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                else:
                    self.computations[cur].append(line)
        # symbol table: op name -> result type string (per whole module; op
        # names are unique module-wide in optimized HLO)
        self.types: Dict[str, str] = {}
        for comp, lines in self.computations.items():
            for line in lines:
                om = _OP_LINE.match(line)
                if not om:
                    continue
                name, rest = om.group(1), om.group(2)
                tm = _TYPE_PREFIX.match(rest)
                if tm:
                    self.types[name] = tm.group(1)
        # parameter types from signatures are also needed
        for comp in self.computations:
            pass

    def sig_param_types(self, text_line: str):
        return None


def parse_hlo(text: str) -> HloModule:
    mod = HloModule(text)
    # parameter declarations inside computations: "%p = f32[..] parameter(0)"
    return mod


def _param_types_from_header(text: str, mod: HloModule):
    # computation headers carry "(name: type, name: type)" — add to table
    for header in re.finditer(
            r"^(?:ENTRY\s+)?%?[\w.\-]+\s+\(([^)]*(?:\([^)]*\)[^)]*)*)\)\s+->",
            text, re.M):
        body = header.group(1)
        # split on commas not inside brackets/parens
        depth = 0
        cur = ""
        parts = []
        for ch in body:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append(cur)
                cur = ""
            else:
                cur += ch
        if cur.strip():
            parts.append(cur)
        for p in parts:
            if ":" in p:
                nm, ty = p.split(":", 1)
                mod.types.setdefault(nm.strip().lstrip("%"), ty.strip())


def _trip_count(mod: HloModule, while_line: str, cond_name: str) -> int:
    m = _TRIP.search(while_line)
    if m:
        return int(m.group(1))
    # fallback: constant in the condition computation
    consts = []
    for line in mod.computations.get(cond_name, []):
        cm = re.search(r"constant\((\d+)\)", line)
        if cm:
            consts.append(int(cm.group(1)))
    return max(consts) if consts else 1


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2.search(line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def _dot_flops(mod: HloModule, rest: str) -> float:
    out_dims = _shape_dims(rest.split(" dot(")[0])
    cm = _LHS_CONTRACT.search(rest)
    contract = [int(d) for d in cm.group(1).split(",") if d] if cm else []
    args = rest[rest.index("dot(") + 4:]
    ops = _OPERANDS.findall(args.split(")")[0])
    lhs_type = mod.types.get(ops[0], "") if ops else ""
    lhs_dims = _shape_dims(lhs_type)
    k = 1
    for d in contract:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * k


def _conv_flops(mod: HloModule, rest: str) -> float:
    # 2 * out_elems * (kernel spatial * in_channels); approximate from the
    # rhs (kernel) operand total elements / out_channels
    out_dims = _shape_dims(rest.split(" convolution(")[0])
    args = rest[rest.index("convolution(") + 12:]
    ops = _OPERANDS.findall(args.split(")")[0])
    rhs_dims = _shape_dims(mod.types.get(ops[1], "")) if len(ops) > 1 else []
    n_out = 1
    for d in out_dims:
        n_out *= d
    k = 1
    for d in rhs_dims[:-1]:
        k *= d
    return 2.0 * n_out * k


def analyze(text: str, default_group: int = 1) -> Dict:
    mod = parse_hlo(text)
    _param_types_from_header(text, mod)

    def walk(comp: str, mult: float, in_fusion: bool, acc: Dict, seen):
        lines = mod.computations.get(comp, [])
        for line in lines:
            om = _OP_LINE.match(line)
            if not om:
                continue
            name, rest = om.group(1), om.group(2)
            if " dot(" in rest:
                acc["flops"] += _dot_flops(mod, rest) * mult
            elif " convolution(" in rest:
                acc["flops"] += _conv_flops(mod, rest) * mult
            # collectives
            for cop in COLLECTIVES:
                token = f" {cop}("
                token_s = f" {cop}-start("
                if token in rest or token_s in rest:
                    B = _shape_elems_bytes(mod.types.get(name, ""))
                    # -start results are tuples (operand, result); halve
                    if token_s in rest:
                        B = B / 2.0
                    g = _group_size(rest, default_group)
                    if cop == "all-gather":
                        moved = B * (g - 1) / max(g, 1)
                    elif cop == "reduce-scatter":
                        moved = B * (g - 1)
                    elif cop == "all-reduce":
                        moved = 2.0 * B * (g - 1) / max(g, 1)
                    elif cop == "all-to-all":
                        moved = B * (g - 1) / max(g, 1)
                    else:
                        moved = B
                    d = acc["collectives"].setdefault(
                        cop, {"count": 0.0, "moved_bytes": 0.0})
                    d["count"] += mult
                    d["moved_bytes"] += moved * mult
                    acc["coll_bytes"] += moved * mult
                    break
            # bytes: count op-level I/O when not inside a fusion body.
            # Skip plumbing ops (their "operands" are whole loop-carry
            # tuples) — they move no data.
            if not in_fusion:
                kind_m = re.search(r"\s([\w\-]+)\(", rest)
                kind = kind_m.group(1) if kind_m else ""
                if kind not in ("get-tuple-element", "tuple", "parameter",
                                "constant", "while", "conditional", "bitcast",
                                "after-all", "optimization-barrier"):
                    out_t = mod.types.get(name, "")
                    out_b = 0 if out_t.startswith("(") else \
                        _shape_elems_bytes(out_t)
                    in_b = 0
                    args_m = re.search(r"\(([^)]*)\)", rest)
                    if args_m:
                        for opn in _OPERANDS.findall(args_m.group(1)):
                            t = mod.types.get(opn, "")
                            if not t.startswith("("):
                                in_b += _shape_elems_bytes(t)
                    acc["bytes"] += (out_b + in_b) * mult
                    nm = re.search(r'op_name="([^"]*)"', rest)
                    if nm:
                        tail = nm.group(1).rsplit("/", 2)[-2:]
                        key = "/".join(t for t in tail if "->" in t) or tail[-1]
                    else:
                        key = kind
                    sc = acc["bytes_by_scope"]
                    sc[key] = sc.get(key, 0.0) + (out_b + in_b) * mult
            # recursion
            cm = _CALLS.search(rest)
            if cm and cm.group(1) not in seen:
                walk(cm.group(1), mult, True, acc, seen)
            bm = _BODY.search(rest)
            if bm:
                trip = _trip_count(mod, rest, (_COND.search(rest) or bm).group(1))
                walk(bm.group(1), mult * trip, in_fusion, acc, seen)
                condm = _COND.search(rest)
                if condm:
                    walk(condm.group(1), mult * trip, in_fusion, acc, seen)
            tm = _TO_APPLY.search(rest)
            if tm and " reduce(" not in rest and " reduce-window(" not in rest \
                    and " scatter(" not in rest and " sort(" not in rest \
                    and " map(" not in rest and " all-reduce" not in rest \
                    and " reduce-scatter" not in rest:
                walk(tm.group(1), mult, in_fusion, acc, seen)
            brm = _BRANCHES.search(rest)
            if brm:
                branches = [b.strip().lstrip("%") for b in
                            brm.group(1).split(",")]
                for b in branches:  # upper bound: all branches
                    walk(b, mult, in_fusion, acc, seen)
        return acc

    acc = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0, "collectives": {},
           "bytes_by_scope": {}}
    if mod.entry:
        walk(mod.entry, 1.0, False, acc, set())
    return acc
