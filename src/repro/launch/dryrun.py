import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  This module is the ONLY place the 512 placeholder
# devices exist; tests and benchmarks see the real device count.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON artifact with:
  - memory_analysis (bytes per device: argument/output/temp/peak)
  - cost_analysis   (per-device HLO FLOPs / bytes accessed)
  - collective op census + estimated bytes moved (parsed from optimized HLO)
  - analytic MODEL_FLOPS (6*N_active*D train, 2*N_active*D inference)
which EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline_report.py
consume.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config, get_shape, cells
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import transformer as T
from repro.optim import AdamW
from repro.roofline.analysis import roofline_terms
from repro.roofline.hlo_cost import analyze as hlo_analyze
from repro.sharding.partition import PartitionRules, ShardCtx

ART_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"


def _opt_for(cfg) -> AdamW:
    # >=100B params: bf16 moments so optimizer state fits 16 GB/chip HBM.
    big = cfg.param_count() >= 100e9
    return AdamW(state_dtype="bfloat16" if big else "float32")


def _grad_dtype_for(cfg) -> str:
    return "bfloat16" if cfg.param_count() >= 100e9 else "float32"


def build_cell(arch: str, shape_name: str, mesh, rules=None,
               cfg_overrides=None, mu_override=None):
    """Returns (fn, in_avals tuple, in_shardings tuple, out_shardings)."""
    import dataclasses
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = get_shape(shape_name)
    rules = rules or PartitionRules()
    sctx = ShardCtx(mesh, rules)

    p_aval = T.abstract_params(cfg)
    p_spec = T.param_pspecs(cfg, mesh, rules)
    p_sh = jax.tree.map(lambda s: jax.NamedSharding(mesh, s), p_spec)

    def shard_tree(axes_tree, aval_tree):
        specs = rules.tree_specs(axes_tree, aval_tree, mesh)
        return jax.tree.map(lambda s: jax.NamedSharding(mesh, s), specs)

    in_aval = M.input_specs(cfg, shape)
    in_sh = shard_tree(M.input_axes(cfg, shape), in_aval)
    repl = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())

    if shape.kind == "train":
        opt = _opt_for(cfg)
        o_aval = opt.abstract_state(p_aval)
        o_sh = type(o_aval)(repl, p_sh, p_sh)
        bspec = rules.spec_for(("batch",), (shape.global_batch,), mesh)
        n_batch_shards = 1
        for a in (jax.tree.leaves(tuple(bspec)) or []):
            n_batch_shards *= mesh.shape.get(a, 1)
        mu = (mu_override if mu_override else
              M.auto_microbatches(cfg, shape, n_batch_shards))
        # microbatching must not shrink the global batch below the number
        # of batch shards, or the partitioner replicates everything
        while mu > 1 and shape.global_batch // mu < n_batch_shards:
            mu //= 2
        fn = M.make_train_step(cfg, opt, sctx, microbatches=mu,
                               grad_dtype=_grad_dtype_for(cfg))
        fn.microbatches = mu
        avals = (p_aval, o_aval, in_aval)
        in_shardings = (p_sh, o_sh, in_sh)
        out_shardings = (p_sh, o_sh, repl)
    elif shape.kind == "prefill":
        fn = M.make_prefill_step(cfg, sctx)
        avals = (p_aval, in_aval)
        in_shardings = (p_sh, in_sh)
        cache_sh = shard_tree(T.cache_axes(cfg),
                              T.cache_specs(cfg, shape.global_batch,
                                            shape.seq_len, cfg.dtype))
        out_shardings = (repl, cache_sh)
    else:  # decode
        fn = M.make_decode_step(cfg, sctx)
        avals = (p_aval, in_aval["token"], in_aval["cache"], in_aval["pos"])
        in_shardings = (p_sh, in_sh["token"], in_sh["cache"], in_sh["pos"])
        out_shardings = (repl, in_sh["cache"])
    return cfg, shape, fn, avals, in_shardings, out_shardings


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             rules=None, cfg_overrides=None, tag: str = "", mu=None,
             mesh_shape=None):
    if mesh_shape:  # alternative carve of the same 256-chip pod (§Perf)
        mesh_name = f"pod{mesh_shape[0]}x{mesh_shape[1]}"
    else:
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{tag}" if tag else ""
    out_path = out_dir / mesh_name / f"{arch}__{shape_name}{suffix}.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    if mesh_shape:
        mesh = jax.make_mesh(tuple(mesh_shape), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    cfg, shape, fn, avals, in_sh, out_sh = build_cell(
        arch, shape_name, mesh, rules=rules, cfg_overrides=cfg_overrides,
        mu_override=mu)
    shape_cfg = SHAPES[shape_name]
    # donate params/opt_state (train) or the KV cache (decode): in-place
    # updates, halving peak residency — matches production deployment.
    donate = (0, 1) if shape_cfg.kind == "train" else (
        (2,) if shape_cfg.kind == "decode" else ())
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*avals)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        print(mem)     # proves it fits (bytes per device)
        print({k: v for k, v in cost.items()
               if k in ("flops", "bytes accessed")})
        hlo = compiled.as_text()

    acc = hlo_analyze(hlo)
    census = {"ops": acc["collectives"],
              "moved_bytes_per_device": acc["coll_bytes"]}
    n_chips = mesh.devices.size
    mem_d = {k: getattr(mem, k, None) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes")}
    # CPU backend ignores donation (outputs land in temp despite the alias
    # claim): args + temp - alias approximates the TPU peak where donated
    # params/opt/cache update in place.
    peak = (mem_d.get("argument_size_in_bytes") or 0) + \
           (mem_d.get("temp_size_in_bytes") or 0) - \
           (mem_d.get("alias_size_in_bytes") or 0)
    training = shape.kind == "train"
    model_flops = cfg.model_flops_per_token(training) * shape.tokens
    artifact = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "n_chips": int(n_chips),
        "kind": shape.kind,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem_d,
        "peak_bytes_per_device": peak,
        "fits_16GB": bool(peak < 16e9),
        "cost": {"flops_per_device": acc["flops"],
                 "bytes_per_device": acc["bytes"],
                 "bytes_by_scope": dict(sorted(
                     acc["bytes_by_scope"].items(),
                     key=lambda kv: -kv[1])[:60]),
                 "xla_flops_body_once": cost.get("flops"),
                 "xla_bytes_body_once": cost.get("bytes accessed")},
        "collectives": census,
        "model_flops_global": model_flops,
        "microbatches": getattr(fn, "microbatches", 1),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "tokens": shape.tokens,
    }
    artifact["roofline"] = roofline_terms(artifact)
    out_path.write_text(json.dumps(artifact, indent=1))
    print(f"[dryrun] {mesh_name} {arch} {shape_name}: OK "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s, "
          f"peak/device {peak/1e9:.2f} GB)")
    return artifact


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--out", default=str(ART_DIR))
    ap.add_argument("--tag", default="", help="variant tag for the artifact")
    ap.add_argument("--rules-json", default="",
                    help='partition-rule overrides, e.g. '
                         '\'{"batch": [["data","model"]]}\'')
    ap.add_argument("--cfg-json", default="",
                    help='ModelConfig overrides, e.g. '
                         '\'{"moe_dispatch": "gather"}\'')
    ap.add_argument("--mu", type=int, default=0,
                    help="override gradient-accumulation depth")
    ap.add_argument("--mesh-shape", type=int, nargs=2, default=None,
                    help="alternative (data, model) carve of the 256-chip "
                         "pod (perf exploration)")
    args = ap.parse_args(argv)
    out_dir = Path(args.out)
    rules = None
    if args.rules_json:
        overrides = {k: tuple(tuple(c) for c in v)
                     for k, v in json.loads(args.rules_json).items()}
        rules = PartitionRules(overrides)
    cfg_overrides = json.loads(args.cfg_json) if args.cfg_json else None

    todo = []
    if args.all:
        for arch in ARCHS:
            for shape_name, status in cells(arch):
                if status != "RUN":
                    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
                    p = out_dir / mesh_name / f"{arch}__{shape_name}.json"
                    p.parent.mkdir(parents=True, exist_ok=True)
                    p.write_text(json.dumps({
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": status}, indent=1))
                    continue
                todo.append((arch, shape_name))
    else:
        todo.append((args.arch, args.shape))

    failures = []
    for arch, shape_name in todo:
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        p = out_dir / mesh_name / f"{arch}__{shape_name}.json"
        if args.skip_done and p.exists():
            try:
                if json.loads(p.read_text()).get("status") == "ok":
                    continue
            except Exception:
                pass
        try:
            run_cell(arch, shape_name, args.multi_pod, out_dir,
                     rules=rules, cfg_overrides=cfg_overrides, tag=args.tag,
                     mu=args.mu or None, mesh_shape=args.mesh_shape)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape_name, repr(e)))
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(json.dumps({
                "arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "FAIL", "error": repr(e)[:2000]}, indent=1))
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:", file=sys.stderr)
        for f in failures:
            print("  ", f, file=sys.stderr)
        sys.exit(1)
    print("[dryrun] all cells OK")


if __name__ == "__main__":
    main()
