"""End-to-end training driver, workflow-managed.

The training job is expressed as an RPEX workflow (the paper's model): the
device pilot runs `train_segment` SPMD tasks (N optimizer steps each), while
single-slot Python tasks handle evaluation and checkpoint commits
concurrently — the heterogeneous-task mix of the Colmena use case, applied
to an LM pre-training job.

Fault tolerance: auto-resume from the newest checkpoint (params, optimizer
state, data cursor); ``--inject-failure`` kills a slot block mid-run to
exercise retry + reschedule.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --reduced --steps 200 --segment 20 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs import get_config, reduce_config
from repro.core import (DataFlowKernel, PilotDescription, RPEXExecutor,
                        python_app, spmd_app)
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.models import model as M
from repro.models import transformer as T
from repro.optim import AdamW, cosine_schedule
from repro.sharding.partition import PartitionRules, ShardCtx


def build_state(cfg, mesh, rules, seed=0):
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    opt = AdamW(lr=cosine_schedule(3e-4, 20, 10_000))
    opt_state = opt.init(params)
    if mesh is not None:
        pspecs = T.param_pspecs(cfg, mesh, rules)
        shard = lambda t, s: jax.device_put(t, jax.NamedSharding(mesh, s))
        params = jax.tree.map(shard, params, pspecs)
        opt_state = type(opt_state)(
            jax.device_put(opt_state.step),
            jax.tree.map(shard, opt_state.m, pspecs),
            jax.tree.map(shard, opt_state.v, pspecs))
    return params, opt, opt_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--segment", type=int, default=10,
                    help="steps per train_segment task")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--slots", type=int, default=0)
    ap.add_argument("--data-shards", type=int, default=1)
    ap.add_argument("--model-shards", type=int, default=1)
    ap.add_argument("--inject-failure", type=int, default=0,
                    help="kill this many slots mid-run (fault drill)")
    ap.add_argument("--resume", action="store_true", default=True)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    rules = PartitionRules()

    rpex = RPEXExecutor(PilotDescription(
        n_slots=args.slots or max(4, len(jax.devices()))))
    n_dev = len(jax.devices())
    use_mesh = args.data_shards * args.model_shards <= n_dev and \
        args.data_shards * args.model_shards > 1
    mesh = (jax.make_mesh((args.data_shards, args.model_shards),
                          ("data", "model")) if use_mesh else None)
    sctx = ShardCtx(mesh, rules)

    params, opt, opt_state = build_state(cfg, mesh, rules)
    ckpt = Checkpointer(args.ckpt_dir)
    loader_cursor = 0
    start_step = 0
    if args.resume and ckpt.latest_step() is not None:
        start_step, (params, opt_state, cursor_arr) = ckpt.restore(
            (params, opt_state, np.zeros((), np.int64)))
        loader_cursor = int(cursor_arr)
        print(f"[train] resumed from step {start_step} "
              f"(data cursor {loader_cursor})")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch,
                      frontend_tokens=cfg.frontend_tokens if
                      cfg.frontend == "vision_stub" else 0,
                      d_model=cfg.d_model)
    loader = ShardedLoader(dcfg, start_cursor=loader_cursor)

    step_fn = M.make_train_step(cfg, opt, sctx,
                                microbatches=args.microbatches)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    n_slots = rpex.pilot.n_slots
    seg_slots = max(1, n_slots - 2)      # leave slots for eval/ckpt helpers

    @spmd_app(slots=seg_slots, jit=False)
    def train_segment(task_mesh, params, opt_state, batches):
        # segment body drives the pre-jitted step; task_mesh is the carved
        # sub-mesh (the actual sharded mesh is managed by jit_step's specs)
        metrics = None
        for b in batches:
            params, opt_state, metrics = jit_step(params, opt_state, b)
        return params, opt_state, metrics

    @python_app
    def evaluate(params, batch):
        loss, _ = M.loss_fn(cfg, params, batch, sctx)
        return float(loss)

    @python_app
    def commit_checkpoint(step, params, opt_state, cursor):
        ckpt.save(step, (params, opt_state, np.int64(cursor)))
        return step

    t0 = time.time()
    losses = []
    with DataFlowKernel(executors={"rpex": rpex}, run_id=None) as dfk:
        step = start_step
        pending = []
        failed_injected = False
        while step < args.steps:
            n = min(args.segment, args.steps - step)
            batches = [jax.tree.map(jnp.asarray, next(loader))
                       for _ in range(n)]
            fut = train_segment(params, opt_state, batches)
            params, opt_state, metrics = fut.result()
            step += n
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
            if args.inject_failure and not failed_injected and \
                    step >= args.steps // 2:
                failed_injected = True
                victims = rpex.pilot.agent.inject_slot_failure(
                    list(range(args.inject_failure)))
                print(f"[train] injected failure on "
                      f"{args.inject_failure} slots (victims: {victims})")
            if step % args.ckpt_every == 0 or step >= args.steps or \
                    step % args.eval_every == 0:
                # host snapshot BEFORE the next segment donates these buffers
                snap_p = jax.tree.map(np.asarray, params)
            if step % args.ckpt_every == 0 or step >= args.steps:
                snap_o = jax.tree.map(np.asarray, opt_state)
                pending.append(commit_checkpoint(step, snap_p, snap_o,
                                                 loader.cursor))
            if step % args.eval_every == 0:
                eb = jax.tree.map(jnp.asarray, next(loader))
                pending.append(evaluate(snap_p, eb))
        for f in pending:
            f.result()
    loader.close()
    rpex.shutdown()
    if losses:
        print(f"[train] done: {step} steps, final loss {losses[-1]:.4f}, "
              f"first loss {losses[0]:.4f}")
    else:
        # resumed past --steps: every segment was skipped via checkpoint
        print(f"[train] done: already at step {step}, nothing to run")
    return losses


if __name__ == "__main__":
    main()
