"""Batched serving driver: prefill + decode with continuous batching.

Requests arrive with different prompt lengths and generation budgets; the
server packs them into a fixed-slot decode batch (a slot frees as soon as
its sequence finishes and is refilled from the queue — continuous
batching).  Prefill tasks and the decode loop are pilot tasks, so serving
shares the runtime (and its fault handling) with training.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --requests 12 --batch-slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import PilotDescription, RPEXExecutor
from repro.models import model as M
from repro.models import transformer as T
from repro.sharding.partition import NULL_CTX


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-ctx", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    rng = np.random.default_rng(args.seed)
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))

    B = args.batch_slots
    decode = jax.jit(M.make_decode_step(cfg), donate_argnums=(2,))

    # request queue: (prompt tokens, n_new)
    reqs = [(rng.integers(2, cfg.vocab_size,
                          size=rng.integers(4, args.max_ctx // 2)),
             int(rng.integers(2, args.max_new))) for _ in range(args.requests)]

    cache = T.init_cache(cfg, B, args.max_ctx, cfg.dtype)
    active = [None] * B            # (req_id, pos, remaining) per slot
    outputs = {i: [] for i in range(len(reqs))}
    queue = list(enumerate(reqs))
    cur_tok = np.zeros((B, 1), np.int32)
    pos_per_slot = np.zeros(B, np.int32)

    t0 = time.time()
    steps = 0
    # NOTE: per-slot positions differ; this simple server decodes slots in
    # lockstep with per-slot masking via separate decode calls per distinct
    # pos would be wasteful — instead we prefill each new request token by
    # token ("prefill-as-decode"), which keeps a single (B,1) decode shape.
    while queue or any(a is not None for a in active):
        for s in range(B):
            if active[s] is None and queue:
                rid, (prompt, n_new) = queue.pop(0)
                active[s] = [rid, 0, n_new, list(prompt), []]
                pos_per_slot[s] = 0
        for s in range(B):
            if active[s] is None:
                cur_tok[s, 0] = 0
                continue
            rid, pos, n_new, prompt, gen = active[s]
            cur_tok[s, 0] = (prompt[pos] if pos < len(prompt)
                             else (gen[-1] if gen else 1))
        # single fused decode step for the batch (per-slot pos = min active)
        pos_scalar = int(min([a[1] for a in active if a is not None] or [0]))
        logits, cache = decode(params, jnp.asarray(cur_tok), cache,
                               jnp.int32(pos_scalar))
        steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s in range(B):
            if active[s] is None:
                continue
            a = active[s]
            a[1] += 1
            if a[1] >= len(a[3]):                 # past prefill: generating
                a[4].append(int(nxt[s]))
            if len(a[4]) >= a[2] or a[1] >= args.max_ctx - 1:
                outputs[a[0]] = a[4]
                active[s] = None                  # slot freed -> refilled
    dt = time.time() - t0
    done = sum(1 for v in outputs.values() if v is not None)
    print(f"[serve] {done}/{len(reqs)} requests, {steps} decode steps, "
          f"{steps*B/dt:.1f} tok-slots/s, {dt:.1f}s")
    for i in sorted(outputs)[:4]:
        print(f"  req {i}: {outputs[i][:8]}")
    return outputs


if __name__ == "__main__":
    main()
