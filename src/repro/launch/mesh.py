"""Production mesh construction.

A *function*, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax import;
everything else must keep seeing the real device count).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading pod axis (2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Mesh over whatever devices exist (smoke tests, CPU trainer)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return jax.make_mesh((data, model), ("data", "model"))
