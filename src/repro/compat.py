"""Version-compatibility shims for the jax API surface this repo uses.

``jax.shard_map`` was promoted out of ``jax.experimental`` only in newer
jax releases, and its replication-check kwarg was renamed ``check_rep`` ->
``check_vma`` along the way.  Import ``shard_map`` from here so the same
code (written against the new spelling) runs on both sides of the
promotion.
"""
from __future__ import annotations

import inspect

import jax

try:
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    if "check_vma" in inspect.signature(_legacy_shard_map).parameters:
        shard_map = _legacy_shard_map
    else:
        def shard_map(f, *args, check_vma=None, **kwargs):
            if check_vma is not None:
                kwargs.setdefault("check_rep", check_vma)
            return _legacy_shard_map(f, *args, **kwargs)

__all__ = ["shard_map"]
