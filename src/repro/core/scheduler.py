"""Device-slot scheduler — the RP Agent scheduler analog.

Slots are TPU chips in the pilot's device grid, identified 0..N-1 in mesh
order.  Allocation is *contiguous + power-of-2 aligned* first-fit: a
contiguous aligned range of the flattened mesh corresponds to a rectangular
TPU sub-slice with intact ICI neighborhoods (the analogue of giving each MPI
Intra-communicator a compact node set), and alignment prevents the
fragmentation that would otherwise strand capacity under churn.

Invariants (property-tested in tests/test_scheduler.py):
  * an allocated slot is never allocated to a second task until released
  * allocations never include failed or shrunk-away slots
  * allocate(n) returns exactly n contiguous slots aligned to 2^ceil(log2 n)
    (for power-of-2 n) or None
  * release() makes slots reusable; fragmentation never loses capacity
    (any request <= largest aligned free block succeeds)
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Set, Tuple


def _align_of(n: int) -> int:
    a = 1
    while a < n:
        a *= 2
    return a


class SlotScheduler:
    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self._lock = threading.Lock()
        self.capacity = n_slots          # includes busy, excludes failed
        self._extent = n_slots           # highest slot id ever + 1
        self._free: Set[int] = set(range(n_slots))
        self._failed: Set[int] = set()
        self._busy: Dict[str, Tuple[int, ...]] = {}   # uid -> slots

    # ------------------------------ alloc ------------------------------ #
    def allocate(self, uid: str, n: int) -> Optional[Tuple[int, ...]]:
        """Contiguous aligned first-fit; returns slot ids or None."""
        if n < 1:
            raise ValueError("n >= 1")
        align = _align_of(n)
        with self._lock:
            if uid in self._busy:
                raise KeyError(f"{uid} already holds an allocation")
            start = 0
            while start + n <= self._extent:
                block = range(start, start + n)
                if all(s in self._free for s in block):
                    slots = tuple(block)
                    self._free.difference_update(slots)
                    self._busy[uid] = slots
                    return slots
                start += align
            return None

    def release(self, uid: str):
        with self._lock:
            slots = self._busy.pop(uid, ())
            for s in slots:
                if s not in self._failed and s < self._extent:
                    self._free.add(s)

    def owner_of(self, slot: int) -> Optional[str]:
        with self._lock:
            for uid, slots in self._busy.items():
                if slot in slots:
                    return uid
            return None

    # ------------------------------ fault ------------------------------ #
    def mark_failed(self, slots) -> List[str]:
        """Remove slots from service; returns uids of tasks running on them
        (the agent must fail/retry those tasks)."""
        with self._lock:
            victims = []
            for s in slots:
                if s in self._failed:
                    continue
                self._failed.add(s)
                if s in self._free:
                    self._free.discard(s)
                    self.capacity -= 1
                else:
                    for uid, held in self._busy.items():
                        if s in held and uid not in victims:
                            victims.append(uid)
                    self.capacity -= 1
            return victims

    # ----------------------------- elastic ----------------------------- #
    def grow(self, n: int) -> Tuple[int, ...]:
        with self._lock:
            new = tuple(range(self._extent, self._extent + n))
            self._free.update(new)
            self._extent += n
            self.capacity += n
            return new

    def shrink(self, n: int) -> Tuple[int, ...]:
        """Retire up to n FREE slots (never preempts running tasks)."""
        with self._lock:
            victims = sorted(self._free, reverse=True)[:n]
            for s in victims:
                self._free.discard(s)
                self._failed.add(s)     # retired == out of service
                self.capacity -= 1
            return tuple(victims)

    # ------------------------------ stats ------------------------------ #
    @property
    def n_free(self) -> int:
        with self._lock:
            return len(self._free)

    @property
    def n_busy(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._busy.values())

    def utilization(self) -> float:
        with self._lock:
            total = len(self._free) + sum(len(v) for v in self._busy.values())
            return (sum(len(v) for v in self._busy.values()) / total
                    if total else 0.0)
