"""Device-slot scheduler — the RP Agent scheduler analog.

Slots are TPU chips in the pilot's device grid, identified 0..N-1 in mesh
order.  Allocation is *contiguous + power-of-2 aligned* first-fit: a
contiguous aligned range of the flattened mesh corresponds to a rectangular
TPU sub-slice with intact ICI neighborhoods (the analogue of giving each MPI
Intra-communicator a compact node set), and alignment prevents the
fragmentation that would otherwise strand capacity under churn.

The free set is kept as a sorted list of disjoint, coalesced [start, end)
intervals (a buddy-style free-block list) instead of a flat slot set, so an
allocation probe touches O(#blocks) aligned candidates rather than scanning
every slot of the extent — the difference between O(blocks) and O(extent)
per allocate under the churn the agent loop generates.

The scheduler is also the wakeup source for the event-driven agent loop:
``add_listener`` registers a callback fired (outside the scheduler lock)
whenever capacity may have increased — release or grow — so a blocked
scheduling pass waits on its condition variable instead of polling.

Invariants (property-tested in tests/test_scheduler.py):
  * an allocated slot is never allocated to a second task until released
  * allocations never include failed or shrunk-away slots
  * allocate(n) returns exactly n contiguous slots aligned to 2^ceil(log2 n)
    (for power-of-2 n) or None
  * release() makes slots reusable; fragmentation never loses capacity
    (any request <= largest aligned free block succeeds)
"""
from __future__ import annotations

import bisect
import threading
from typing import Callable, Dict, List, Optional, Tuple


def _align_of(n: int) -> int:
    a = 1
    while a < n:
        a *= 2
    return a


class SlotScheduler:
    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self._lock = threading.Lock()
        self.capacity = n_slots          # includes busy, excludes failed
        self._extent = n_slots           # highest slot id ever + 1
        self._blocks: List[List[int]] = [[0, n_slots]]  # sorted [start, end)
        self._failed: set = set()
        self._busy: Dict[str, Tuple[int, ...]] = {}   # uid -> slots
        self._listeners: List[Callable[[], None]] = []

    # ---------------------------- listeners ---------------------------- #
    def add_listener(self, cb: Callable[[], None]):
        """Register a capacity-increase callback (release/grow).  Fired
        outside the scheduler lock so listeners may take their own locks."""
        with self._lock:
            self._listeners.append(cb)

    def _notify(self):
        for cb in list(self._listeners):
            cb()

    # --------------------------- free blocks --------------------------- #
    def _insert_free(self, start: int, end: int):
        """Insert [start, end) into the block list, coalescing neighbors.
        Caller holds the lock."""
        if start >= end:
            return
        i = bisect.bisect_left(self._blocks, [start, end])
        # merge with predecessor
        if i > 0 and self._blocks[i - 1][1] == start:
            i -= 1
            self._blocks[i][1] = end
        else:
            self._blocks.insert(i, [start, end])
        # merge with successor
        if i + 1 < len(self._blocks) and self._blocks[i][1] == \
                self._blocks[i + 1][0]:
            self._blocks[i][1] = self._blocks[i + 1][1]
            del self._blocks[i + 1]

    def _carve(self, i: int, start: int, end: int):
        """Remove [start, end) from block i.  Caller holds the lock."""
        b0, b1 = self._blocks[i]
        repl = []
        if b0 < start:
            repl.append([b0, start])
        if end < b1:
            repl.append([end, b1])
        self._blocks[i:i + 1] = repl

    def _remove_free_slot(self, s: int) -> bool:
        """Drop a single free slot; True if it was free.  Caller holds
        the lock."""
        i = bisect.bisect_right(self._blocks, [s, float("inf")]) - 1
        if i >= 0 and self._blocks[i][0] <= s < self._blocks[i][1]:
            self._carve(i, s, s + 1)
            return True
        return False

    # ------------------------------ alloc ------------------------------ #
    def allocate(self, uid: str, n: int) -> Optional[Tuple[int, ...]]:
        """Contiguous aligned first-fit over free blocks; slot ids or None."""
        if n < 1:
            raise ValueError("n >= 1")
        align = _align_of(n)
        with self._lock:
            if uid in self._busy:
                raise KeyError(f"{uid} already holds an allocation")
            for i, (b0, b1) in enumerate(self._blocks):
                start = -(-b0 // align) * align     # first aligned start
                if start + n <= b1:
                    slots = tuple(range(start, start + n))
                    self._carve(i, start, start + n)
                    self._busy[uid] = slots
                    return slots
            return None

    def largest_free_block(self) -> int:
        """Largest aligned request guaranteed to succeed right now."""
        with self._lock:
            best = 0
            for b0, b1 in self._blocks:
                a = 1
                while True:
                    start = -(-b0 // a) * a
                    if start + a > b1:
                        break
                    best = max(best, a)
                    a *= 2
            return best

    def release(self, uid: str):
        with self._lock:
            slots = self._busy.pop(uid, ())
            freed = False
            run_start = None
            prev = None
            for s in list(slots) + [None]:      # sentinel flushes last run
                ok = (s is not None and s not in self._failed
                      and s < self._extent)
                if ok and run_start is None:
                    run_start = s
                elif not ok and run_start is not None:
                    self._insert_free(run_start, prev + 1)
                    freed = True
                    run_start = None
                prev = s
        if freed:
            self._notify()

    def owner_of(self, slot: int) -> Optional[str]:
        with self._lock:
            for uid, slots in self._busy.items():
                if slot in slots:
                    return uid
            return None

    # ------------------------------ fault ------------------------------ #
    def mark_failed(self, slots) -> List[str]:
        """Remove slots from service; returns uids of tasks running on them
        (the agent must fail/retry those tasks)."""
        with self._lock:
            victims = []
            for s in slots:
                if s in self._failed or not (0 <= s < self._extent):
                    # already failed, or never part of the extent: counting
                    # a nonexistent slot would corrupt capacity, and
                    # poisoning _failed with ids beyond the extent would
                    # break a later grow() that reuses them
                    continue
                self._failed.add(s)
                if self._remove_free_slot(s):
                    self.capacity -= 1
                else:
                    for uid, held in self._busy.items():
                        if s in held and uid not in victims:
                            victims.append(uid)
                    self.capacity -= 1
            return victims

    # ----------------------------- elastic ----------------------------- #
    def grow(self, n: int) -> Tuple[int, ...]:
        with self._lock:
            new = tuple(range(self._extent, self._extent + n))
            self._insert_free(self._extent, self._extent + n)
            self._extent += n
            self.capacity += n
        self._notify()
        return new

    def shrink(self, n: int) -> Tuple[int, ...]:
        """Retire up to n FREE slots (never preempts running tasks),
        highest slot ids first."""
        with self._lock:
            victims = []
            for b in reversed(self._blocks):
                while len(victims) < n and b[1] > b[0]:
                    b[1] -= 1
                    victims.append(b[1])
                if len(victims) >= n:
                    break
            self._blocks = [b for b in self._blocks if b[1] > b[0]]
            for s in victims:
                self._failed.add(s)     # retired == out of service
                self.capacity -= 1
            return tuple(victims)

    # ------------------------------ stats ------------------------------ #
    def free_blocks(self) -> List[Tuple[int, int]]:
        """Snapshot of the free interval list — invariant: sorted,
        disjoint, coalesced (no two adjacent blocks touch)."""
        with self._lock:
            return [(b0, b1) for b0, b1 in self._blocks]

    @property
    def n_free(self) -> int:
        with self._lock:
            return sum(b1 - b0 for b0, b1 in self._blocks)

    @property
    def n_busy(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._busy.values())

    def utilization(self) -> float:
        with self._lock:
            busy = sum(len(v) for v in self._busy.values())
            total = sum(b1 - b0 for b0, b1 in self._blocks) + busy
            return busy / total if total else 0.0
