"""StateStore — the MongoDB analog: journaled task/pilot state.

RP uses a MongoDB instance to share state between client-side managers and
the agent; in a single-controller JAX deployment the equivalent is an
in-process store with a JSON-lines journal on disk.  The journal gives the
workflow layer crash-consistent restart: a restarted DFK replays DONE tasks
(futures resolve immediately from recorded results when re-submitted with
the same workflow key) and resubmits in-flight ones.

Beyond the per-task latest-state map, the store keeps a *unified event
stream*: every task transition and every runtime event (pilot start, route
decision, elastic resize) is appended as one timestamped record.  The
stream replaces the ad-hoc per-component timestamp dicts the runtime used
to keep — per-pilot utilization (the paper's Fig. 6 Scheduled/Launching/
Running/Idle breakdown) is integrated directly from it.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

from .futures import TaskRecord, TaskState

_RUN_STATES = ("SCHEDULED", "LAUNCHING", "RUNNING")
_END_STATES = ("DONE", "FAILED", "CANCELED")


class StateStore:
    def __init__(self, journal_path: Optional[str] = None):
        self.journal_path = Path(journal_path) if journal_path else None
        self._lock = threading.Lock()
        self.tasks: Dict[str, dict] = {}
        self.events: List[dict] = []        # unified, append-only stream
        self._listeners: List[Any] = []     # fired (outside the lock) on
                                            # every appended event
        self._fh = None
        if self.journal_path:
            self.journal_path.parent.mkdir(parents=True, exist_ok=True)
            if self.journal_path.exists():
                self._replay()
            self._fh = open(self.journal_path, "a", buffering=1)

    def _replay(self):
        with open(self.journal_path) as fh:
            for line in fh:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue        # torn tail write from a crash
                self.tasks[rec["uid"]] = rec

    # ------------------------------ events ------------------------------ #
    def add_listener(self, cb):
        """Register a callback fired (outside the store lock) with each
        appended event record — the PoolScaler's wake-up source."""
        with self._lock:
            self._listeners.append(cb)

    def _notify(self, rec: dict):
        for cb in list(self._listeners):
            cb(rec)

    def record_event(self, event: str, **fields):
        """Append a non-task runtime event (pilot start, routing, resize,
        steal, retire)."""
        rec = {"event": event, "t": time.monotonic(), **fields}
        with self._lock:
            self.events.append(rec)
        self._notify(rec)

    def record(self, task: TaskRecord, workflow_key: Optional[str] = None):
        rec = {
            "uid": task.uid,
            "key": workflow_key,
            "kind": task.kind,
            "state": task.state.value,
            "retries": task.retries,
            "slot_ids": list(task.slot_ids),
            "t": time.time(),
        }
        if task.pilot_uid is not None:
            rec["pilot"] = task.pilot_uid
        if task.state == TaskState.DONE and _jsonable(task.result):
            rec["result"] = task.result
        if task.error is not None:
            rec["error"] = repr(task.error)[:500]
        ev = {
            "event": "STATE", "uid": task.uid,
            "state": task.state.value, "t": time.monotonic(),
            "slots": len(task.slot_ids) or 1,
            "pilot": task.pilot_uid,
        }
        with self._lock:
            prev = self.tasks.get(task.uid, {})
            if "key" not in rec or rec["key"] is None:
                rec["key"] = prev.get("key")
            self.tasks[task.uid] = {**prev, **rec}
            self.events.append(ev)
            if self._fh:
                self._fh.write(json.dumps(self.tasks[task.uid]) + "\n")
        self._notify(ev)

    # ------------------------------ queries ----------------------------- #
    def completed_result(self, workflow_key: str):
        """(found, result) for a previously-DONE task with this key."""
        with self._lock:
            for rec in self.tasks.values():
                if rec.get("key") == workflow_key and \
                        rec.get("state") == TaskState.DONE.value and \
                        "result" in rec:
                    return True, rec["result"]
        return False, None

    def states(self) -> Dict[str, str]:
        with self._lock:
            return {uid: r.get("state", "?") for uid, r in self.tasks.items()}

    def timeline(self) -> Dict[str, Dict[str, float]]:
        """{uid: {state: monotonic_t}} reconstructed from the event stream
        (first occurrence of each state wins, matching TaskRecord stamps)."""
        out: Dict[str, Dict[str, float]] = {}
        with self._lock:
            for e in self.events:
                if e.get("event") != "STATE":
                    continue
                ts = out.setdefault(e["uid"], {})
                ts.setdefault(e["state"], e["t"])
        return out

    def utilization(self, capacity: int,
                    t0: Optional[float] = None,
                    t1: Optional[float] = None) -> Dict[str, float]:
        """Fig. 6 breakdown from the event stream: fraction of slot-seconds
        in Scheduled / Launching / Running / Idle over [t0, t1]."""
        slots: Dict[str, int] = {}
        with self._lock:
            events = [e for e in self.events if e.get("event") == "STATE"]
        for e in events:
            slots[e["uid"]] = max(slots.get(e["uid"], 1), e.get("slots", 1))
        tl = self.timeline()
        if not tl:
            return {"Scheduled": 0.0, "Launching": 0.0, "Running": 0.0,
                    "Idle": 1.0}
        all_t = [t for ts in tl.values() for t in ts.values()]
        t0 = t0 if t0 is not None else min(all_t)
        t1 = t1 if t1 is not None else max(all_t)
        occ = {"Scheduled": 0.0, "Launching": 0.0, "Running": 0.0}
        for uid, ts in tl.items():
            n = slots.get(uid, 1)
            if "SCHEDULED" in ts and "LAUNCHING" in ts:
                occ["Scheduled"] += n * (ts["LAUNCHING"] - ts["SCHEDULED"])
            if "LAUNCHING" in ts and "RUNNING" in ts:
                occ["Launching"] += n * (ts["RUNNING"] - ts["LAUNCHING"])
            # earliest terminal stamp: a retried task records FAILED before
            # its eventual DONE, and crediting through the requeue wait
            # would overcount Running
            ends = [ts[s] for s in _END_STATES if s in ts]
            if "RUNNING" in ts and ends:
                occ["Running"] += n * max(0.0, min(ends) - ts["RUNNING"])
        total = max(capacity * (t1 - t0), 1e-12)
        scale = min(1.0, total / max(sum(occ.values()), 1e-12))
        occ = {k: v * scale for k, v in occ.items()}
        out = {k: v / total for k, v in occ.items()}
        out["Idle"] = max(0.0, 1.0 - sum(out.values()))
        return out

    def close(self):
        # under the lock: a late task completion (e.g. one that outlived a
        # drain timeout) may be mid-record; after this, its journal write
        # is skipped (memory-only) instead of hitting a closed handle
        with self._lock:
            if self._fh:
                self._fh.close()
                self._fh = None


def _jsonable(x) -> bool:
    try:
        json.dumps(x)
        return True
    except (TypeError, ValueError):
        return False


def overhead_from_events(events: List[dict]) -> float:
    """RP overhead recomputed from the unified event stream: wall-clock
    seconds during which the runtime was placing or launching at least one
    task — the union (not the per-task sum) of every [SCHEDULED, RUNNING)
    interval observed in the stream.

    The per-task timestamp sum this replaces overcounts twice: concurrent
    launches are each charged full price even though they overlap in wall
    time, and a retried task's timestamps dict keeps only the last
    SCHEDULED/RUNNING pair, silently mixing attempts.  The event stream
    keeps every occurrence, so each attempt contributes its own interval
    and overlapping intervals are merged before integrating.  Slot-idle
    gaps between dependent tasks contribute nothing: no task is in
    SCHEDULED/LAUNCHING there, so no interval covers the gap.
    """
    opens: Dict[str, float] = {}            # uid -> t of pending SCHEDULED
    ivals: List[tuple] = []
    for e in sorted((e for e in events if e.get("event") == "STATE"),
                    key=lambda e: e["t"]):
        uid, state, t = e["uid"], e["state"], e["t"]
        if state == "SCHEDULED":
            opens[uid] = t
        elif state in ("RUNNING",) + _END_STATES and uid in opens:
            # RUNNING closes the overhead interval; a terminal state closes
            # it too for tasks that failed before ever running
            start = opens.pop(uid)
            if t > start:
                ivals.append((start, t))
    ivals.sort()
    total = 0.0
    cur_start: Optional[float] = None
    cur_end = 0.0
    for s, t in ivals:
        if cur_start is None or s > cur_end:
            if cur_start is not None:
                total += cur_end - cur_start
            cur_start, cur_end = s, t
        else:
            cur_end = max(cur_end, t)
    if cur_start is not None:
        total += cur_end - cur_start
    return total
