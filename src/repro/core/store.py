"""StateStore — the MongoDB analog: journaled task/pilot state.

RP uses a MongoDB instance to share state between client-side managers and
the agent; in a single-controller JAX deployment the equivalent is an
in-process store with a JSON-lines journal on disk.  The journal gives the
workflow layer crash-consistent restart: a restarted DFK replays DONE tasks
(futures resolve immediately from recorded results when re-submitted with
the same workflow key) and resubmits in-flight ones.

Beyond the per-task latest-state map, the store keeps a *unified event
stream*: every task transition and every runtime event (pilot start, route
decision, elastic resize) is appended as one timestamped record.  The
stream replaces the ad-hoc per-component timestamp dicts the runtime used
to keep — per-pilot utilization (the paper's Fig. 6 Scheduled/Launching/
Running/Idle breakdown) is integrated directly from it.

Since PR 3 the store is an *off-critical-path* subsystem:

  * Journal writes are write-behind with group commit: ``record`` appends
    the merged record to a bounded in-memory queue and returns; a
    background writer thread drains the queue, serializes the whole batch,
    and lands it with one ``write`` + one ``flush`` per drain cycle.
    ``close()`` drains the queue before closing the file, so a clean
    shutdown loses nothing; a hard crash loses at most the queue window,
    and the replay path tolerates a torn tail line either way.
  * ``completed_result`` is O(1): a ``workflow_key -> record`` index is
    maintained on append (and on replay) instead of scanning every record.
  * ``utilization()`` / ``timeline()`` / ``overhead()`` read counters that
    are maintained incrementally as events append, so PoolScaler wakeups
    and benchmark probes never re-integrate the full event stream.
  * Long elastic runs compact the journal in place (snapshot + tail): when
    the file holds many times more lines than live task records, the
    writer thread atomically rewrites it as one snapshot line per task
    plus a stats header — and a *bounded event tail*: the most recent
    STATE events ride along (marked ``tail``, wall-stamped for epoch
    re-anchoring) so recent per-task state timelines survive compaction.
    ``CHECKPOINT`` events (the task-checkpoint subsystem's save/gc
    markers, see checkpoint.py) collapse to one line per live key.
    Replay ingests tail events into the timeline only — their aggregate
    contribution already lives in the stats header, so counters never
    double-count.
  * A per-(app_kind, pilot) duration model rides the same incremental
    path: EWMA mean/variance of observed DONE run times folded in
    ``_ingest``, snapshotted into the compaction stats header, rebuilt on
    replay, and seedable cross-pilot by kind — the signal every
    cost-model scheduling decision reads (see docs/scheduling.md).
  * Restart rebuilds the event stream: every journal line carries a
    monotonic timestamp (``mt``), so ``_replay`` reconstructs the STATE
    events (and replays journaled runtime events) instead of dropping
    them — post-restart ``utilization()``/``rp_overhead()`` see the
    pre-restart history instead of silently undercounting.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from pathlib import Path
from typing import Any, Deque, Dict, List, Optional, Tuple

from . import serializer
from .futures import TaskRecord, TaskState
from .objectstore import ObjectRef

_RUN_STATES = ("SCHEDULED", "LAUNCHING", "RUNNING")
_END_STATES = ("DONE", "FAILED", "CANCELED")


class EVENTS:
    """Central registry of journaled event names — the single source of
    truth the event-protocol checker (``repro.analysis.events``) holds
    emitters, replay, compaction, and listeners against.

    Every name written into a journal ``{"event": ...}`` record must be
    declared here, and every emitter/consumer in ``src/repro/core`` and
    ``benchmarks`` must reference the registry constant rather than a
    string literal, so drift (emitted-but-never-replayed, consumed-but-
    never-emitted, undeclared) is mechanically checkable."""

    STATE = "STATE"                     # task state transition (record())
    SNAPSHOT = "_SNAPSHOT"              # compaction header line
    CHECKPOINT = "CHECKPOINT"           # task-checkpoint save/gc marker
    PILOT_START = "PILOT_START"         # pilot came up
    PILOT_RETIRE = "PILOT_RETIRE"       # pilot drained + retired
    PILOT_LOST = "PILOT_LOST"           # heartbeat/crash loss declared
    GROW = "GROW"                       # elastic resize: slots added
    SHRINK = "SHRINK"                   # elastic resize: slots removed
    ROUTED = "ROUTED"                   # pool routing decision
    STOLEN = "STOLEN"                   # work-stealing / re-route event
    QUARANTINED = "QUARANTINED"         # poison task terminally failed
    SHUTDOWN_STRANDED = "SHUTDOWN_STRANDED"   # hung tasks at shutdown
    OBJECTS_REHOSTED = "OBJECTS_REHOSTED"     # data-plane ownership move

    @classmethod
    def all_names(cls):
        return frozenset(v for k, v in vars(cls).items()
                         if isinstance(v, str) and not k.startswith("_"))

# Replay clock translation: journal stamps are time.monotonic(), whose
# epoch resets on reboot.  Each line also carries a wall stamp, so replay
# detects an epoch mismatch (boot offsets differing by more than this many
# seconds) and shifts old stamps into the current boot's monotonic domain.
_EPOCH_TOL_S = 600.0


class StateStore:
    def __init__(self, journal_path: Optional[str] = None,
                 max_queue: int = 8192,
                 compact_min_lines: int = 4096,
                 compact_factor: int = 4,
                 compact_tail_events: int = 256,
                 dur_alpha: float = 0.2):
        self.journal_path = Path(journal_path) if journal_path else None
        self.objectstore = None         # pool-wired data plane: DONE
                                        # records with ObjectRef results
                                        # journal ref metadata and spill
                                        # through it (docs/dataplane.md)
        self._lock = threading.Lock()
        self.tasks: Dict[str, dict] = {}
        self.events: List[dict] = []        # unified, append-only stream
        self._listeners: List[Any] = []     # fired (outside the lock) on
                                            # every appended event
        # key -> record index (O(1) completed_result); a DONE-with-result
        # record is never displaced by a later non-DONE record of another
        # uid, matching the old scan's "find any completed" semantics
        self._by_key: Dict[str, dict] = {}

        # ---- incremental counters (maintained on every STATE append) ----
        self._timeline: Dict[str, Dict[str, float]] = {}
        self._slots_max: Dict[str, int] = {}
        self._occ = {"Scheduled": 0.0, "Launching": 0.0, "Running": 0.0}
        self._ended: set = set()            # uids past their first terminal
        self._t_min: Optional[float] = None
        self._t_max: Optional[float] = None
        # streaming overhead union: wall-clock with >=1 task in
        # [SCHEDULED, RUNNING) — active-count sweep over the ordered stream
        self._oh_opens: Dict[str, float] = {}
        self._oh_active = 0
        self._oh_ustart = 0.0               # start of the current busy span
        self._oh_cur = 0.0                  # closed union inside that span
        self._oh_total = 0.0
        self._oh_seeded = 0.0               # pre-compaction overhead whose
                                            # intervals were snapshotted away
        self._oh_ivals: List[Tuple[float, float]] = []  # for cross-pilot union
        # ---- duration model (cost-model scheduling, see docs/scheduling.md)
        # kind -> [ewma_mean_s, ewma_var_s2, n_samples] of observed DONE run
        # times; folded in _ingest like the other counters, snapshotted into
        # the compaction stats header, and seedable cross-pilot by kind.
        self._dur: Dict[str, List[float]] = {}
        self._dur_open: Dict[str, float] = {}   # uid -> latest RUNNING t
        self._dur_alpha = dur_alpha

        # ---- write-behind journal ----
        self._fh = None
        self._wq: Deque[dict] = deque()
        self._wcv = threading.Condition()
        self._wstop = False
        self._wsleeping = False             # writer parked on its cv
        self._winflight = 0                 # records popped, not yet on disk
        self.journal_error: Optional[str] = None   # set when an I/O error
                                            # killed journaling (memory-only
                                            # operation continues)
        self._writer: Optional[threading.Thread] = None
        self._max_queue = max_queue
        self._compact_min_lines = compact_min_lines
        self._compact_factor = compact_factor
        self._compact_tail_events = compact_tail_events
        self._journal_lines = 0
        if self.journal_path:
            self.journal_path.parent.mkdir(parents=True, exist_ok=True)
            if self.journal_path.exists():
                self._replay()
            self._fh = open(self.journal_path, "a")
            self._writer = threading.Thread(target=self._writer_loop,
                                            daemon=True)
            self._writer.start()

    # ------------------------------ replay ------------------------------ #
    @staticmethod
    def _epoch_delta(wall: Optional[float], mono: float,
                     cur_off: float) -> float:
        """Shift (seconds) to translate a journaled monotonic stamp into
        the current boot's monotonic domain; 0.0 within the same boot."""
        if wall is None:
            return 0.0
        delta = (wall - mono) - cur_off
        return delta if abs(delta) > _EPOCH_TOL_S else 0.0

    def _replay(self):
        cur_off = time.time() - time.monotonic()
        with open(self.journal_path) as fh:
            for line in fh:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue        # torn tail write from a crash
                self._journal_lines += 1
                if rec.get("event") == EVENTS.SNAPSHOT:
                    stats = dict(rec.get("stats") or {})
                    snap_off = rec.get("mono_offset")
                    if snap_off is not None \
                            and abs(snap_off - cur_off) > _EPOCH_TOL_S:
                        for b in ("t_min", "t_max"):
                            if stats.get(b) is not None:
                                stats[b] += snap_off - cur_off
                    self._seed_stats(stats)
                    continue
                if "event" in rec:              # journaled runtime event
                    shift = self._epoch_delta(rec.get("wt"), rec["t"],
                                              cur_off)
                    if rec.pop("tail", None):
                        # bounded event tail from a compaction snapshot:
                        # restore the recent per-task state timeline, but
                        # timeline ONLY — these events' occ/overhead
                        # contribution is already in the stats header
                        ev = {k: v for k, v in rec.items() if k != "wt"}
                        ev["t"] += shift
                        self.events.append(ev)
                        self._ingest_timeline_only(ev)
                        continue
                    if shift:
                        rec = {**rec, "t": rec["t"] + shift}
                    self.events.append(rec)
                    continue
                if "uid" not in rec:
                    continue
                self.tasks[rec["uid"]] = rec
                self._index_key(rec)
                # rebuild the STATE stream: every journal line is one
                # transition, stamped with its monotonic time.  Snapshot
                # lines ("snap") are latest-state summaries whose history
                # was compacted away — their aggregate contribution is
                # carried by the _SNAPSHOT stats line instead.
                if "mt" in rec and not rec.get("snap"):
                    mt = rec["mt"] + self._epoch_delta(rec.get("t"),
                                                       rec["mt"], cur_off)
                    ev = {"event": EVENTS.STATE, "uid": rec["uid"],
                          "state": rec["state"], "t": mt,
                          "slots": len(rec.get("slot_ids") or ()) or 1,
                          "pilot": rec.get("pilot"),
                          "kind": rec.get("akind") or rec.get("kind")}
                    self.events.append(ev)
                    self._ingest(ev)

    def _seed_stats(self, stats: dict):
        """Restore aggregate counters from a compaction snapshot header."""
        for k, v in (stats.get("occ") or {}).items():
            if k in self._occ:
                self._occ[k] += float(v)
        self._oh_seeded += float(stats.get("oh_total", 0.0))
        for kind, (mean, var, n) in (stats.get("dur") or {}).items():
            self._dur_merge(kind, mean, var, n)
        for bound, pick in (("t_min", min), ("t_max", max)):
            v = stats.get(bound)
            if v is not None:
                cur = getattr(self, f"_{bound}")
                setattr(self, f"_{bound}",
                        float(v) if cur is None else pick(cur, float(v)))

    # ------------------------------ events ------------------------------ #
    def add_listener(self, cb):
        """Register a callback fired (outside the store lock) with each
        appended event record — the PoolScaler's wake-up source."""
        with self._lock:
            self._listeners.append(cb)

    def _notify(self, rec: dict):
        for cb in list(self._listeners):
            cb(rec)

    def record_event(self, event: str, **fields):
        """Append a non-task runtime event (pilot start, routing, resize,
        steal, retire).  Journaled (write-behind) so a restarted store
        sees the full runtime event history, not just task states; the
        wall stamp ("wt") lets replay re-anchor the monotonic stamp after
        a reboot."""
        rec = {"event": event, "t": time.monotonic(), "wt": time.time(),
               **fields}
        with self._lock:
            self.events.append(rec)
            if self._fh is not None:
                self._wq.append(rec)
        self._wake_writer()
        if self._listeners:
            self._notify(rec)

    def record(self, task: TaskRecord, workflow_key: Optional[str] = None):
        """Append one task transition.  The critical-path cost is a dict
        merge plus counter updates under the lock; serialization and disk
        I/O happen on the writer thread (group commit)."""
        rec = {
            "uid": task.uid,
            "key": workflow_key,
            "kind": task.kind,
            "state": task.state.value,
            "retries": task.retries,
            "slot_ids": list(task.slot_ids),
            "t": time.time(),
            "mt": time.monotonic(),
        }
        if task.pilot_uid is not None:
            rec["pilot"] = task.pilot_uid
        if task.app_kind and task.app_kind != task.kind:
            # the duration model keys on the *app* kind (bash apps execute
            # as kind "python" but their run times are a bash population)
            rec["akind"] = task.app_kind
        if task.state == TaskState.DONE:
            if isinstance(task.result, ObjectRef):
                # data plane: the line carries the ref *metadata* only;
                # the writer spills the payload (durable-before-event)
                # instead of re-serializing a large result through the
                # json probe — the old double-serialization path
                rec["result_ref"] = {"oid": task.result.oid,
                                     "size": task.result.size,
                                     "kind": task.result.kind}
            # journaled: jsonability is checked by the writer thread (the
            # dumps is the expensive part) which also unpins the result
            # from memory if it cannot be serialized.  Journal-less: no
            # writer will ever strip it, so gate synchronously (PR-2
            # behavior) rather than pin arbitrary result objects forever.
            elif self._fh is not None or _jsonable(task.result):
                rec["result"] = task.result
        if task.error is not None:
            rec["error"] = repr(task.error)[:500]
        if task.attempt_errors:
            # why each prior attempt failed (the retry path keeps the
            # history instead of wiping task.error): a FAILED record in
            # the journal shows all N attempts, matching the __cause__
            # chain the surfaced exception carries
            rec["attempt_errors"] = [repr(e)[:200]
                                     for e in task.attempt_errors]
        ev = {
            "event": EVENTS.STATE, "uid": task.uid,
            "state": task.state.value, "t": rec["mt"],
            "slots": len(task.slot_ids) or 1,
            "pilot": task.pilot_uid,
            "kind": task.app_kind or task.kind,
        }
        with self._lock:
            prev = self.tasks.get(task.uid)
            if prev:
                if rec.get("key") is None:
                    rec["key"] = prev.get("key")
                merged = {**prev, **rec}
            else:
                merged = rec
            self.tasks[task.uid] = merged
            self._index_key(merged)
            self.events.append(ev)
            self._ingest(ev)
            if self._fh is not None:
                self._wq.append(merged)
        self._wake_writer()
        if self._listeners:
            self._notify(ev)

    def _index_key(self, rec: dict):
        """Caller holds self._lock.  Latest record wins, except that a
        completed record (DONE with a result) is only displaced by another
        record of the *same* task — a later resubmission cannot hide an
        earlier completion, whether it never finished or finished with a
        result the writer later strips as non-serializable.  (The old
        linear scan returned the first completed record in insertion
        order, which is the same answer.)"""
        key = rec.get("key")
        if key is None:
            return
        cur = self._by_key.get(key)
        if (cur is not None and cur.get("uid") != rec.get("uid")
                and cur.get("state") == TaskState.DONE.value
                and ("result" in cur or "result_ref" in cur)):
            return
        self._by_key[key] = rec

    def _ingest_timeline_only(self, ev: dict):
        """Fold a compaction-tail STATE event into the per-task timeline
        (first occurrence wins) without touching the occ/overhead
        aggregates — those already carry it via the snapshot stats."""
        uid, state, t = ev["uid"], ev["state"], ev["t"]
        n = max(self._slots_max.get(uid, 1), ev.get("slots", 1))
        self._slots_max[uid] = n
        ts = self._timeline.setdefault(uid, {})
        if state not in ts:
            ts[state] = t

    # ----------------------- incremental counters ----------------------- #
    def _ingest(self, ev: dict):
        """Caller holds self._lock.  Fold one STATE event into the cached
        utilization / timeline / overhead counters.  Equivalent to the old
        full-stream recomputation because events arrive in time order and
        the old integration only ever used the *first* occurrence of each
        state per uid (and the earliest terminal stamp)."""
        uid, state, t = ev["uid"], ev["state"], ev["t"]
        self._t_min = t if self._t_min is None else min(self._t_min, t)
        self._t_max = t if self._t_max is None else max(self._t_max, t)
        n = max(self._slots_max.get(uid, 1), ev.get("slots", 1))
        self._slots_max[uid] = n
        ts = self._timeline.setdefault(uid, {})
        first = state not in ts
        if first:
            ts[state] = t
        if state == "LAUNCHING" and first and "SCHEDULED" in ts:
            self._occ["Scheduled"] += n * (t - ts["SCHEDULED"])
        elif state == "RUNNING" and first and "LAUNCHING" in ts:
            self._occ["Launching"] += n * (t - ts["LAUNCHING"])
        elif state in _END_STATES and uid not in self._ended:
            # earliest terminal stamp: a retried task records FAILED before
            # its eventual DONE, and crediting through the requeue wait
            # would overcount Running
            self._ended.add(uid)
            if "RUNNING" in ts:
                self._occ["Running"] += n * max(0.0, t - ts["RUNNING"])
        # duration model: one sample per successful completion, measured
        # from the *latest* RUNNING stamp (a retried task's requeue wait
        # must not inflate its run time).  FAILED/CANCELED leave no sample.
        if state == "RUNNING":
            self._dur_open[uid] = t
        elif state in _END_STATES:
            start = self._dur_open.pop(uid, None)
            if state == "DONE" and start is not None:
                self._dur_update(ev.get("kind") or "?", max(0.0, t - start))
        # streaming overhead union (see overhead())
        if state == "SCHEDULED":
            if uid not in self._oh_opens:
                self._oh_opens[uid] = t
                if self._oh_active == 0:
                    self._oh_ustart = t
                    self._oh_cur = 0.0
                self._oh_active += 1
        elif state in ("RUNNING",) + _END_STATES and uid in self._oh_opens:
            start = self._oh_opens.pop(uid)
            if t > start:
                self._oh_ivals.append((start, t))
            self._oh_active -= 1
            if self._oh_active == 0:
                self._oh_total += t - self._oh_ustart
                self._oh_cur = 0.0
            else:
                self._oh_cur = t - self._oh_ustart

    # ------------------------------ queries ----------------------------- #
    def completed_result(self, workflow_key: str):
        """(found, result) for a previously-DONE task with this key.
        O(1): one indexed lookup, no record scan.  A record completed
        through the data plane carries ``result_ref`` metadata instead of
        an inline value: the payload re-materializes from the object
        store's spill (the replay/restart path, docs/dataplane.md)."""
        ref = None
        with self._lock:
            rec = self._by_key.get(workflow_key)
            if rec is not None and \
                    rec.get("state") == TaskState.DONE.value:
                if "result" in rec:
                    return True, rec["result"]
                ref = rec.get("result_ref")
        if ref is not None and self.objectstore is not None:
            try:                        # client-side read: uncounted bytes
                return True, self.objectstore.get(ref["oid"])
            except (KeyError, OSError):
                pass                    # spill lost: treat as not found —
                                        # the task re-executes
        return False, None

    def states(self) -> Dict[str, str]:
        with self._lock:
            return {uid: r.get("state", "?") for uid, r in self.tasks.items()}

    def events_snapshot(self) -> List[dict]:
        """Consistent copy of the unified event stream."""
        with self._lock:
            return list(self.events)

    def timeline(self) -> Dict[str, Dict[str, float]]:
        """{uid: {state: monotonic_t}} — first occurrence of each state
        wins, matching TaskRecord stamps.  Served from the incrementally
        maintained cache (no event-stream scan)."""
        with self._lock:
            return {uid: dict(ts) for uid, ts in self._timeline.items()}

    def utilization(self, capacity: int,
                    t0: Optional[float] = None,
                    t1: Optional[float] = None) -> Dict[str, float]:
        """Fig. 6 breakdown: fraction of slot-seconds in Scheduled /
        Launching / Running / Idle over [t0, t1].  Reads the cached
        integrals — O(1) in the number of events."""
        with self._lock:
            occ = dict(self._occ)
            lo, hi = self._t_min, self._t_max
        if lo is None:
            return {"Scheduled": 0.0, "Launching": 0.0, "Running": 0.0,
                    "Idle": 1.0}
        t0 = t0 if t0 is not None else lo
        t1 = t1 if t1 is not None else hi
        total = max(capacity * (t1 - t0), 1e-12)
        scale = min(1.0, total / max(sum(occ.values()), 1e-12))
        occ = {k: v * scale for k, v in occ.items()}
        out = {k: v / total for k, v in occ.items()}
        out["Idle"] = max(0.0, 1.0 - sum(out.values()))
        return out

    def overhead(self) -> float:
        """RP overhead (this store only): wall-clock union of
        [SCHEDULED, RUNNING) intervals, maintained incrementally, plus
        any pre-compaction overhead carried by a snapshot header."""
        with self._lock:
            return self._oh_seeded + self._oh_total + self._oh_cur

    def overhead_base(self) -> float:
        """Overhead accumulated before the last journal compaction: its
        intervals were snapshotted away, only the integral survives."""
        with self._lock:
            return self._oh_seeded

    def overhead_intervals(self) -> List[Tuple[float, float]]:
        """Closed [SCHEDULED, RUNNING) intervals for cross-pilot union
        (see RPEXExecutor.rp_overhead) — one per launch attempt, so the
        multi-pilot merge unions O(tasks) intervals instead of re-deriving
        them from O(events) stream records."""
        with self._lock:
            return list(self._oh_ivals)

    # --------------------------- duration model -------------------------- #
    def _dur_update(self, kind: str, x: float):
        """Caller holds self._lock.  Fold one observed run time (seconds)
        into the per-kind EWMA mean/variance — West's exponentially
        weighted recurrence, so stale history decays instead of pinning
        the mean forever like a plain average would."""
        m = self._dur.get(kind)
        if m is None:
            self._dur[kind] = [x, 0.0, 1]
            return
        a = self._dur_alpha
        d = x - m[0]
        incr = a * d
        m[0] += incr
        m[1] = (1.0 - a) * (m[1] + d * incr)
        m[2] += 1

    def _dur_merge(self, kind: str, mean: float, var: float, n: int):
        """Caller holds self._lock (or is single-threaded replay).  Merge
        an external (mean, var, n) summary — compaction-header seeding and
        cross-pilot seeding both land here.  n-weighted moment pooling:
        the combined variance keeps the between-source spread."""
        n = int(n)
        if n <= 0:
            return
        cur = self._dur.get(kind)
        if cur is None or cur[2] <= 0:
            self._dur[kind] = [float(mean), float(var), n]
            return
        n0 = cur[2]
        tot = n0 + n
        mu = (cur[0] * n0 + float(mean) * n) / tot
        cur[1] = (n0 * (cur[1] + (cur[0] - mu) ** 2)
                  + n * (float(var) + (float(mean) - mu) ** 2)) / tot
        cur[0] = mu
        cur[2] = tot

    def duration_stats(
            self, kind: Optional[str] = None
    ) -> Optional[Tuple[float, float, int]]:
        """(ewma_mean_s, ewma_var_s2, n_samples) of observed run times for
        one app kind — or, with ``kind=None``, the n-weighted pool across
        every kind this store has seen (the pilot-level mixture estimate).
        None when there are no samples yet (cold start): callers must fall
        back, never invent a duration."""
        with self._lock:
            if kind is not None:
                m = self._dur.get(kind)
                return (m[0], m[1], m[2]) if m else None
            if not self._dur:
                return None
            n = sum(m[2] for m in self._dur.values())
            mean = sum(m[0] * m[2] for m in self._dur.values()) / n
            var = sum(m[2] * (m[1] + (m[0] - mean) ** 2)
                      for m in self._dur.values()) / n
            return (mean, var, n)

    def duration_model(self) -> Dict[str, Tuple[float, float, int]]:
        """Snapshot of the whole model, {kind: (mean, var, n)} — the
        cross-pilot seeding source (PilotPool.add_pilot)."""
        with self._lock:
            return {k: (m[0], m[1], m[2]) for k, m in self._dur.items()}

    def seed_durations(self, kind: str, mean: float, var: float, n: int):
        """Seed the model for a kind from another pilot's observations —
        a freshly spawned pilot starts warm instead of falling back to
        count-based decisions until it has its own history."""
        with self._lock:
            self._dur_merge(kind, mean, var, n)

    # --------------------------- write-behind ---------------------------- #
    def _wake_writer(self):
        if self._writer is None:
            return
        if len(self._wq) >= self._max_queue:
            # backpressure: never holds self._lock, so the writer (which
            # takes self._lock briefly when compacting) can always drain.
            # Soft-bounded: record() runs under scheduler locks (e.g. the
            # Agent's condition variable on the submit fast path), so a
            # saturated writer throttles producers briefly but must never
            # wedge them — the queue transiently overshoots instead.
            with self._wcv:
                self._wcv.notify_all()
                deadline = time.monotonic() + 0.25
                while (len(self._wq) >= self._max_queue
                       and not self._wstop
                       and time.monotonic() < deadline):
                    self._wcv.wait(0.05)
            return
        # fast path: only pay the cv acquisition when the writer is parked.
        # The unlocked flag read can race (writer parking concurrently) —
        # the writer's timed wait bounds a missed wake at ~50ms of extra
        # journal lag, never a lost record; flush()/close() always notify.
        if self._wsleeping:
            with self._wcv:
                self._wcv.notify_all()

    def _writer_loop(self):
        while True:
            with self._wcv:
                while not self._wq and not self._wstop:
                    self._wsleeping = True
                    self._wcv.wait(0.05)
                self._wsleeping = False
                batch = []
                while self._wq:
                    batch.append(self._wq.popleft())
                stop = self._wstop
                self._winflight = len(batch)
                self._wcv.notify_all()      # free any backpressured producer
            if batch:
                try:
                    self._write_batch(batch)
                    self._maybe_compact()
                except Exception as e:  # noqa: BLE001 — disk-full etc.:
                    # the journal goes dead but the store must stay live.
                    # The old synchronous path surfaced I/O errors to the
                    # caller; here the writer marks the store journal-dead
                    # (record() stops enqueuing, queue discarded) instead
                    # of dying silently and wedging producers in
                    # backpressure forever.
                    self._journal_dead(e)
                with self._wcv:
                    self._winflight = 0
                    self._wcv.notify_all()  # flush() waits on durability
            if stop:
                with self._wcv:
                    if not self._wq:        # drained: safe to exit
                        return

    def _journal_dead(self, err: Exception):
        with self._lock:
            self.journal_error = repr(err)
            if self._fh is not None:
                try:
                    self._fh.close()
                except Exception:  # noqa: BLE001
                    pass
                self._fh = None
        with self._wcv:
            self._wq.clear()
            self._wcv.notify_all()

    def _write_batch(self, batch: List[dict]):
        """Group commit: serialize the whole drain cycle, one write + one
        flush.  Records whose result had to be dropped from the line are
        also stripped from the in-memory maps — otherwise every large
        non-serializable result (e.g. device arrays) stays pinned for the
        store's lifetime, which the old synchronous probe never allowed."""
        if self._fh is None:
            return
        lines = []
        slimmed: List[dict] = []
        for rec in batch:
            ref = rec.get("result_ref")
            if ref is not None and self.objectstore is not None:
                # durable-before-event: the payload blob + .ref pointer
                # must be on disk before the DONE line that names them
                try:
                    self.objectstore.ensure_spilled(ref["oid"])
                except (KeyError, OSError,
                        serializer.SerializationError):
                    pass            # unspillable: the metadata still
                                    # journals; replay just can't
                                    # re-materialize the payload
            line, dropped = self._dumps(rec)
            lines.append(line)
            if dropped:
                slimmed.append(rec)
        if slimmed:
            with self._lock:
                for rec in slimmed:
                    rec.pop("result", None)
        try:
            self._fh.write("".join(lines))
            self._fh.flush()
        except ValueError:                  # closed mid-write during close()
            return
        self._journal_lines += len(lines)

    @staticmethod
    def _dumps(rec: dict) -> Tuple[str, bool]:
        """(journal line, result_dropped) — serialization failures fall
        back to slimmer forms instead of losing the whole record."""
        try:
            return json.dumps(rec) + "\n", False
        except (TypeError, ValueError):
            slim = {k: v for k, v in rec.items() if k != "result"}
            try:
                return json.dumps(slim) + "\n", "result" in rec
            except (TypeError, ValueError):
                return json.dumps({k: v for k, v in slim.items()
                                   if _jsonable(v)}) + "\n", "result" in rec

    def _maybe_compact(self):
        """Writer thread only: when the journal holds many times more
        lines than live task records, rewrite it as a snapshot (one line
        per task + one stats header) and keep appending — so a long
        elastic run's restart replays O(tasks), not O(transitions)."""
        threshold = max(self._compact_min_lines,
                        self._compact_factor * max(1, len(self.tasks)))
        if self._journal_lines < threshold or self._fh is None:
            return
        with self._lock:
            # Records still queued are already folded into the counters
            # and the task map being snapshotted — letting them land in
            # the tail afterwards would make a restart ingest them twice,
            # so the queue is dropped (snapshot covers it).  Runtime
            # events — flushed or queued — are re-emitted from the
            # in-memory stream so pilot-lifecycle history (PILOT_START /
            # STOLEN / GROW / PILOT_RETIRE ...) survives compaction;
            # only per-task ROUTED events are left out (high cardinality,
            # and each task record carries its "pilot" binding anyway).
            self._wq.clear()
            snap = [dict(rec, snap=True) for rec in self.tasks.values()]
            kept_events = []
            ckpt_latest: Dict[str, dict] = {}
            for e in self.events:
                kind = e.get("event")
                if kind in (None, EVENTS.STATE, EVENTS.ROUTED):
                    continue
                if kind == EVENTS.CHECKPOINT:
                    # collapse: a long task journals one CHECKPOINT per
                    # saved step, but only the latest per key is live —
                    # replay would ignore the rest anyway (monotonic
                    # steps) and gc'd keys drop out entirely, so the
                    # compacted journal carries one line per live key
                    key = e.get("key")
                    if e.get("gc"):
                        ckpt_latest.pop(key, None)
                    elif (key not in ckpt_latest
                          or e.get("step", 0)
                          >= ckpt_latest[key].get("step", 0)):
                        ckpt_latest[key] = e
                    continue
                kept_events.append(e)
            kept_events.extend(ckpt_latest.values())
            # bounded event tail: the most recent STATE events ride along
            # so recent per-task state timelines survive the compaction
            # (replay ingests them timeline-only — their aggregate
            # contribution is already inside the stats header below).
            # Each gets a wall stamp so a post-reboot replay can re-anchor
            # its monotonic time like any other journaled event.
            mono_off = time.time() - time.monotonic()
            state_evs = [e for e in self.events
                         if e.get("event") == EVENTS.STATE]
            tail = [dict(e, tail=True, wt=e["t"] + mono_off)
                    for e in state_evs[-self._compact_tail_events:]]
            stats = {"occ": dict(self._occ),
                     "oh_total": (self._oh_seeded + self._oh_total
                                  + self._oh_cur),
                     "t_min": self._t_min, "t_max": self._t_max,
                     "dur": {k: list(v) for k, v in self._dur.items()}}
        tmp = self.journal_path.with_name(self.journal_path.name
                                          + ".compact.tmp")
        with open(tmp, "w") as out:
            out.write(json.dumps({"event": EVENTS.SNAPSHOT,
                                  "t": time.monotonic(),
                                  "mono_offset": mono_off,
                                  "stats": stats}) + "\n")
            for rec in snap:
                out.write(self._dumps(rec)[0])
            for rec in kept_events:
                out.write(self._dumps(rec)[0])
            for rec in tail:
                out.write(self._dumps(rec)[0])
            out.flush()
            os.fsync(out.fileno())
        self._fh.close()
        os.replace(tmp, self.journal_path)   # atomic: never a torn journal
        self._fh = open(self.journal_path, "a")
        self._journal_lines = len(snap) + len(kept_events) + len(tail) + 1

    def flush(self, timeout: float = 10.0) -> bool:
        """Block until every queued journal record has been written.
        False on timeout — and False after a writer I/O error killed the
        journal: the queue was discarded then, so an empty queue is not
        durability and True must never claim it."""
        if self._writer is None:
            return True
        deadline = time.monotonic() + timeout
        with self._wcv:
            self._wcv.notify_all()
            while self._wq or self._winflight:
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._wcv.wait(min(left, 0.05))
        return self.journal_error is None

    def close(self):
        """Drain the write-behind queue, then close the journal.  A task
        completing after close() is recorded in memory only (its journal
        write is skipped) instead of hitting a closed handle."""
        writer = self._writer
        if writer is not None:
            with self._wcv:
                self._wstop = True
                self._wcv.notify_all()
            writer.join(timeout=10.0)
            self._writer = None
        with self._lock:
            if self._fh:
                self._fh.close()
                self._fh = None


def _jsonable(x) -> bool:
    try:
        json.dumps(x)
        return True
    except (TypeError, ValueError):
        return False


def union_intervals(ivals: List[Tuple[float, float]]) -> float:
    """Total length of the union of (start, end) intervals."""
    total = 0.0
    cur_start: Optional[float] = None
    cur_end = 0.0
    for s, t in sorted(ivals):
        if cur_start is None or s > cur_end:
            if cur_start is not None:
                total += cur_end - cur_start
            cur_start, cur_end = s, t
        else:
            cur_end = max(cur_end, t)
    if cur_start is not None:
        total += cur_end - cur_start
    return total


def overhead_from_events(events: List[dict]) -> float:
    """RP overhead recomputed from a unified event stream: wall-clock
    seconds during which the runtime was placing or launching at least one
    task — the union (not the per-task sum) of every [SCHEDULED, RUNNING)
    interval observed in the stream.

    The per-task timestamp sum this replaces overcounts twice: concurrent
    launches are each charged full price even though they overlap in wall
    time, and a retried task's timestamps dict keeps only the last
    SCHEDULED/RUNNING pair, silently mixing attempts.  The event stream
    keeps every occurrence, so each attempt contributes its own interval
    and overlapping intervals are merged before integrating.  Slot-idle
    gaps between dependent tasks contribute nothing: no task is in
    SCHEDULED/LAUNCHING there, so no interval covers the gap.

    Live stores maintain this incrementally (StateStore.overhead /
    overhead_intervals); this offline form remains for synthetic streams
    and merged multi-pilot event dumps.
    """
    opens: Dict[str, float] = {}            # uid -> t of pending SCHEDULED
    ivals: List[Tuple[float, float]] = []
    for e in sorted((e for e in events if e.get("event") == EVENTS.STATE),
                    key=lambda e: e["t"]):
        uid, state, t = e["uid"], e["state"], e["t"]
        if state == "SCHEDULED":
            opens[uid] = t
        elif state in ("RUNNING",) + _END_STATES and uid in opens:
            # RUNNING closes the overhead interval; a terminal state closes
            # it too for tasks that failed before ever running
            start = opens.pop(uid)
            if t > start:
                ivals.append((start, t))
    return union_intervals(ivals)
