"""StateStore — the MongoDB analog: journaled task/pilot state.

RP uses a MongoDB instance to share state between client-side managers and
the agent; in a single-controller JAX deployment the equivalent is an
in-process store with a JSON-lines journal on disk.  The journal gives the
workflow layer crash-consistent restart: a restarted DFK replays DONE tasks
(futures resolve immediately from recorded results when re-submitted with
the same workflow key) and resubmits in-flight ones.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

from .futures import TaskRecord, TaskState


class StateStore:
    def __init__(self, journal_path: Optional[str] = None):
        self.journal_path = Path(journal_path) if journal_path else None
        self._lock = threading.Lock()
        self.tasks: Dict[str, dict] = {}
        self._fh = None
        if self.journal_path:
            self.journal_path.parent.mkdir(parents=True, exist_ok=True)
            if self.journal_path.exists():
                self._replay()
            self._fh = open(self.journal_path, "a", buffering=1)

    def _replay(self):
        with open(self.journal_path) as fh:
            for line in fh:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue        # torn tail write from a crash
                self.tasks[rec["uid"]] = rec

    def record(self, task: TaskRecord, workflow_key: Optional[str] = None):
        rec = {
            "uid": task.uid,
            "key": workflow_key,
            "kind": task.kind,
            "state": task.state.value,
            "retries": task.retries,
            "slot_ids": list(task.slot_ids),
            "t": time.time(),
        }
        if task.state == TaskState.DONE and _jsonable(task.result):
            rec["result"] = task.result
        if task.error is not None:
            rec["error"] = repr(task.error)[:500]
        with self._lock:
            prev = self.tasks.get(task.uid, {})
            if "key" not in rec or rec["key"] is None:
                rec["key"] = prev.get("key")
            self.tasks[task.uid] = {**prev, **rec}
            if self._fh:
                self._fh.write(json.dumps(self.tasks[task.uid]) + "\n")

    def completed_result(self, workflow_key: str):
        """(found, result) for a previously-DONE task with this key."""
        with self._lock:
            for rec in self.tasks.values():
                if rec.get("key") == workflow_key and \
                        rec.get("state") == TaskState.DONE.value and \
                        "result" in rec:
                    return True, rec["result"]
        return False, None

    def states(self) -> Dict[str, str]:
        with self._lock:
            return {uid: r.get("state", "?") for uid, r in self.tasks.items()}

    def close(self):
        if self._fh:
            self._fh.close()
            self._fh = None


def _jsonable(x) -> bool:
    try:
        json.dumps(x)
        return True
    except (TypeError, ValueError):
        return False
