"""Task Translator — the paper's mid-point integration component.

Exactly the three capabilities of §IV-C:
  (i)  detect whether a Parsl task is a pure Python function, an SPMD
       (MPI-analog) function, or a Bash/executable call;
  (ii) translate the Parsl task 1:1 into a pilot TaskRecord, attaching the
       resource requirements (slots / sub-mesh) that Parsl's own API does
       not carry — supplied through the @spmd_app decorator's extension;
  (iii) reflect pilot task state back into the Parsl future via callbacks.
"""
from __future__ import annotations

import shlex
import subprocess
from typing import Callable, Optional, Sequence

from .futures import (AppFuture, ResourceSpec, RetryPolicy, TaskRecord,
                      TaskState, new_uid)


def detect_kind(fn: Callable) -> str:
    """Capability (i): classify the app callable."""
    kind = getattr(fn, "__app_kind__", None)
    if kind is not None:
        return kind
    if getattr(fn, "__is_bash__", False):
        return "bash"
    return "python"


def _bash_runner(cmd_builder: Callable):
    def run(*args, **kwargs):
        cmd = cmd_builder(*args, **kwargs)
        proc = subprocess.run(
            cmd if isinstance(cmd, list) else shlex.split(cmd),
            capture_output=True, text=True, check=False)
        if proc.returncode != 0:
            raise RuntimeError(
                f"bash app failed rc={proc.returncode}: {proc.stderr[:500]}")
        return proc.stdout
    return run


def translate(fn: Callable, args: tuple, kwargs: dict,
              resources: Optional[ResourceSpec] = None,
              max_retries: int = 0,
              affinity: Sequence[str] = (),
              retry_policy: Optional[RetryPolicy] = None,
              affinity_bytes: Optional[dict] = None) -> TaskRecord:
    """Capability (ii): 1:1 Parsl-task -> pilot-task translation.

    ``affinity`` carries runtime-discovered data-affinity hints (the DFK
    dep manager passes the pilots that produced this task's inputs); they
    merge — deduplicated, static ResourceSpec hints (input-array device /
    pilot names) first — into the
    ``TaskRecord.affinity`` stamp a LocalityAware placement policy scores.

    ``affinity_bytes`` ({producer pilot: input bytes}, also from the dep
    manager) upgrades that stamp to *byte-weighted* affinity: placement
    follows the largest input instead of counting producers equally
    (docs/dataplane.md).

    ``retry_policy`` supersedes the bare ``max_retries`` count when given:
    the attempt budget comes from ``retry_policy.max_retries`` and failed
    attempts get backoff, error classification, and poison quarantine
    (docs/resilience.md).
    """
    app_kind = kind = detect_kind(fn)   # classify once: translate() runs
    res = resources or getattr(fn, "__resources__", None) or ResourceSpec()
    body = fn                           # per task on the submit hot path
    if kind == "bash":
        body = _bash_runner(fn)
        kind = "python"  # executed as a single-slot callable wrapping a proc
        res = ResourceSpec(slots=res.slots, cpu_only=True,
                           priority=res.priority, sticky=res.sticky,
                           affinity=res.affinity,
                           checkpointable=res.checkpointable)
    kwargs = dict(kwargs)
    if kind == "spmd" and not getattr(fn, "__spmd_jit__", True):
        kwargs["_jit"] = False
    aff = tuple(res.affinity) + tuple(affinity)
    uid = new_uid("task")
    task = TaskRecord(
        uid=uid, kind=kind, fn=body, args=args, kwargs=kwargs,
        resources=res,
        max_retries=(retry_policy.max_retries if retry_policy is not None
                     else max_retries),
        retry_policy=retry_policy,
        app_kind=app_kind,
        sticky=res.sticky,
        affinity=tuple(dict.fromkeys(aff)) if aff else (),
        affinity_bytes=dict(affinity_bytes) if affinity_bytes else None,
        checkpointable=res.checkpointable,
        inproc_only=(kind == "spmd"),   # a sub-mesh binds to the agent
                                        # process's XLA client: a proc
                                        # transport routes spmd inproc
        ckpt_key=uid,       # replicas inherit it; keyed workflows replace
                            # it with the stable workflow key (restart)
        res_kind=res.res_kind or (
            "device" if kind == "spmd" and not res.cpu_only else "cpu"))
    task.transition(TaskState.NEW)
    return task


def bind_future(task: TaskRecord, future: AppFuture):
    """Capability (iii): a done-callback that resolves the Parsl future from
    the pilot task's terminal state."""
    def cb(t: TaskRecord):
        if t.state == TaskState.DONE:
            future.set_result(t.result)
        else:
            future.set_exception(
                t.error or RuntimeError(f"{t.uid} ended {t.state.value}"))
    return cb
