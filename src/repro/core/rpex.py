"""RPEX — the RADICAL-Pilot Executor for the DFK (the paper's §IV-D).

A Parsl-style executor that bootstraps the pilot runtime on initialization
(PilotManager + TaskManager, as the paper describes), translates each Parsl
task through the Task Translator, and reflects pilot task states back into
AppFutures.  Supports both the paper's stream submission (one by one, as
Parsl's DFK emits tasks) and the bulk mode the paper names as future work.
"""
from __future__ import annotations

import threading
from typing import List, Optional, Tuple

from .executors import Executor, ParslTask
from .futures import AppFuture, TaskState
from .pilot import Pilot, PilotDescription, PilotManager, TaskManager
from .translator import bind_future, translate


class RPEXExecutor(Executor):
    label = "rpex"
    supports_bulk = True

    def __init__(self, pilot_desc: Optional[PilotDescription] = None,
                 pilot: Optional[Pilot] = None):
        # "Once initialized, RPEX ... starts a new RP session and creates
        # the Pilot Manager and the Task Manager."
        self._own_pilot = pilot is None
        if pilot is None:
            self.pmgr = PilotManager()
            self.pilot = self.pmgr.submit_pilot(
                pilot_desc or PilotDescription())
        else:
            self.pmgr = None
            self.pilot = pilot
        self.tmgr = TaskManager(self.pilot)
        self.overhead_events: List[Tuple[str, float]] = []

    # ------------------------------------------------------------------ #
    def submit(self, ptask: ParslTask, future: AppFuture):
        task = translate(ptask.fn, ptask.args, ptask.kwargs,
                         ptask.resources, ptask.retries)
        future.task = task
        self.pilot.store.record(task, workflow_key=ptask.key)
        self.tmgr.submit(task, done_cb=bind_future(task, future))

    def submit_bulk(self, pairs: List[Tuple[ParslTask, AppFuture]]):
        tasks = []
        cbs = {}
        for pt, fut in pairs:
            task = translate(pt.fn, pt.args, pt.kwargs, pt.resources,
                             pt.retries)
            fut.task = task
            self.pilot.store.record(task, workflow_key=pt.key)
            cbs[task.uid] = bind_future(task, fut)
            tasks.append(task)

        def cb(t):
            uid = t.uid if t.replica_of is None else t.replica_of
            f = cbs.pop(uid, None)
            if f is not None:
                f(t)

        self.tmgr.submit_bulk(tasks, done_cb=cb)

    # ------------------------------------------------------------------ #
    def drain(self, timeout: Optional[float] = None) -> bool:
        return self.tmgr.wait(timeout=timeout)

    def shutdown(self):
        if self._own_pilot and self.pmgr is not None:
            self.pmgr.close()
