"""RPEX — the RADICAL-Pilot Executor for the DFK (the paper's §IV-D).

A Parsl-style executor that bootstraps the pilot runtime on initialization
(PilotManager + TaskManager, as the paper describes), translates each Parsl
task through the Task Translator, and reflects pilot task states back into
AppFutures.  Supports both the paper's stream submission (one by one, as
Parsl's DFK emits tasks) and the bulk mode the paper names as future work.

One RPEXExecutor may own *several* pilots (a PilotPool) with heterogeneous
descriptions — e.g. a CPU pilot for pure-Python pre/post-processing and a
device pilot for SPMD tasks.  The translator stamps each task's resource
kind and the TaskManager late-binds it to a compatible pilot chosen by a
pluggable ``PlacementPolicy`` (least-loaded by default), so one executor
serves heterogeneous tasks on heterogeneous resources (the paper's
central claim).

Descriptions may also mix *worker transports* (see docs/processes.md):
``PilotDescription(transport="proc")`` gives that pilot a pool of worker
OS processes executing python/bash bodies off the GIL (the RP
master/worker split), while ``"inproc"`` (default) keeps the original
thread pool — e.g. a proc CPU pilot for compute-heavy python tasks next
to an inproc device pilot for SPMD tasks, in one pool.

Placement is configured with the ``placement=`` kwarg: a policy name
(``"least-loaded"`` — the default — ``"locality"``, or ``"cost"``) or any
``repro.core.placement.PlacementPolicy`` instance, e.g.
``RPEXExecutor(descs, placement=LocalityAware(locality_weight=0.75))`` or
``placement=CostModelPolicy(inner="locality")``.  The policy decides
routing, bulk spreading, steal-victim ordering, per-task steal
eligibility, preemption-victim choice, and which scaler template spawns —
see docs/placement.md.  ``"cost"`` re-prices all of those in predicted
seconds from the per-(app_kind, pilot) duration model each pilot's
StateStore maintains (docs/scheduling.md); the same model drives the
agents' per-kind straggler deadlines and — with a ``ScalerConfig`` — the
PoolScaler's predictive scale-up signal, whichever placement policy is
active.
"""
from __future__ import annotations

import threading
from typing import List, Optional, Sequence, Tuple, Union

from .executors import Executor, ParslTask
from .futures import AppFuture, TaskState
from .pilot import (Pilot, PilotDescription, PilotManager, PilotPool,
                    PoolScaler, ScalerConfig, TaskManager)
from .placement import PlacementPolicy, resolve_policy
from .store import union_intervals
from .translator import bind_future, translate

Descs = Union[PilotDescription, Sequence[PilotDescription]]


class RPEXExecutor(Executor):
    label = "rpex"
    supports_bulk = True
    resolves_refs = True     # edges may ship ObjectRefs: the executing
                             # pilot materializes them (docs/dataplane.md)

    def __init__(self, pilot_desc: Optional[Descs] = None,
                 pilot: Optional[Pilot] = None,
                 pilots: Optional[Sequence[Pilot]] = None,
                 scaler: Optional[ScalerConfig] = None,
                 steal: bool = True,
                 preempt: bool = True,
                 placement: Union[None, str, PlacementPolicy] = None,
                 heartbeat_timeout_s: Optional[float] = None,
                 data_plane: bool = True,
                 data_threshold: Optional[int] = None):
        # "Once initialized, RPEX ... starts a new RP session and creates
        # the Pilot Manager and the Task Manager."
        policy = resolve_policy(placement)
        self._own_pilots = pilot is None and pilots is None
        if self._own_pilots:
            if pilot_desc is None:
                descs = [PilotDescription()]
            elif isinstance(pilot_desc, PilotDescription):
                descs = [pilot_desc]
            else:
                descs = list(pilot_desc)
            self.pmgr = PilotManager()
            self.pool = self.pmgr.submit_pilots(
                descs, steal=steal, preempt=preempt, policy=policy,
                heartbeat_timeout_s=heartbeat_timeout_s,
                data_plane=data_plane, data_threshold=data_threshold)
        else:
            self.pmgr = None
            self.pool = PilotPool(
                pilots=list(pilots) if pilots is not None else [pilot],
                steal=steal, preempt=preempt, policy=policy,
                heartbeat_timeout_s=heartbeat_timeout_s,
                data_plane=data_plane, data_threshold=data_threshold)
        self.tmgr = TaskManager(self.pool)
        self.scaler = (PoolScaler(self.pool, scaler).start()
                       if scaler is not None else None)
        self.overhead_events: List[Tuple[str, float]] = []

    @property
    def pilot(self) -> Pilot:
        """Primary pilot (single-pilot compatibility accessor)."""
        return self.pool.pilots[0]

    @property
    def placement(self) -> PlacementPolicy:
        """The active placement policy (docs-visible; see
        docs/placement.md)."""
        return self.pool.policy

    @property
    def objectstore(self):
        """The pool's data plane (None with ``data_plane=False``) — its
        ``stats()`` expose bytes_moved/spills (docs/dataplane.md)."""
        return self.pool.objectstore

    # ------------------------------------------------------------------ #
    def submit(self, ptask: ParslTask, future: AppFuture):
        task = translate(ptask.fn, ptask.args, ptask.kwargs,
                         ptask.resources, ptask.retries,
                         affinity=ptask.affinity,
                         retry_policy=ptask.retry_policy,
                         affinity_bytes=ptask.affinity_bytes)
        future.task = task
        self.tmgr.submit(task, done_cb=bind_future(task, future),
                         workflow_key=ptask.key)

    def submit_bulk(self, pairs: List[Tuple[ParslTask, AppFuture]]):
        tasks = []
        keys = {}
        cbs = {}
        for pt, fut in pairs:
            task = translate(pt.fn, pt.args, pt.kwargs, pt.resources,
                             pt.retries, affinity=pt.affinity,
                             retry_policy=pt.retry_policy,
                             affinity_bytes=pt.affinity_bytes)
            fut.task = task
            if pt.key is not None:
                keys[task.uid] = pt.key
            cbs[task.uid] = bind_future(task, fut)
            tasks.append(task)

        def cb(t):
            uid = t.uid if t.replica_of is None else t.replica_of
            f = cbs.pop(uid, None)
            if f is not None:
                f(t)

        self.tmgr.submit_bulk(tasks, done_cb=cb, workflow_keys=keys)

    # ------------------------------------------------------------------ #
    def completed_result(self, workflow_key: str):
        """(found, result) across every pilot's journal — including
        retired pilots, since a stolen task's DONE record lives in the
        journal of the pilot that actually ran it."""
        for p in self.pool.all_pilots():
            found, result = p.store.completed_result(workflow_key)
            if found:
                return True, result
        return False, None

    def checkpoint_step(self, workflow_key: str):
        """Latest checkpointed step recorded for this key across every
        pilot (incl. retired), or None — the partial-restart analog of
        ``completed_result``: a key that is not DONE but has a
        checkpoint will re-execute and resume from this step."""
        return self.pool.checkpoint_step(workflow_key)

    def utilization(self):
        """Per-pilot busy-slot fraction across the (possibly elastic)
        pilot set (unified event stream backs the offline Fig.6-style
        breakdown; see StateStore.utilization)."""
        return self.pool.utilization()

    def rp_overhead(self) -> float:
        """RP overhead in seconds: the wall-clock union of
        SCHEDULED->RUNNING intervals across every pilot, including retired
        ones.  Unlike the per-task timestamp sum, this neither
        double-counts concurrent launches nor charges slot-idle gaps
        between dependent tasks.  Each store maintains its closed
        intervals incrementally, so the cross-pilot merge unions O(tasks)
        intervals instead of re-scanning O(events) stream records.
        History whose intervals were compacted away survives as each
        store's scalar base — summed, since cross-pilot overlap of that
        prefix is no longer reconstructable (a documented upper bound)."""
        ivals = []
        base = 0.0
        for p in self.pool.all_pilots():
            ivals.extend(p.store.overhead_intervals())
            base += p.store.overhead_base()
        return base + union_intervals(ivals)

    def drain(self, timeout: Optional[float] = None) -> bool:
        return self.tmgr.wait(timeout=timeout)

    def shutdown(self):
        if self.scaler is not None:
            self.scaler.stop()
        if self._own_pilots:
            self.pool.close()
            if self.pmgr is not None:
                self.pmgr.pilots.clear()
