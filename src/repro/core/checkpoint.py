"""Task checkpointing — cooperative partial restarts for long tasks.

The straggler-replica and preempt-and-migrate mechanisms both need the
same primitive: the ability to re-run a long task *from where it got to*
instead of from step 0.  This module provides it in two pieces:

  * ``CheckpointStore`` — a per-pilot checkpoint registry journaled
    through the pilot's StateStore: every ``save``/``discard`` appends a
    ``CHECKPOINT`` event (write-behind, like all runtime events), so a
    restarted pilot replays its checkpoint map from the journal.  Payloads
    are pickled to a ``<journal>.ckpt/`` directory next to the journal —
    written atomically (tmp + ``os.replace``) *before* the event is
    queued, so a replayed event never references a torn payload; a crash
    between the two loses only that one checkpoint, never corrupts the
    map.  Journal-less stores keep payloads in memory only.  Compaction
    keeps one CHECKPOINT line per live key (see ``StateStore``), and
    ``discard`` unlinks the payload and journals a ``gc`` marker so
    completed tasks' checkpoints do not accumulate.

  * ``Checkpoint`` — the per-execution context handed to a checkpointable
    task body as the ``ckpt`` keyword argument, and the runtime's
    cooperative-preemption boundary: when the agent has requested
    preemption, the next ``ckpt.save(step, state)`` persists the step and
    then raises ``TaskPreempted``, so the body unwinds having lost
    nothing and the runtime can requeue, migrate, or restart it to
    ``restore()`` from exactly that step.

Checkpoints are keyed by ``TaskRecord.ckpt_key`` — the task uid by
default, shared by straggler replicas (so a replica resumes from the
leader's progress) and replaced by the stable workflow key when the task
is submitted through a keyed workflow (so a restarted run resumes an
interrupted task mid-stream).  Steps are monotonic per key: a stale
writer (e.g. a canceled leader unwinding behind its replica) can never
roll a checkpoint back.
"""
from __future__ import annotations

import os
import pickle
import re
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

from .objectstore import BlobLeaf
from .store import EVENTS, StateStore

try:
    import numpy as _np
except ImportError:                     # pragma: no cover - numpy is a
    _np = None                          # hard dep everywhere else

_SAFE = re.compile(r"[^A-Za-z0-9._-]")


class TaskPreempted(BaseException):
    """Raised inside a task body by ``Checkpoint.save`` when the agent
    has requested cooperative preemption: the step just saved is durable,
    so unwinding here forfeits no work.  Derives from ``BaseException``
    so a task body's own ``except Exception`` error handling cannot
    accidentally swallow the unwind."""

    def __init__(self, key: str, step: int):
        super().__init__(f"preempted at checkpoint {key!r} step {step}")
        self.key = key
        self.step = step


class CheckpointStore:
    """Journal-backed checkpoint registry (one per pilot; see module
    docstring for the durability model)."""

    def __init__(self, store: StateStore):
        self.store = store
        self.objectstore = None         # pool-wired data plane: large
                                        # ndarray leaves persist as
                                        # content-addressed BlobLeaf refs
                                        # deduped against result spills
                                        # (docs/dataplane.md)
        self._lock = threading.Lock()
        # key -> {"step": int, "state": Any (if in memory), "path": str?}
        self._latest: Dict[str, dict] = {}
        self.dir: Optional[Path] = None
        if store.journal_path is not None:
            self.dir = store.journal_path.with_name(
                store.journal_path.name + ".ckpt")
            self.dir.mkdir(parents=True, exist_ok=True)
        # replay: a journaled store has already rebuilt its event stream,
        # including CHECKPOINT events, by the time we attach
        for ev in store.events_snapshot():
            if ev.get("event") == EVENTS.CHECKPOINT:
                self._ingest(ev)

    def _ingest(self, ev: dict):
        key = ev.get("key")
        if key is None:
            return
        if ev.get("gc"):
            self._latest.pop(key, None)
            return
        cur = self._latest.get(key)
        if cur is None or ev.get("step", 0) >= cur["step"]:
            self._latest[key] = {"step": ev.get("step", 0),
                                 "path": ev.get("path")}

    # ------------------------------- api -------------------------------- #
    def save(self, key: str, step: int, state: Any) -> bool:
        """Record ``state`` as the checkpoint for ``step``.  Returns False
        (recording nothing) when a newer step is already held — steps are
        monotonic per key, so a lagging duplicate writer cannot roll the
        checkpoint back."""
        path = self._persist(key, step, state)
        with self._lock:
            cur = self._latest.get(key)
            prev = cur.get("path") if cur else None
            if cur is not None and cur["step"] > step:
                stale, accepted = path, False
            elif path is None and prev is not None:
                # the new state could not be pickled: keep the previous
                # durable payload (its journaled event must keep pointing
                # at a real file — a post-crash replay resumes from it)
                # and carry its path forward so a later successful save
                # still GCs it
                stale = None
                self._latest[key] = {"step": step, "state": state,
                                     "path": prev}
                accepted = True
            else:
                stale = None if prev == path else prev
                self._latest[key] = {"step": step, "state": state,
                                     "path": path}
                accepted = True
        self._unlink(stale)            # payload GC: one live file per key
        if accepted and (self.dir is None or path is not None):
            # journaled stores only record events whose payload actually
            # landed on disk: an unpicklable state is a memory-only
            # checkpoint, and replaying its event would make step()
            # assert a resume that restore() can never deliver
            self.store.record_event(EVENTS.CHECKPOINT, key=key, step=step,
                                    path=path)
        return accepted

    def step(self, key: str) -> Optional[int]:
        """Latest recorded step for ``key`` without touching the payload
        (the cheap existence probe restart observability uses)."""
        with self._lock:
            cur = self._latest.get(key)
            return None if cur is None else cur["step"]

    def has(self, key: str) -> bool:
        with self._lock:
            return key in self._latest

    def latest(self, key: str) -> Optional[Tuple[int, Any]]:
        """(step, state) of the newest checkpoint, or None.  Replayed
        entries lazy-load their payload from disk (and cache it); a
        missing or unreadable payload means no usable checkpoint."""
        with self._lock:
            cur = self._latest.get(key)
            if cur is None:
                return None
            if "state" in cur:
                return cur["step"], cur["state"]
            step, path = cur["step"], cur.get("path")
        if not path or not os.path.exists(path):
            return None
        try:
            with open(path, "rb") as fh:
                state = pickle.load(fh)
            state = self._rehydrate(state)
        except Exception:  # noqa: BLE001 — unreadable payload (or a
            return None    # missing leaf blob): no resume
        with self._lock:
            cur = self._latest.get(key)
            if cur is not None and cur["step"] == step:
                cur["state"] = state
        return step, state

    def discard(self, key: str):
        """GC a completed task's checkpoint: drop the entry, unlink the
        payload, and journal a ``gc``-marked CHECKPOINT event so replay
        and compaction drop the key too."""
        with self._lock:
            cur = self._latest.pop(key, None)
        if cur is None:
            return
        self._unlink(cur.get("path"))
        self.store.record_event(EVENTS.CHECKPOINT, key=key, gc=True)

    def adopt(self, key: str, src: "CheckpointStore") -> bool:
        """Copy ``src``'s latest checkpoint for ``key`` into this store
        (the migrate-hook path: the checkpoint travels with the task)
        unless ours is already at least as new.  Steps are compared
        first (lock-only on both sides) so the steady-state no-op —
        e.g. ``ensure_checkpoint`` probing every pilot on each keyed
        submission — never touches the payload."""
        if src is self:
            return False
        src_step = src.step(key)
        if src_step is None:
            return False
        with self._lock:
            cur = self._latest.get(key)
            if cur is not None and cur["step"] >= src_step:
                return False
        got = src.latest(key)
        if got is None:
            return False
        return self.save(key, got[0], got[1])

    def keys(self):
        with self._lock:
            return list(self._latest)

    # ----------------------------- payloads ----------------------------- #
    def _persist(self, key: str, step: int, state: Any) -> Optional[str]:
        """Write the payload next to the journal, atomically, *before*
        the CHECKPOINT event is recorded — a replayed event always points
        at a fully-written file.  Unpicklable state falls back to a
        memory-only checkpoint (usable within this process; a restart
        then starts the task from scratch)."""
        if self.dir is None:
            return None
        name = f"{_SAFE.sub('_', key)}.{step}.pkl"
        # per-writer tmp name: a leader and its checkpoint-resumed
        # replica share the key by design and may save the same step
        # concurrently — interleaved writes into one shared tmp would
        # let os.replace promote a torn payload
        tmp = self.dir / f"{name}.{threading.get_ident()}.tmp"
        final = self.dir / name
        try:
            state = self._dehydrate(state)
            with open(tmp, "wb") as fh:
                pickle.dump(state, fh)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, final)
            return str(final)
        except Exception:  # noqa: BLE001
            try:
                tmp.unlink()
            except OSError:
                pass
            return None

    @staticmethod
    def _unlink(path: Optional[str]):
        if path:
            try:
                os.unlink(path)
            except OSError:
                pass

    # ------------------------- per-leaf blobs --------------------------- #
    def _dehydrate(self, state: Any, _depth: int = 0) -> Any:
        """Replace large ndarray leaves with content-addressed BlobLeaf
        refs through the pool object store: the pickled skeleton stays
        small, and a leaf byte-identical to a published result (or
        repeated across steps/keys) lands on disk exactly once.  Without
        a wired object store this is the identity (the PR-7 whole-pickle
        path)."""
        store = self.objectstore
        if store is None or _np is None:
            return state
        if (isinstance(state, _np.ndarray) and not state.dtype.hasobject
                and state.nbytes >= store.threshold):
            try:
                sha, size = store.put_blob(state)
            except Exception:  # noqa: BLE001 — unspillable leaf: inline
                return state
            return BlobLeaf(sha, size,
                            f"ndarray[{state.dtype}]{state.shape}")
        if _depth >= 3:
            return state
        if isinstance(state, dict):
            return {k: self._dehydrate(v, _depth + 1)
                    for k, v in state.items()}
        if isinstance(state, list):
            return [self._dehydrate(v, _depth + 1) for v in state]
        if isinstance(state, tuple):
            out = [self._dehydrate(v, _depth + 1) for v in state]
            if hasattr(state, "_fields"):       # NamedTuple
                return type(state)(*out)
            return tuple(out)
        return state

    def _rehydrate(self, state: Any, _depth: int = 0) -> Any:
        """Inverse of ``_dehydrate``: load BlobLeaf refs back from the
        object store's blob namespace.  A missing blob raises — the
        caller treats the checkpoint as unusable."""
        if isinstance(state, BlobLeaf):
            if self.objectstore is None:
                raise RuntimeError(
                    "checkpoint contains BlobLeaf refs but no object "
                    "store is wired")
            return self.objectstore.get_blob(state.sha)
        if _depth >= 3:
            return state
        if isinstance(state, dict):
            return {k: self._rehydrate(v, _depth + 1)
                    for k, v in state.items()}
        if isinstance(state, list):
            return [self._rehydrate(v, _depth + 1) for v in state]
        if isinstance(state, tuple):
            out = [self._rehydrate(v, _depth + 1) for v in state]
            if hasattr(state, "_fields"):       # NamedTuple
                return type(state)(*out)
            return tuple(out)
        return state


class Checkpoint:
    """Per-execution checkpoint context (the ``ckpt`` keyword argument of
    a checkpointable task body).

    Contract — steps run exactly once across preempt/migrate/restart:

        start = 0
        got = ckpt.restore()
        if got is not None:
            start = got[0] + 1          # the saved step is complete
        for step in range(start, n_steps):
            state = do_step(step, state)
            ckpt.save(step, state)      # durable (and the preemption
                                        # boundary) from here

    ``save`` raising ``TaskPreempted`` is normal control flow: let it
    propagate — the agent catches it and requeues/migrates the task.
    """

    def __init__(self, store: CheckpointStore, key: str):
        self.store = store
        self.key = key
        self._preempt = threading.Event()
        # transport hook: a process-mode transport points this at a
        # "forward the preempt flag down the worker pipe" closure while
        # the body runs remotely, so request_preempt() reaches the child
        self._forward: Optional[callable] = None

    def restore(self) -> Optional[Tuple[int, Any]]:
        """(last_saved_step, state), or None on a fresh start."""
        return self.store.latest(self.key)

    def save(self, step: int, state: Any):
        """Persist ``step`` then honor any pending preemption request."""
        self.store.save(self.key, step, state)
        if self._preempt.is_set():
            raise TaskPreempted(self.key, step)

    def preempt_requested(self) -> bool:
        """Bodies with long gaps between saves may poll this and
        checkpoint early to yield sooner."""
        return self._preempt.is_set()

    def request_preempt(self):
        """Agent-side: ask the body to unwind at its next save.  When the
        body executes in a worker process, the attached transport hook
        forwards the flag over the control pipe; the flag is also set
        locally first, so a hook attached *after* this call still sees it
        (the transport re-forwards on attach)."""
        self._preempt.set()
        fwd = self._forward
        if fwd is not None:
            try:
                fwd()
            except Exception:  # noqa: BLE001 — a dying pipe must not
                pass           # break the requester; the driver thread
                               # surfaces WorkerDied on its own
