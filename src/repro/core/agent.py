"""Agent — the RP Agent analog: event-driven scheduler loop + worker pool.

The runtime is allocation-driven, not clock-driven: a single scheduling
thread sleeps on a condition variable and is woken only by events that can
change schedulability — task submission, slot release (via the scheduler's
listener hook), elastic grow, retry requeue, or shutdown.  There is no
polling sleep anywhere on the submit -> schedule -> run -> complete path.

Scheduled tasks are executed through a pluggable **WorkerTransport**
(transport.py) — the paper's master/worker split as a seam: the agent
schedules and keeps every piece of bookkeeping in the transport's local
pool threads; only the body call (``transport.execute``) differs by mode.
``InprocTransport`` (default) is the original persistent thread pool —
workers spawn lazily up to ``max_workers``, idle ones reap themselves
after ``worker_idle_s`` — and ``ProcessTransport`` runs python/bash
bodies in worker OS processes, off the GIL.

Scheduling keeps the priority/FIFO wait heap with bounded backfill (later
small tasks may run ahead of a blocked large task, never starving it).  A
separate monitor thread implements straggler mitigation (soft-deadline
replicas) and retry-on-failure; it waits on the stop event rather than
sleeping, so shutdown is prompt.  Replicas of *checkpointable* tasks
share the leader's checkpoint key, so they resume from the leader's
latest saved step instead of recomputing from step 0; a losing leader is
asked to unwind at its next checkpoint boundary rather than grinding on.

Cooperative preemption: ``preempt(uid, handoff)`` flags a RUNNING
checkpointable task's Checkpoint context; its next ``ckpt.save`` persists
the step then unwinds with ``TaskPreempted``, and the agent resets the
task to TRANSLATED, moves its counters off this agent (exactly like a
queued steal), and calls ``handoff(task, done_cb)`` outside all locks —
the PilotPool's preempt-and-migrate and a draining pilot's partial-work
handback are both built on this hook.

Work stealing: ``steal()`` extracts queued-but-not-dispatched tasks under
the same condition variable the scheduler loop holds for a whole pass, so
a task is either still in the wait heap (stealable, callback moves with
it) or already allocated (not stealable) — never both, never neither.
When a pass leaves the agent hungry (empty wait heap, free slots) the
``idle_cb`` hook fires outside the lock so a PilotPool can migrate work
from a loaded sibling without lock-ordering hazards.

``shutdown(wait=True)`` is an event wait on the outstanding-task counter —
it returns as soon as the agent drains (immediately when idle) and
reports the uids of any tasks stranded past the timeout.

Failure domain (docs/resilience.md): the loop stamps a liveness beat on
every wakeup — scheduler-loop progress, not thread-alive — which the
PilotPool's health monitor supervises (``ping``/``last_beat``); ``halt``
silences both loops for lost-pilot recovery and crash injection.  FAILED
tasks run through a retry classifier: a per-task ``RetryPolicy`` adds
exponential backoff with deterministic jitter (delayed requeue bounded
by the cv wait — still no polling), sends infrastructure failures
(``WorkerDied``/pilot-lost/slot-failure) to a *different* pilot via the
pool's ``reroute_cb``, short-circuits ``fatal_exceptions``, and
quarantines tasks whose attempts keep killing workers.  Every attempt's
error is kept on the record and chained (``__cause__``) into the final
FAILED exception.

All state transitions are timestamped through the StateStore's unified
event stream so the Fig.6-style utilization breakdown (Scheduled/Launching/
Running/Idle) can be integrated offline.
"""
from __future__ import annotations

import heapq
import logging
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

from .checkpoint import Checkpoint, CheckpointStore, TaskPreempted
from .faults import PilotLost, SlotFailure
from .futures import (TERMINAL, ResourceSpec, TaskRecord, TaskState,
                      chain_attempt_errors, model_kind, new_uid)
from .objectstore import materialize
from .scheduler import SlotScheduler
from .spmd_executor import SPMDFunctionExecutor
from .store import EVENTS, StateStore
from .transport import InprocTransport, WorkerDied

_log = logging.getLogger(__name__)

# errors that implicate the pilot's infrastructure rather than the task
# body: a RetryPolicy with retry_different_pilot sends these retries
# through the pool to another pilot instead of the local wait heap
_INFRA_ERRORS = (WorkerDied, PilotLost, SlotFailure)


class Agent:
    def __init__(self, scheduler: SlotScheduler,
                 executor: SPMDFunctionExecutor,
                 store: Optional[StateStore] = None,
                 max_workers: int = 32,
                 backfill_window: int = 16,
                 straggler_factor: float = 3.0,
                 straggler_min_samples: int = 5,
                 straggler_min_deadline: float = 0.1,
                 straggler_stdev_k: float = 4.0,
                 per_kind_deadlines: bool = True,
                 monitor_interval: float = 0.02,
                 poll_interval: Optional[float] = None,
                 ckpt_store: Optional[CheckpointStore] = None,
                 transport=None,
                 worker_idle_s: float = 30.0):
        self.scheduler = scheduler
        self.executor = executor
        self.store = store or StateStore()
        self.ckpt = ckpt_store or CheckpointStore(self.store)
        self.max_workers = max_workers
        self.backfill_window = backfill_window
        self.straggler_factor = straggler_factor
        self.straggler_min_samples = straggler_min_samples
        self.straggler_min_deadline = straggler_min_deadline
        self.straggler_stdev_k = straggler_stdev_k
        self.per_kind_deadlines = per_kind_deadlines
        # poll_interval is accepted for backward compatibility; the loop is
        # event-driven, so it only scales the straggler-monitor cadence.
        self.monitor_interval = (poll_interval * 10 if poll_interval
                                 else monitor_interval)

        self._cv = threading.Condition()
        self._wait: List[Tuple[int, int, TaskRecord]] = []   # heap
        self._delayed: List[Tuple[float, int, TaskRecord]] = []
                                    # backoff-delayed retries: (ready_at,
                                    # seq, task) heap; the loop's cv wait
                                    # is bounded by the earliest ready
                                    # time (deadline-driven, not polled)
        self._seq = 0
        self._running: Dict[str, TaskRecord] = {}
        self._replicas: Dict[str, str] = {}                  # replica -> orig
        self._done_cb: Dict[str, Callable] = {}
        self._ckpt_ctxs: Dict[str, Checkpoint] = {}          # uid -> live ctx
        self._preempt_handoff: Dict[str, Callable] = {}      # uid -> handoff
        self._replicated: set = set()   # originals that already got their
                                        # one replica this run attempt — a
                                        # fast-failing replica must not
                                        # trigger a respawn storm
        # recent durations only: the p95 straggler deadline needs the last
        # ~100 samples, not an unbounded re-sorted history
        self._durations: "deque[float]" = deque(maxlen=256)
        self._outstanding = 0       # submitted, not yet terminal
        self._dirty = False         # a wake event arrived for the loop
        self._stop = threading.Event()
        self._crashed = False       # chaos/lost-pilot halt: loops die
                                    # silently (no drain, no refusal)
        self._beat = time.monotonic()   # liveness beat, stamped only by
                                        # the scheduler loop itself —
                                        # heartbeat supervision judges
                                        # scheduler-loop progress, not
                                        # thread-alive
        # infra-failed retry handoff: the PilotPool wires this so a
        # WorkerDied/pilot-lost/slot-failure retry lands on a *different*
        # pilot (called outside all locks, like idle_cb)
        self.reroute_cb: Optional[
            Callable[[TaskRecord, Optional[Callable]], None]] = None
        # pool-wired data plane (docs/dataplane.md): with a store attached,
        # ObjectRef inputs are materialized here — on the *executing*
        # pilot, so transfer bytes are attributed correctly even after a
        # steal or retry — and large results are published as refs
        self.objectstore = None

        self._accepting = True      # False once draining/stopped: submit
                                    # refuses instead of heaping tasks no
                                    # scheduler thread will ever drain
        # the worker pool lives behind the transport; the agent's runner
        # (_run_task, all bookkeeping) is its per-task callback
        self.transport = (transport if transport is not None
                          else InprocTransport(max_workers, worker_idle_s))
        self.transport.start(self._run_task, executor)
        self._demand_slots = 0      # slots of all outstanding tasks (O(1)
                                    # routing load metric)
        self._queued_slots = 0      # slots of queued-but-not-dispatched
                                    # tasks (O(1) steal/scaler metric —
                                    # PoolScaler ticks and steal sorting
                                    # read it instead of scanning the heap)
        # per-app-kind splits of the two counters above: the cost-model
        # layers (CostModelPolicy, Pilot.predicted_queue_wait) price a
        # backlog as sum(slots_of_kind x predicted duration of kind), so
        # the slot counts must be available by kind without heap scans
        self._kind_demand: Dict[str, int] = {}
        self._kind_queued: Dict[str, int] = {}
        self._sched_thread = threading.Thread(target=self._loop, daemon=True)
        self._mon_thread = threading.Thread(target=self._monitor, daemon=True)
        self._started = False
        # work-request hook: called (outside all locks) with the free slot
        # count whenever a scheduling pass ends with an empty wait heap and
        # spare capacity — the PilotPool wires this to its steal coordinator
        self.idle_cb: Optional[Callable[[int], None]] = None
        self.scheduler.add_listener(self._on_capacity)

    # ------------------------------ api -------------------------------- #
    def start(self):
        if not self._started:
            self._started = True
            self._sched_thread.start()
            self._mon_thread.start()
        return self

    def submit(self, task: TaskRecord,
               done_cb: Optional[Callable] = None) -> bool:
        """Returns False (without enqueuing) when the agent no longer
        accepts work — draining or stopped — so a submission racing a
        retire is refused visibly instead of heaping a task no scheduler
        thread will ever drain."""
        with self._cv:
            if not self._accepting or self._stop.is_set():
                return False
            if done_cb is not None:
                self._done_cb[task.uid] = done_cb
            self._outstanding += 1
            self._demand_slots += task.resources.slots
            self._kadd(self._kind_demand, model_kind(task),
                       task.resources.slots)
            # fast path: nothing waiting and slots available — allocate in
            # the submitting thread and hand straight to a worker, skipping
            # the scheduler-thread handoff (one fewer context switch on the
            # hot submit->run path; priority order is vacuous on an empty
            # queue, so semantics are unchanged)
            if not self._wait:
                slots = self.scheduler.allocate(task.uid,
                                                task.resources.slots)
                if slots is not None:
                    task.slot_ids = slots
                    task.transition(TaskState.SCHEDULED, self.store)
                    self._running[task.uid] = task
                    self._dispatch(task)
                    return True
            heapq.heappush(self._wait,
                           (-task.resources.priority, self._seq, task))
            self._seq += 1
            self._queued_slots += task.resources.slots
            self._kadd(self._kind_queued, model_kind(task),
                       task.resources.slots)
            self._dirty = True
            self._cv.notify_all()
            return True

    def submit_bulk(self, tasks, done_cb: Optional[Callable] = None) -> bool:
        """Bulk submission (the paper's named future work): one lock
        acquisition and one wakeup for a whole batch, cutting per-task
        submission overhead.  False if the agent no longer accepts work
        (nothing enqueued).

        Fast path (mirrors submit()): with an empty wait heap the batch is
        allocated inline in the submitting thread, in the same descending-
        priority order a fresh scheduling pass would use, skipping the
        scheduler-thread handoff; the first task that does not fit (and
        everything after it) is heaped for the event-driven loop."""
        with self._cv:
            if not self._accepting or self._stop.is_set():
                return False
            pending = list(tasks)
            if not self._wait:
                pending.sort(key=lambda t: -t.resources.priority)  # stable
                cut = None
                for i, t in enumerate(pending):
                    slots = self.scheduler.allocate(t.uid, t.resources.slots)
                    if slots is None:
                        cut = i
                        break
                    if done_cb is not None:
                        self._done_cb[t.uid] = done_cb
                    self._outstanding += 1
                    self._demand_slots += t.resources.slots
                    self._kadd(self._kind_demand, model_kind(t),
                               t.resources.slots)
                    t.slot_ids = slots
                    t.transition(TaskState.SCHEDULED, self.store)
                    self._running[t.uid] = t
                    self._dispatch(t)
                pending = [] if cut is None else pending[cut:]
            for t in pending:
                self._enqueue(t, done_cb)
            if pending:
                self._cv.notify_all()
            return True

    def stop_accepting(self):
        """Refuse all future submissions (the drain barrier): called
        before a drain's final queue sweep so no racing steal can land a
        task after the sweep."""
        with self._cv:
            self._accepting = False

    def _enqueue(self, task: TaskRecord, done_cb: Optional[Callable]):
        """Caller holds self._cv."""
        if done_cb is not None:
            self._done_cb[task.uid] = done_cb
        heapq.heappush(self._wait,
                       (-task.resources.priority, self._seq, task))
        self._seq += 1
        self._outstanding += 1
        self._demand_slots += task.resources.slots
        self._queued_slots += task.resources.slots
        kind = model_kind(task)
        self._kadd(self._kind_demand, kind, task.resources.slots)
        self._kadd(self._kind_queued, kind, task.resources.slots)
        self._dirty = True

    def shutdown(self, wait: bool = True, timeout: float = 60.0
                 ) -> List[str]:
        """Returns the uids of tasks still outstanding when the drain
        wait timed out (empty when drained, or with ``wait=False``) — a
        hung body is diagnosable instead of silently abandoned.  The
        stranded set is also logged and journaled (SHUTDOWN_STRANDED)."""
        stranded: List[str] = []
        if self._stop.is_set() or self._crashed:
            # the scheduler loop is already gone: queued work can never
            # drain, so a repeated (or post-crash) shutdown must not park
            # on the full drain timeout
            wait = False
        if wait:
            with self._cv:
                drained = self._cv.wait_for(
                    lambda: self._outstanding == 0, timeout)
                if not drained:
                    stranded = sorted(
                        {t.uid for t in self._running.values()
                         if t.state not in TERMINAL}
                        | {t.uid for _, _, t in self._wait
                           if t.state not in TERMINAL}
                        | {t.uid for _, _, t in self._delayed
                           if t.state not in TERMINAL})
            if stranded:
                _log.warning(
                    "Agent.shutdown: %d task(s) still outstanding after "
                    "%.1fs drain wait: %s", len(stranded), timeout,
                    ", ".join(stranded))
                self.store.record_event(EVENTS.SHUTDOWN_STRANDED,
                                        count=len(stranded),
                                        uids=stranded[:32])
        with self._cv:
            # set under the cv so the submit fast path can never observe
            # "not stopped"; the scheduler thread joins before the pool is
            # poisoned, so no dispatch can race a shutting-down transport
            self._stop.set()
            self._cv.notify_all()
        if self._started:
            self._sched_thread.join(timeout=5.0)   # no more dispatches after
            self._mon_thread.join(timeout=5.0)
        self.transport.shutdown()
        return stranded

    # --------------------------- failure domain -------------------------- #
    @property
    def crashed(self) -> bool:
        return self._crashed

    @property
    def last_beat(self) -> float:
        """Monotonic stamp of the scheduler loop's last observed progress
        (wakeup or scheduling pass).  Goes stale when the loop is wedged
        or crashed — the PilotPool health monitor's loss signal."""
        return self._beat

    def ping(self):
        """Ask the scheduler loop for a fresh liveness beat: wakes it
        without marking work dirty; a healthy loop re-stamps ``last_beat``
        on the wakeup, a wedged one leaves it to age out."""
        with self._cv:
            self._cv.notify_all()

    def halt(self):
        """Silence the scheduler and monitor loops without draining,
        refusing, or notifying anyone — the lost-pilot recovery path (and
        crash injection) uses this; running bodies become zombies whose
        eventual finishes settle quietly against CANCELED records."""
        with self._cv:
            self._crashed = True
            self._cv.notify_all()

    def inject_crash(self):
        """Chaos hook: simulate the whole pilot dying — loops stop
        silently, heartbeats go stale, and the PilotPool health monitor
        is expected to declare the pilot LOST and recover its tasks."""
        self.halt()

    def abandon_running(self
                        ) -> List[Tuple[TaskRecord, Optional[Callable]]]:
        """Detach every RUNNING task from this agent (the lost-pilot
        sweep): records flip to CANCELED so the zombie bodies' eventual
        finishes settle quietly without firing callbacks or retrying;
        live checkpoint contexts get a preempt request so checkpointable
        bodies unwind at their next save instead of grinding on.  Returns
        (task, done_cb) pairs for non-replica tasks — the pool re-runs
        them elsewhere from a fresh clone of each record."""
        out: List[Tuple[TaskRecord, Optional[Callable]]] = []
        with self._cv:
            victims = list(self._running.values())
            ctxs = list(self._ckpt_ctxs.values())
            handoffs = list(self._preempt_handoff.values())
            self._preempt_handoff.clear()
            for t in victims:
                if t.state in TERMINAL:
                    continue
                cb = self._done_cb.pop(t.uid, None)
                t.transition(TaskState.CANCELED, self.store)
                if t.replica_of is None:
                    out.append((t, cb))
        for h in handoffs:
            h(None, None)       # release any reserved preempt budget
        for ctx in ctxs:
            ctx.request_preempt()
        return out

    def inject_slot_failure(self, slots):
        """Simulate node failure: victims are FAILED then retried elsewhere."""
        victims = self.scheduler.mark_failed(slots)
        with self._cv:
            for uid in victims:
                t = self._running.get(uid)
                if t is not None:
                    t.error = SlotFailure(f"slot failure on {slots}")
        return victims

    @staticmethod
    def _kadd(counts: Dict[str, int], kind: str, n: int):
        """Caller holds self._cv.  Adjust a per-kind slot counter, dropping
        zeroed entries so a long-lived agent never accretes dead kinds."""
        new = counts.get(kind, 0) + n
        if new > 0:
            counts[kind] = new
        else:
            counts.pop(kind, None)

    def load(self) -> int:
        """Slot demand (queued + running) — the PilotPool routing metric.
        An O(1) counter read, maintained at submit/terminal transitions."""
        with self._cv:
            return self._demand_slots

    def demand_by_kind(self) -> Dict[str, int]:
        """Per-app-kind split of ``load()``: {kind: outstanding slots}.
        O(#kinds) copy of incrementally maintained counters — the cost
        model prices this backlog as sum(slots x predicted duration)."""
        with self._cv:
            return dict(self._kind_demand)

    def queued_by_kind(self) -> Dict[str, int]:
        """Per-app-kind split of ``queued_demand()`` (the stealable,
        not-yet-dispatched backlog) — the PoolScaler's predictive wait
        signal prices exactly this, since running tasks keep their slots
        regardless of how many pilots exist."""
        with self._cv:
            return dict(self._kind_queued)

    def queued_demand(self) -> int:
        """Slots demanded by queued-but-not-dispatched tasks (the stealable
        backlog).  An O(1) counter read maintained at enqueue / dispatch /
        steal, so PoolScaler ticks and steal-victim sorting no longer scan
        the wait heap under the scheduler's condition variable.  A task
        that turns terminal while queued keeps its slots counted until the
        next scheduling pass or steal sweeps it — the same staleness
        window ``_demand_slots`` (load()) has always had."""
        with self._cv:
            return max(0, self._queued_slots)

    def queued_task_kinds(self) -> List[Tuple[Tuple[str, ...], int]]:
        """One entry per queued-but-not-dispatched task: (the identifiers
        it routes under — kind, pre-translation app kind, resource kind —
        deduplicated, None dropped; its slot demand).  The PoolScaler
        aggregates these across pilots into the starving-queue signal the
        placement policy's ``pick_template`` matches against when more
        than one scale-up template is configured."""
        with self._cv:
            return [
                (tuple(dict.fromkeys(
                    k for k in (t.kind, t.app_kind, t.res_kind)
                    if k is not None)),
                 t.resources.slots)
                for _, _, t in self._wait if t.state not in TERMINAL]

    def oldest_queued_wait(self, now: Optional[float] = None) -> float:
        """Seconds the longest-waiting queued task has sat unscheduled —
        the PoolScaler's scale-up signal.  0.0 when the queue is empty."""
        now = now if now is not None else time.monotonic()
        with self._cv:
            ts = [t.timestamps.get("TRANSLATED",
                                   t.timestamps.get("NEW", now))
                  for _, _, t in self._wait if t.state not in TERMINAL]
        return max(0.0, now - min(ts)) if ts else 0.0

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Event-wait until every submitted task reached a terminal state
        (or was stolen away).  True if drained within the timeout."""
        with self._cv:
            return self._cv.wait_for(lambda: self._outstanding == 0, timeout)

    # ---------------------------- work stealing -------------------------- #
    def steal(self, pred: Optional[Callable[[TaskRecord], bool]] = None,
              max_tasks: Optional[int] = None,
              max_slots: Optional[int] = None
              ) -> List[Tuple[TaskRecord, Optional[Callable]]]:
        """Steal-safe queue extraction: atomically remove queued-but-not-
        dispatched tasks (latest-submitted first, classic steal-from-the-
        tail) together with their completion callbacks.

        Runs under the same condition variable `_schedule_pass` holds for a
        whole pass, so a task racing a dispatch is observed on exactly one
        side: still queued (stolen, never dispatched here) or already
        allocated (kept, never stolen).  Outstanding/demand counters move
        with the task, so `shutdown(wait=True)` and `load()` stay correct
        on the victim.  Sticky tasks and straggler replicas are never
        handed out (replicas' first-finisher-wins bookkeeping is pilot-
        local) — ``sticky`` is the *hard* eligibility pin enforced here,
        while soft placement-policy gates (e.g. LocalityAware's
        affinity-vs-imbalance test) arrive composed into ``pred`` by the
        pool; `pred=None` takes everything else (the drain path).
        """
        taken: List[Tuple[TaskRecord, Optional[Callable]]] = []
        with self._cv:
            if not self._wait and not (pred is None and self._delayed):
                return taken
            keep: List[Tuple[int, int, TaskRecord]] = []
            slots_left = max_slots if max_slots is not None else float("inf")
            # FIFO order is ascending (-priority, seq); walk the tail first
            for item in sorted(self._wait, reverse=True):
                _, _, t = item
                if t.state in TERMINAL:
                    # canceled while queued: settle in place, as the
                    # scheduling pass would have
                    self._done_cb.pop(t.uid, None)
                    self._outstanding -= 1
                    self._demand_slots -= t.resources.slots
                    self._queued_slots -= t.resources.slots
                    kind = model_kind(t)
                    self._kadd(self._kind_demand, kind, -t.resources.slots)
                    self._kadd(self._kind_queued, kind, -t.resources.slots)
                    continue
                eligible = (t.replica_of is None
                            and (pred is None
                                 or (not t.sticky and pred(t)))
                            and (max_tasks is None or len(taken) < max_tasks)
                            and t.resources.slots <= slots_left)
                if not eligible:
                    keep.append(item)
                    continue
                taken.append((t, self._done_cb.pop(t.uid, None)))
                slots_left -= t.resources.slots
                self._outstanding -= 1
                self._demand_slots -= t.resources.slots
                self._queued_slots -= t.resources.slots
                kind = model_kind(t)
                self._kadd(self._kind_demand, kind, -t.resources.slots)
                self._kadd(self._kind_queued, kind, -t.resources.slots)
            keep.sort()
            self._wait = keep                    # sorted list is a valid heap
            if pred is None and self._delayed:
                # the drain path must also sweep backoff-delayed retries —
                # moving to another pilot waives the remaining backoff
                # (delayed tasks are never in _queued_slots, so only the
                # outstanding/demand counters move)
                still: List[Tuple[float, int, TaskRecord]] = []
                for item in self._delayed:
                    _, _, t = item
                    if t.state in TERMINAL:
                        self._done_cb.pop(t.uid, None)
                        self._outstanding -= 1
                        self._demand_slots -= t.resources.slots
                        self._kadd(self._kind_demand, model_kind(t),
                                   -t.resources.slots)
                        continue
                    if ((max_tasks is not None and len(taken) >= max_tasks)
                            or t.resources.slots > slots_left):
                        still.append(item)
                        continue
                    taken.append((t, self._done_cb.pop(t.uid, None)))
                    slots_left -= t.resources.slots
                    self._outstanding -= 1
                    self._demand_slots -= t.resources.slots
                    self._kadd(self._kind_demand, model_kind(t),
                               -t.resources.slots)
                heapq.heapify(still)
                self._delayed = still
            if self._outstanding == 0:
                self._cv.notify_all()            # a shutdown wait may park
        return taken

    # ------------------------ cooperative preemption --------------------- #
    def preemptable_tasks(self, include_sticky: bool = False
                          ) -> List[TaskRecord]:
        """RUNNING tasks eligible for cooperative preempt-and-migrate:
        checkpointable (the saved step travels, so no work is lost), not
        ``sticky`` (the hard pin applies to running tasks too — except
        under ``include_sticky``, the drain path: a dying pilot cannot
        honor stickiness), not a replica and not a replicated leader
        (first-finisher-wins bookkeeping is pilot-local), and with no
        preempt already pending."""
        with self._cv:
            leaders = set(self._replicas.values())
            return [t for uid, t in self._running.items()
                    if t.checkpointable
                    and (include_sticky or not t.sticky)
                    and t.replica_of is None and uid not in leaders
                    and uid in self._ckpt_ctxs
                    and uid not in self._preempt_handoff
                    and t.state == TaskState.RUNNING]

    def preempt(self, uid: str, handoff: Callable) -> bool:
        """Request cooperative preemption of a RUNNING checkpointable
        task.  Its next ``ckpt.save`` persists the step and unwinds with
        ``TaskPreempted``; the agent then resets the task to TRANSLATED,
        moves its outstanding/demand counters off this agent (exactly
        like a queued steal), and calls ``handoff(task, done_cb)``
        outside all locks.  If the task instead reaches a normal finish
        first, the pending request is dropped and the handoff is called
        once with ``(None, None)`` so the requester can release whatever
        it reserved for the migration.  False when the task is not
        running here, has no live Checkpoint context yet, or a preempt
        is already pending — by construction a handed-off task always
        has a saved checkpoint (the raise happens *after* the save)."""
        with self._cv:
            t = self._running.get(uid)
            ctx = self._ckpt_ctxs.get(uid)
            if (t is None or ctx is None or t.replica_of is not None
                    or uid in self._preempt_handoff):
                return False
            self._preempt_handoff[uid] = handoff
        ctx.request_preempt()
        return True

    # --------------------------- scheduling ----------------------------- #
    def _on_capacity(self):
        """Scheduler listener: slots were released or grown — wake the loop."""
        with self._cv:
            self._dirty = True
            self._cv.notify_all()

    def _loop(self):
        while True:
            with self._cv:
                while (not self._dirty and not self._stop.is_set()
                       and not self._crashed):
                    # liveness beat: stamped only here and below, by the
                    # scheduler loop itself on every wakeup — a wedged or
                    # crashed loop goes visibly stale to the health monitor
                    self._beat = time.monotonic()
                    if self._delayed:
                        # bound the wait by the earliest backoff deadline:
                        # delayed-retry promotion is deadline-driven, not
                        # polled
                        wait_s = self._delayed[0][0] - time.monotonic()
                        if wait_s <= 0.0:
                            self._promote_delayed()
                            continue
                        self._cv.wait(wait_s)
                    else:
                        self._cv.wait()
                    self._promote_delayed()
                if self._stop.is_set() or self._crashed:
                    return
                self._dirty = False
                self._beat = time.monotonic()
            self._schedule_pass()
            self._maybe_request_work()

    def _promote_delayed(self):
        """Caller holds self._cv: move backoff-delayed retries whose
        ready time has arrived into the wait heap (and into the queued
        counters they were excluded from while parked)."""
        now = time.monotonic()
        while self._delayed and self._delayed[0][0] <= now:
            _, _, t = heapq.heappop(self._delayed)
            if t.state in TERMINAL:      # canceled while backing off
                self._done_cb.pop(t.uid, None)
                self._outstanding -= 1
                self._demand_slots -= t.resources.slots
                self._kadd(self._kind_demand, model_kind(t),
                           -t.resources.slots)
                if self._outstanding == 0:
                    self._cv.notify_all()
                continue
            heapq.heappush(self._wait,
                           (-t.resources.priority, self._seq, t))
            self._seq += 1
            self._queued_slots += t.resources.slots
            self._kadd(self._kind_queued, model_kind(t),
                       t.resources.slots)
            self._dirty = True

    def _maybe_request_work(self):
        """After a pass: if the wait heap is empty and slots are free, ask
        the pool for work.  Called with no locks held — the hook steals
        from a sibling agent (its cv) then submits here (our cv), and
        holding ours across that would invert the lock order."""
        cb = self.idle_cb
        if cb is None:
            return
        with self._cv:
            hungry = not self._wait and not self._stop.is_set()
        if hungry:
            free = self.scheduler.n_free
            if free > 0:
                cb(free)

    def _schedule_pass(self):
        with self._cv:
            window = []
            rest = []
            launched = False
            while self._wait and len(window) < self.backfill_window:
                window.append(heapq.heappop(self._wait))
            for item in window:
                _, _, t = item
                if t.state in TERMINAL:      # canceled while queued
                    self._outstanding -= 1
                    self._demand_slots -= t.resources.slots
                    self._queued_slots -= t.resources.slots
                    kind = model_kind(t)
                    self._kadd(self._kind_demand, kind, -t.resources.slots)
                    self._kadd(self._kind_queued, kind, -t.resources.slots)
                    if self._outstanding == 0:
                        self._cv.notify_all()
                    continue
                slots = self.scheduler.allocate(t.uid, t.resources.slots)
                if slots is None:
                    rest.append(item)        # backfill: keep trying later ones
                    continue
                t.slot_ids = slots
                self._queued_slots -= t.resources.slots
                self._kadd(self._kind_queued, model_kind(t),
                           -t.resources.slots)
                t.transition(TaskState.SCHEDULED, self.store)
                self._running[t.uid] = t
                self._dispatch(t)
                launched = True
            for item in rest:
                heapq.heappush(self._wait, item)
            if launched and self._wait:
                # progress was made and work remains: run another pass (a
                # blocked-only pass instead waits for a capacity event)
                self._dirty = True

    def _dispatch(self, task: TaskRecord):
        """Hand a scheduled task to the transport's worker pool (which
        grows lazily until it covers all claimed work, so tasks scheduled
        in one pass run concurrently).  Caller holds self._cv; the
        transport takes only its own pool lock and never calls back into
        the agent from under it, so the ordering is acyclic."""
        self.transport.dispatch(task)

    # ---------------------------- execution ----------------------------- #
    def _run_task(self, task: TaskRecord):
        task.transition(TaskState.LAUNCHING, self.store)
        if self.objectstore is not None:
            # deref ObjectRef inputs on the executing pilot: same-pilot
            # edges hand over the in-memory object (zero copies),
            # cross-pilot edges fetch once, cache, and count bytes_moved.
            # The overwrite is deliberate — a later retry re-ships values,
            # which is correct (the ref may be GC'd by then).
            task.args = materialize(task.args, self.objectstore,
                                    task.pilot_uid)
            task.kwargs = materialize(task.kwargs, self.objectstore,
                                      task.pilot_uid)
        ctx = None
        if task.checkpointable:
            ctx = Checkpoint(self.ckpt, task.ckpt_key or task.uid)
            task.ckpt_ctx = ctx         # the executor injects it as the
            with self._cv:              # body's ``ckpt`` kwarg
                self._ckpt_ctxs[task.uid] = ctx
        try:
            try:
                if task.kind == "spmd":
                    # materialize the sub-mesh + specialized callable now
                    # so LAUNCHING captures compile cost (the ibrun
                    # analog)...
                    mesh = self.executor.submesh(task.slot_ids,
                                                 task.resources.mesh_shape)
                task.transition(TaskState.RUNNING, self.store)
                t0 = time.monotonic()
                result = self.transport.execute(task)
                dt = time.monotonic() - t0
                if task.error is not None:     # slot failed mid-flight
                    raise task.error
            finally:
                # clear the context BEFORE any finish path can requeue or
                # hand off the task: its next run installs a fresh
                # context (possibly immediately, on another worker or
                # agent), and this worker must never clobber it
                if ctx is not None:
                    if task.ckpt_ctx is ctx:
                        task.ckpt_ctx = None
                    with self._cv:
                        if self._ckpt_ctxs.get(task.uid) is ctx:
                            del self._ckpt_ctxs[task.uid]
            if self.objectstore is not None:
                # publish once: at/above the store threshold the result
                # becomes an ObjectRef owned by this pilot; consumers
                # deref lazily (docs/dataplane.md)
                result = self.objectstore.maybe_publish(result,
                                                        task.pilot_uid)
            task.result = result
            self._finish(task, TaskState.DONE, dt)
        except TaskPreempted:
            self._preempt_finish(task)
        except BaseException as e:   # noqa: BLE001 — agent must survive
            task.error = e
            self._finish(task, TaskState.FAILED, None)

    def _finish(self, task: TaskRecord, state: TaskState, duration):
        self.scheduler.release(task.uid)      # fires _on_capacity listener
        with self._cv:
            self._running.pop(task.uid, None)
            handoff = self._preempt_handoff.pop(task.uid, None)
            if duration is not None:
                self._durations.append(duration)
            orig_uid = self._replicas.pop(task.uid, None)
        if handoff is not None:
            # a pending preempt was overtaken by a normal finish: notify
            # the requester with (None, None) so it can release whatever
            # it reserved for the migration (e.g. the pool's in-flight
            # preempt budget for the thief)
            handoff(None, None)

        if task.state == TaskState.CANCELED:
            # a replica already answered for this task and canceled it —
            # don't retry, don't overwrite CANCELED, don't re-fire callbacks
            if task.checkpointable:
                # GC any checkpoint this leader re-saved after the
                # winning replica's discard
                self.ckpt.discard(task.ckpt_key or task.uid)
            self._settle(task)
            return

        # replica bookkeeping — checked BEFORE the retry path: a FAILED
        # replica with retries remaining used to fall into the generic
        # retry requeue *after* its _replicas mapping was popped, turning
        # it into an ordinary task — first-finisher-wins bookkeeping was
        # lost, a later success canceled nothing, and it kept running
        # after the original completed.  Failed replicas are dropped,
        # never retried (the original is still running and retries on its
        # own terms).  First finisher wins, the loser is canceled; a
        # failed replica must NOT consume the original's callback.
        if orig_uid is not None:
            if state == TaskState.DONE:
                cb = self._done_cb.pop(orig_uid, None)
                with self._cv:
                    orig = self._running.get(orig_uid)
                    octx = self._ckpt_ctxs.get(orig_uid)
                task.transition(state, self.store)
                if cb is not None:
                    cb(task)
                if orig is not None:
                    orig.transition(TaskState.CANCELED, self.store)
                    if octx is not None:
                        # a checkpointing leader unwinds at its next save
                        # instead of grinding a canceled task to the end
                        octx.request_preempt()
                if task.checkpointable:
                    self.ckpt.discard(task.ckpt_key or orig_uid)
            else:
                task.transition(state, self.store)
            self._settle(task)
            return

        if state == TaskState.FAILED:
            err = task.error
            policy = task.retry_policy
            if isinstance(err, WorkerDied):
                # poison tracking: this attempt took a worker process down
                task.worker_deaths += 1
            fatal = policy is not None and policy.is_fatal(err)
            quarantined = (policy is not None
                           and policy.quarantine_after is not None
                           and task.worker_deaths >= policy.quarantine_after)
            if quarantined and not task.quarantined:
                # the task's attempts keep killing workers: fail it
                # terminally instead of respawn-storming the proc pool
                task.quarantined = True
                self.store.record_event(
                    EVENTS.QUARANTINED, uid=task.uid, pilot=task.pilot_uid,
                    worker_deaths=task.worker_deaths,
                    attempts=task.retries + 1,
                    error=repr(err)[:200] if err is not None else None)
            if (not fatal and not quarantined
                    and task.retries < task.max_retries):
                task.retries += 1
                if err is not None:
                    task.attempt_errors.append(err)   # history, not a wipe:
                                                      # the final failure
                                                      # chains all attempts
                task.error = None
                task.slot_ids = ()
                # a checkpointable retry resumes from its last saved step —
                # the checkpoint is only discarded on DONE
                task.transition(TaskState.TRANSLATED, self.store)
                reroute = self.reroute_cb
                if (reroute is not None and policy is not None
                        and policy.retry_different_pilot
                        and isinstance(err, _INFRA_ERRORS)):
                    # infrastructure fault: this pilot's workers/slots are
                    # suspect — hand the retry to the pool, which places
                    # it on a different pilot.  Hand off BEFORE
                    # decrementing (the _preempt_finish invariant): a
                    # drain observing outstanding == 0 must already see
                    # the task on its new pilot, never lose it between.
                    cb = self._done_cb.pop(task.uid, None)
                    with self._cv:
                        self._replicated.discard(task.uid)
                    reroute(task, cb)
                    with self._cv:
                        self._outstanding -= 1
                        self._demand_slots -= task.resources.slots
                        self._kadd(self._kind_demand, model_kind(task),
                                   -task.resources.slots)
                        if self._outstanding == 0:
                            self._cv.notify_all()
                    return
                delay = (policy.backoff_s(task.retries, task.uid)
                         if policy is not None else 0.0)
                with self._cv:                # requeue keeps it outstanding
                    self._replicated.discard(task.uid)   # fresh attempt:
                                                         # may straggle anew
                    if delay > 0.0:
                        # parked off the wait heap until the backoff
                        # deadline; the loop's cv wait is bounded by it
                        heapq.heappush(self._delayed,
                                       (time.monotonic() + delay,
                                        self._seq, task))
                    else:
                        heapq.heappush(
                            self._wait,
                            (-task.resources.priority, self._seq, task))
                        self._queued_slots += task.resources.slots
                        self._kadd(self._kind_queued, model_kind(task),
                                   task.resources.slots)
                        self._dirty = True
                    self._seq += 1
                    self._cv.notify_all()
                return
            if task.attempt_errors:
                # surface the whole history: earlier attempts become the
                # __cause__ ancestry of the final exception
                chain_attempt_errors(task)

        task.transition(state, self.store)
        if state == TaskState.DONE and task.checkpointable:
            self.ckpt.discard(task.ckpt_key or task.uid)   # payload GC
        cb = self._done_cb.pop(task.uid, None)
        if cb is not None:
            cb(task)
        self._settle(task)

    def _preempt_finish(self, task: TaskRecord):
        """A checkpointable body unwound with TaskPreempted: the step it
        just saved is durable, so the task is reset to TRANSLATED and
        either handed off (preempt-and-migrate / drain) or requeued
        locally.  Counters move with the task exactly as in steal()."""
        self.scheduler.release(task.uid)
        with self._cv:
            self._running.pop(task.uid, None)
            handoff = self._preempt_handoff.pop(task.uid, None)
            orig_uid = self._replicas.pop(task.uid, None)

        if task.state == TaskState.CANCELED or orig_uid is not None:
            # a canceled leader unwound early via the preempt flag (its
            # replica already answered and consumed the callback), or a
            # stray replica: settle quietly, and GC the checkpoint the
            # leader may have re-saved after the winner's discard
            if task.state != TaskState.CANCELED:
                task.transition(TaskState.CANCELED, self.store)
            if task.checkpointable:
                self.ckpt.discard(task.ckpt_key or task.uid)
            self._settle(task)
            return

        cb = self._done_cb.pop(task.uid, None)
        task.error = None
        task.slot_ids = ()
        task.transition(TaskState.TRANSLATED, self.store)
        if handoff is not None:
            # hand off BEFORE decrementing: a drain observing
            # outstanding == 0 must already see this task in its orphan
            # sweep, never lose it in the window between the two
            handoff(task, cb)
            with self._cv:
                self._outstanding -= 1
                self._demand_slots -= task.resources.slots
                self._kadd(self._kind_demand, model_kind(task),
                           -task.resources.slots)
                if self._outstanding == 0:
                    self._cv.notify_all()
            return
        # no handoff registered (the requester raced a drain or vanished):
        # requeue locally — the next pass or steal picks it up
        with self._cv:
            if cb is not None:
                self._done_cb[task.uid] = cb
            heapq.heappush(self._wait,
                           (-task.resources.priority, self._seq, task))
            self._seq += 1
            self._queued_slots += task.resources.slots
            self._kadd(self._kind_queued, model_kind(task),
                       task.resources.slots)
            self._dirty = True
            self._cv.notify_all()

    def _settle(self, task: TaskRecord):
        """One submitted record reached a terminal state."""
        with self._cv:
            self._replicated.discard(task.uid)
            self._outstanding -= 1
            self._demand_slots -= task.resources.slots
            self._kadd(self._kind_demand, model_kind(task),
                       -task.resources.slots)
            if self._outstanding == 0:
                self._cv.notify_all()

    # ----------------------------- monitor ------------------------------ #
    def _deadline(self, kind: Optional[str] = None) -> Optional[float]:
        """Straggler deadline in seconds, or None while too cold to judge.

        Per-kind first (the tentpole fix): with ``kind`` given and enough
        samples in the store's duration model, the deadline is
        ``max(floor, factor x mean, mean + k x stdev)`` of *that kind's*
        population — so one fast kind's flood can no longer drag the
        global p95 below a slow kind's normal runtime and spawn spurious
        replicas (replica churn burns slots the cost model then
        mis-reads).  Cold kinds — and ``per_kind_deadlines=False`` — fall
        back to the original global recent-p95 x factor."""
        if kind is not None and self.per_kind_deadlines:
            stats = self.store.duration_stats(kind)
            if stats is not None and stats[2] >= self.straggler_min_samples:
                mean, var, _n = stats
                return max(self.straggler_min_deadline,
                           mean * self.straggler_factor,
                           mean + self.straggler_stdev_k * var ** 0.5)
        with self._cv:
            if len(self._durations) < self.straggler_min_samples:
                return None
            # slice the deque (most recent 100) BEFORE sorting: sorting
            # first and then slicing took the 100 *largest* of up to 256
            # samples — once the deque exceeded 100 entries the "p95"
            # drifted toward the all-time max, inflating the straggler
            # deadline until replicas effectively stopped firing
            xs = sorted(list(self._durations)[-100:])
            p95 = xs[max(0, int(len(xs) * 0.95) - 1)]
            # floor: now that the p95 tracks recent (possibly sub-ms)
            # durations again, micro-task workloads would otherwise trip
            # deadlines shorter than the monitor's own sampling cadence —
            # a replica there costs more than the task it duplicates
            return max(p95 * self.straggler_factor,
                       self.straggler_min_deadline)

    def _monitor(self):
        # stop-event wait, not a sleep: exits promptly on shutdown and never
        # touches the submit->schedule->complete path.
        while not self._stop.wait(self.monitor_interval):
            if self._crashed:
                return               # the pilot "died": no replicas either
            now = time.monotonic()
            with self._cv:
                running = [
                    t for t in self._running.values()
                    if t.state == TaskState.RUNNING
                    and t.uid not in self._replicated
                    and t.replica_of is None
                    and t.uid not in self._preempt_handoff]
            # one deadline per kind per tick (duration-model read, outside
            # the cv): each task is judged against its own population
            dl_by_kind: Dict[str, Optional[float]] = {}
            for t in running:
                kind = model_kind(t)
                if kind not in dl_by_kind:
                    dl_by_kind[kind] = self._deadline(kind)
                dl = dl_by_kind[kind]
                if (dl is not None
                        and now - t.timestamps.get("RUNNING", now) > dl
                        and self.scheduler.n_free >= t.resources.slots):
                    self._spawn_replica(t)

    def _spawn_replica(self, t: TaskRecord) -> TaskRecord:
        """Submit a straggler replica of a RUNNING task.  The record
        keeps every stamp the translator put on the original — sticky,
        affinity, res/app kind, pilot binding — so the replica's journal
        and placement records match the original's (they used to be
        dropped, so replica records lost the translator's stamps).
        Sharing ``ckpt_key`` is what makes replicas checkpoint-based:
        the replica's ``ckpt.restore()`` picks up the leader's latest
        saved step and resumes there instead of recomputing from 0.

        One replica per original per run attempt (``_replicated``): a
        replica that fails instantly must not trigger a respawn storm —
        the deadline would re-trip on every monitor tick for as long as
        the leader keeps running.  The marker clears if the original
        itself fails and requeues (a fresh attempt may straggle anew)."""
        rep = TaskRecord(
            uid=new_uid("replica"), kind=t.kind, fn=t.fn,
            args=t.args, kwargs=t.kwargs, resources=t.resources,
            replica_of=t.uid, res_kind=t.res_kind, app_kind=t.app_kind,
            pilot_uid=t.pilot_uid, sticky=t.sticky, affinity=t.affinity,
            affinity_bytes=t.affinity_bytes,
            max_retries=t.max_retries,
            checkpointable=t.checkpointable,
            ckpt_key=t.ckpt_key or t.uid)
        with self._cv:
            self._replicas[rep.uid] = t.uid
            self._replicated.add(t.uid)
        rep.transition(TaskState.TRANSLATED, self.store)
        if not self.submit(rep):
            # the agent stopped accepting (drain/stop) between the
            # deadline check and here: roll the bookkeeping back, or the
            # stale _replicas entry would mark the leader as replicated
            # forever — e.g. excluding it from the drain's own
            # preempt-and-handback sweep
            with self._cv:
                self._replicas.pop(rep.uid, None)
                self._replicated.discard(t.uid)
        return rep

    # ------------------------------ stats ------------------------------- #
    def utilization_timeline(self):
        """Per-task state intervals for the Fig.6-style breakdown, derived
        from the StateStore's unified event stream."""
        return self.store.timeline()
