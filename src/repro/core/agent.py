"""Agent — the RP Agent analog: scheduler loop + dispatcher on the pilot.

A single scheduling thread pulls translated tasks from the inbox into a
priority/FIFO wait queue, allocates slot blocks (with bounded backfill:
later small tasks may run ahead of a blocked large task, never starving it),
and hands each scheduled task to a worker thread (the MPI-Master/Worker
analog) that drives the SPMD executor.  A separate monitor thread implements
straggler mitigation (soft-deadline replicas) and retry-on-failure.

All state transitions are timestamped through the StateStore so the
Fig.6-style utilization breakdown (Scheduled/Launching/Running/Idle) can be
integrated offline.
"""
from __future__ import annotations

import heapq
import queue
import threading
import time
import traceback
from typing import Callable, Dict, List, Optional, Tuple

from .futures import TERMINAL, ResourceSpec, TaskRecord, TaskState, new_uid
from .scheduler import SlotScheduler
from .spmd_executor import SPMDFunctionExecutor
from .store import StateStore


class Agent:
    def __init__(self, scheduler: SlotScheduler,
                 executor: SPMDFunctionExecutor,
                 store: Optional[StateStore] = None,
                 max_workers: int = 32,
                 backfill_window: int = 16,
                 straggler_factor: float = 3.0,
                 straggler_min_samples: int = 5,
                 poll_interval: float = 0.002):
        self.scheduler = scheduler
        self.executor = executor
        self.store = store or StateStore()
        self.backfill_window = backfill_window
        self.straggler_factor = straggler_factor
        self.straggler_min_samples = straggler_min_samples
        self.poll = poll_interval

        self.inbox: "queue.Queue[TaskRecord]" = queue.Queue()
        self._wait: List[Tuple[int, int, TaskRecord]] = []   # heap
        self._seq = 0
        self._running: Dict[str, TaskRecord] = {}
        self._replicas: Dict[str, str] = {}                  # replica -> orig
        self._done_cb: Dict[str, Callable] = {}
        self._durations: List[float] = []
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._sem = threading.Semaphore(max_workers)
        self._threads: List[threading.Thread] = []
        self._sched_thread = threading.Thread(target=self._loop, daemon=True)
        self._mon_thread = threading.Thread(target=self._monitor, daemon=True)
        self._started = False

    # ------------------------------ api -------------------------------- #
    def start(self):
        if not self._started:
            self._started = True
            self._sched_thread.start()
            self._mon_thread.start()
        return self

    def submit(self, task: TaskRecord, done_cb: Optional[Callable] = None):
        if done_cb is not None:
            self._done_cb[task.uid] = done_cb
        self.inbox.put(task)

    def submit_bulk(self, tasks, done_cb: Optional[Callable] = None):
        """Bulk submission (the paper's named future work): one inbox
        operation for a whole batch, cutting per-task queue overhead."""
        for t in tasks:
            if done_cb is not None:
                self._done_cb[t.uid] = done_cb
        for t in tasks:
            self.inbox.put(t)

    def shutdown(self, wait: bool = True, timeout: float = 60.0):
        if wait:
            deadline = time.monotonic() + timeout
            while time.monotonic() < deadline:
                with self._lock:
                    idle = not self._wait and not self._running
                if idle and self.inbox.empty():
                    break
                time.sleep(self.poll)
        self._stop.set()

    def inject_slot_failure(self, slots):
        """Simulate node failure: victims are FAILED then retried elsewhere."""
        victims = self.scheduler.mark_failed(slots)
        with self._lock:
            for uid in victims:
                t = self._running.get(uid)
                if t is not None:
                    t.error = RuntimeError(f"slot failure on {slots}")
        return victims

    # --------------------------- scheduling ----------------------------- #
    def _loop(self):
        while not self._stop.is_set():
            moved = False
            try:
                while True:
                    t = self.inbox.get_nowait()
                    with self._lock:
                        heapq.heappush(self._wait,
                                       (-t.resources.priority, self._seq, t))
                        self._seq += 1
                    moved = True
            except queue.Empty:
                pass
            launched = self._try_schedule()
            if not moved and not launched:
                time.sleep(self.poll)

    def _try_schedule(self) -> bool:
        launched = False
        with self._lock:
            window = []
            rest = []
            while self._wait and len(window) < self.backfill_window:
                window.append(heapq.heappop(self._wait))
            for item in window:
                _, _, t = item
                if t.state in TERMINAL:      # canceled while queued
                    continue
                slots = self.scheduler.allocate(t.uid, t.resources.slots)
                if slots is None:
                    rest.append(item)        # backfill: keep trying later ones
                    continue
                t.slot_ids = slots
                t.transition(TaskState.SCHEDULED, self.store)
                self._running[t.uid] = t
                th = threading.Thread(target=self._run_task, args=(t,),
                                      daemon=True)
                self._threads.append(th)
                th.start()
                launched = True
            for item in rest:
                heapq.heappush(self._wait, item)
        return launched

    # ---------------------------- execution ----------------------------- #
    def _run_task(self, task: TaskRecord):
        with self._sem:
            task.transition(TaskState.LAUNCHING, self.store)
            try:
                if task.kind == "spmd":
                    # materialize the sub-mesh + specialized callable now so
                    # LAUNCHING captures compile cost (the ibrun analog)...
                    mesh = self.executor.submesh(task.slot_ids,
                                                 task.resources.mesh_shape)
                task.transition(TaskState.RUNNING, self.store)
                t0 = time.monotonic()
                result = self.executor.execute(task)
                dt = time.monotonic() - t0
                if task.error is not None:     # slot failed mid-flight
                    raise task.error
                task.result = result
                self._finish(task, TaskState.DONE, dt)
            except BaseException as e:   # noqa: BLE001 — agent must survive
                task.error = e
                self._finish(task, TaskState.FAILED, None)

    def _finish(self, task: TaskRecord, state: TaskState, duration):
        self.scheduler.release(task.uid)
        with self._lock:
            self._running.pop(task.uid, None)
            if duration is not None:
                self._durations.append(duration)
            orig_uid = self._replicas.pop(task.uid, None)

        if state == TaskState.FAILED and task.retries < task.max_retries:
            task.retries += 1
            task.error = None
            task.slot_ids = ()
            task.transition(TaskState.TRANSLATED, self.store)
            self.inbox.put(task)
            return

        # replica bookkeeping: first finisher wins, loser is canceled
        if orig_uid is not None:
            cb = self._done_cb.pop(orig_uid, None)
            with self._lock:
                orig = self._running.get(orig_uid)
            if state == TaskState.DONE and cb is not None:
                task.transition(state, self.store)
                cb(task)
                if orig is not None:
                    orig.transition(TaskState.CANCELED, self.store)
                return
            task.transition(state, self.store)
            return

        task.transition(state, self.store)
        cb = self._done_cb.pop(task.uid, None)
        if cb is not None:
            cb(task)

    # ----------------------------- monitor ------------------------------ #
    def _deadline(self) -> Optional[float]:
        with self._lock:
            if len(self._durations) < self.straggler_min_samples:
                return None
            xs = sorted(self._durations)[-100:]
            p95 = xs[max(0, int(len(xs) * 0.95) - 1)]
            return p95 * self.straggler_factor

    def _monitor(self):
        while not self._stop.is_set():
            time.sleep(self.poll * 10)
            dl = self._deadline()
            if dl is None:
                continue
            now = time.monotonic()
            with self._lock:
                candidates = [
                    t for t in self._running.values()
                    if t.state == TaskState.RUNNING
                    and t.uid not in self._replicas.values()
                    and t.replica_of is None
                    and now - t.timestamps.get("RUNNING", now) > dl
                    and self.scheduler.n_free >= t.resources.slots]
            for t in candidates:
                rep = TaskRecord(
                    uid=new_uid("replica"), kind=t.kind, fn=t.fn,
                    args=t.args, kwargs=t.kwargs, resources=t.resources,
                    replica_of=t.uid)
                with self._lock:
                    self._replicas[rep.uid] = t.uid
                rep.transition(TaskState.TRANSLATED, self.store)
                self.inbox.put(rep)

    # ------------------------------ stats ------------------------------- #
    def utilization_timeline(self):
        """Per-task state intervals for the Fig.6-style breakdown."""
        return {uid: dict(t.timestamps)
                for uid, t in list(self._running.items())}
