"""Pilot abstraction — client-side managers (the RP split kept intact).

PilotManager acquires *pilots* (device blocks held for the workload's
lifetime — on a real cluster, a jax.distributed slice; here, the process's
device set, virtualized into slots).  TaskManager submits translated tasks
to a pilot's Agent and tracks their futures.  The separation mirrors RP:
managers run client-side, the Agent runs "on the resource".
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax

from .agent import Agent
from .futures import ResourceSpec, TaskRecord, TaskState, new_uid
from .scheduler import SlotScheduler
from .spmd_executor import SPMDFunctionExecutor
from .store import StateStore


@dataclass
class PilotDescription:
    n_slots: int = 0                  # 0 = one slot per visible device
    devices: Optional[list] = None    # explicit device set (sub-pilot)
    journal: Optional[str] = None     # StateStore journal path (restart)
    max_workers: int = 32
    cache_executables: bool = True
    backfill_window: int = 16
    straggler_factor: float = 3.0


class Pilot:
    def __init__(self, desc: PilotDescription, uid: Optional[str] = None):
        self.uid = uid or new_uid("pilot")
        self.desc = desc
        devices = desc.devices if desc.devices is not None else jax.devices()
        n = desc.n_slots or len(devices)
        self.scheduler = SlotScheduler(n)
        self.executor = SPMDFunctionExecutor(devices,
                                             cache=desc.cache_executables)
        self.store = StateStore(desc.journal)
        self.agent = Agent(self.scheduler, self.executor, self.store,
                           max_workers=desc.max_workers,
                           backfill_window=desc.backfill_window,
                           straggler_factor=desc.straggler_factor).start()
        self.t_start = time.monotonic()

    # elastic scaling --------------------------------------------------- #
    def grow(self, n_slots: int):
        return self.scheduler.grow(n_slots)

    def shrink(self, n_slots: int):
        return self.scheduler.shrink(n_slots)

    @property
    def n_slots(self) -> int:
        return self.scheduler.capacity

    def close(self):
        self.agent.shutdown()
        self.store.close()


class PilotManager:
    def __init__(self):
        self.pilots: Dict[str, Pilot] = {}

    def submit_pilot(self, desc: PilotDescription) -> Pilot:
        p = Pilot(desc)
        self.pilots[p.uid] = p
        return p

    def cancel(self, uid: str):
        p = self.pilots.pop(uid, None)
        if p:
            p.close()

    def close(self):
        for uid in list(self.pilots):
            self.cancel(uid)


class TaskManager:
    """Submits task descriptions to a pilot's agent; tracks completion."""

    def __init__(self, pilot: Pilot):
        self.pilot = pilot
        self.tasks: Dict[str, TaskRecord] = {}
        self._events: Dict[str, threading.Event] = {}

    def submit(self, task: TaskRecord,
               done_cb: Optional[Callable] = None) -> TaskRecord:
        self.tasks[task.uid] = task
        ev = threading.Event()
        self._events[task.uid] = ev

        def _cb(t: TaskRecord):
            ev.set()
            if done_cb is not None:
                done_cb(t)

        task.transition(TaskState.TRANSLATED, self.pilot.store)
        self.pilot.agent.submit(task, done_cb=_cb)
        return task

    def submit_bulk(self, tasks: List[TaskRecord],
                    done_cb: Optional[Callable] = None) -> List[TaskRecord]:
        for t in tasks:
            self.tasks[t.uid] = t
            ev = threading.Event()
            self._events[t.uid] = ev
            t.transition(TaskState.TRANSLATED, self.pilot.store)
        if done_cb is None:
            self.pilot.agent.submit_bulk(tasks,
                                         done_cb=lambda t: self._events[
                                             t.uid if t.replica_of is None
                                             else t.replica_of].set())
        else:
            def _cb(t: TaskRecord):
                uid = t.uid if t.replica_of is None else t.replica_of
                self._events[uid].set()
                done_cb(t)
            self.pilot.agent.submit_bulk(tasks, done_cb=_cb)
        return tasks

    def wait(self, uids=None, timeout: Optional[float] = None) -> bool:
        uids = uids if uids is not None else list(self._events)
        deadline = None if timeout is None else time.monotonic() + timeout
        for uid in uids:
            ev = self._events.get(uid)
            if ev is None:
                continue
            t = None if deadline is None else max(0.0,
                                                  deadline - time.monotonic())
            if not ev.wait(t):
                return False
        return True
