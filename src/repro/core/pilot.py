"""Pilot abstraction — client-side managers (the RP split kept intact).

PilotManager acquires *pilots* (device blocks held for the workload's
lifetime — on a real cluster, a jax.distributed slice; here, the process's
device set, virtualized into slots).  TaskManager submits translated tasks
to a pilot's Agent and tracks their futures.  The separation mirrors RP:
managers run client-side, the Agent runs "on the resource".

Heterogeneous resources enter through the PilotPool: a pool owns N pilots
with distinct PilotDescriptions (e.g. a CPU pilot for pre/post-processing
Python tasks and a device pilot for SPMD tasks).  Each description may
restrict the task kinds it accepts; the TaskManager *late-binds* every
translated task to a compatible pilot at submission time — the paper's
"heterogeneous tasks on heterogeneous resources" claim made operational.

*Which* compatible pilot is a policy question, and since PR 4 the pool
delegates it to a pluggable ``PlacementPolicy`` (see placement.py):
routing (``route``/``route_bulk``), steal-victim ordering and per-task
steal eligibility (``request_work``), and scaler template choice all ask
the policy.  ``LeastLoaded`` (the default) reproduces the PR-2 behavior
exactly; ``LocalityAware`` scores data affinity against load.

Since PR 2 the binding is no longer immutable: the pool is an active load
balancer.  When a pilot's agent goes hungry (empty wait heap, free slots)
its ``idle_cb`` asks the pool for work and the pool *steals* queued-but-
not-dispatched compatible tasks from a policy-ordered victim, re-stamping
``pilot_uid`` and emitting a STOLEN event so TaskManager bookkeeping and
journal replay stay correct.  A PoolScaler can additionally grow and
shrink the pilot set itself: it watches the unified StateStore event
streams, spawns a new pilot from a template description when queue wait
exceeds a threshold (multi-template: the policy picks the template whose
kinds match the starving queue), and drains + retires idle pilots
(PILOT_RETIRE).

Pilots are mortal (docs/resilience.md): with heartbeat supervision
enabled (``heartbeat_timeout_s``) a pool health monitor watches every
agent's liveness beat — scheduler-loop progress, probed with ``ping`` —
and declares a silent pilot LOST (``mark_lost``): a ``PILOT_LOST`` event
is journaled like PILOT_RETIRE, queued tasks re-route to survivors via
the orphan path, RUNNING checkpointable tasks re-adopt their last
durable checkpoint on the new pilot, non-checkpointable RUNNING tasks
FAIL visibly into the retry path, and the PoolScaler's replace-on-loss
trigger restores the lost capacity from a template.  Infrastructure-
failed retries (``RetryPolicy.retry_different_pilot``) also arrive here,
re-placed on a different pilot than the one whose worker or slot just
failed.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple, Union

import jax

from .agent import Agent
from .checkpoint import CheckpointStore
from .faults import PilotLost
from .futures import (ResourceSpec, TaskRecord, TaskState,
                      chain_attempt_errors, new_uid)
from .objectstore import ObjectStore
from .placement import PlacementPolicy, filter_healthy, resolve_policy
from .scheduler import SlotScheduler
from .spmd_executor import SPMDFunctionExecutor
from .store import EVENTS, StateStore
from .transport import make_transport


@dataclass
class PilotDescription:
    n_slots: int = 0                  # 0 = one slot per visible device
    devices: Optional[list] = None    # explicit device set (sub-pilot)
    journal: Optional[str] = None     # StateStore journal path (restart)
    max_workers: int = 32
    cache_executables: bool = True
    backfill_window: int = 16
    straggler_factor: float = 3.0
    straggler_stdev_k: float = 4.0    # per-kind deadline spread multiplier:
                                      # deadline = max(floor, factor*mean,
                                      # mean + k*stdev) of the kind's EWMAs
    per_kind_deadlines: bool = True   # False = PR-6 global-p95 deadlines
                                      # (the knob the mixed-kind straggler
                                      # regression test pins the bug with)
    kinds: Optional[Tuple[str, ...]] = None  # accepted task/resource kinds
                                             # (e.g. ("python", "bash") or
                                             # ("spmd",)); None = accept all
    name: Optional[str] = None        # human-readable pilot label
    transport: str = "inproc"         # worker transport: "inproc" (thread
                                      # pool, default) or "proc" (worker
                                      # OS processes — python/bash bodies
                                      # run off the GIL; spmd stays local)
    worker_idle_s: float = 30.0       # pool threads idle longer than this
                                      # reap themselves (bounded pool)
    proc_start_method: Optional[str] = None  # "fork" (default) | "spawn"
    shm_threshold: Optional[int] = 256 * 1024
                                      # proc transport: ndarray args/results
                                      # at/above this size cross the worker
                                      # boundary via shared memory instead
                                      # of the pickle pipe (None disables —
                                      # the exp11 baseline)


class Pilot:
    def __init__(self, desc: PilotDescription, uid: Optional[str] = None):
        self.uid = uid or new_uid(desc.name or "pilot")
        self.desc = desc
        devices = desc.devices if desc.devices is not None else jax.devices()
        n = desc.n_slots or len(devices)
        self.scheduler = SlotScheduler(n)
        self.executor = SPMDFunctionExecutor(devices,
                                             cache=desc.cache_executables)
        self.store = StateStore(desc.journal)
        self.ckpt = CheckpointStore(self.store)   # replays CHECKPOINT
        self.agent = Agent(self.scheduler, self.executor, self.store,
                           max_workers=desc.max_workers,
                           backfill_window=desc.backfill_window,
                           straggler_factor=desc.straggler_factor,
                           straggler_stdev_k=desc.straggler_stdev_k,
                           per_kind_deadlines=desc.per_kind_deadlines,
                           ckpt_store=self.ckpt,
                           transport=make_transport(
                               desc.transport, desc.max_workers,
                               idle_s=desc.worker_idle_s,
                               start_method=desc.proc_start_method,
                               shm_threshold=desc.shm_threshold)).start()
        self.objectstore = None   # pool-wired data plane (docs/dataplane.md)
        self.t_start = time.monotonic()
        self.draining = False     # a draining pilot accepts no new work
        self.lost = False         # declared LOST by health supervision:
                                  # close() must not wait on its zombies
        self._closed = False
        self.store.record_event(EVENTS.PILOT_START, pilot=self.uid, n_slots=n,
                                kinds=list(desc.kinds or ()) or None,
                                transport=desc.transport)

    # routing ----------------------------------------------------------- #
    def accepts(self, task: TaskRecord) -> bool:
        """Compatible iff the description accepts the task's kind, its
        pre-translation app kind (bash apps execute as kind="python"), or
        its stamped resource kind (None = accepts everything).  A draining
        pilot accepts nothing."""
        if self.draining:
            return False
        if self.desc.kinds is None:
            return True
        return any(k is not None and k in self.desc.kinds
                   for k in (task.kind, task.app_kind, task.res_kind))

    def load(self) -> float:
        """Demanded slots (queued + running) / capacity — the least-loaded
        routing metric."""
        return self.agent.load() / max(1, self.scheduler.capacity)

    def predicted_queue_wait(self) -> float:
        """Predicted seconds to absorb the *queued* backlog: each queued
        kind's slots priced at the duration model's EWMA mean for that
        kind (pilot-mixture fallback), spread over capacity.  0.0 with an
        empty queue — and 0.0 for kinds the model has never seen, so a
        cold pilot contributes nothing and the PoolScaler's observed-wait
        signal remains the effective floor."""
        queued = self.agent.queued_by_kind()
        if not queued:
            return 0.0
        total = 0.0
        for kind, slots in queued.items():
            st = (self.store.duration_stats(kind)
                  or self.store.duration_stats(None))
            if st is not None:
                total += slots * st[0]
        return total / max(1, self.scheduler.capacity)

    # elastic scaling --------------------------------------------------- #
    def grow(self, n_slots: int):
        self.store.record_event(EVENTS.GROW, pilot=self.uid, n=n_slots)
        return self.scheduler.grow(n_slots)

    def shrink(self, n_slots: int):
        self.store.record_event(EVENTS.SHRINK, pilot=self.uid, n=n_slots)
        return self.scheduler.shrink(n_slots)

    @property
    def n_slots(self) -> int:
        return self.scheduler.capacity

    # ----------------------------- retirement --------------------------- #
    def drain(self, timeout: float = 30.0
              ) -> List[Tuple[TaskRecord, Optional[Callable]]]:
        """Stop accepting, hand back queued tasks, finish (or preempt)
        running tasks, then close.  Returns the orphaned (task, done_cb)
        pairs for the caller to re-route elsewhere.

        RUNNING *checkpointable* tasks are cooperatively preempted: each
        unwinds at its next checkpoint boundary and joins the orphans, so
        a retiring pilot hands back partial work that resumes from its
        saved step elsewhere instead of grinding long tasks to the end.
        Tasks that fail mid-drain (e.g. an injected slot failure) requeue
        into the wait heap with no capacity left to run them, so the wait
        loop keeps sweeping the heap into the orphan list until the agent
        is empty — the pilot retires even under faults."""
        self.draining = True
        # barrier: refuse submissions from here on, so a steal racing this
        # drain is rejected (and re-placed by the pool) instead of landing
        # a task after the final sweep on an agent that will never run it
        self.agent.stop_accepting()
        preempted: List[Tuple[TaskRecord, Optional[Callable]]] = []
        plock = threading.Lock()
        collecting = [True]

        def _collect(task, cb):
            if task is None:
                return      # preempt request overtaken by a normal finish
            with plock:
                if collecting[0]:
                    preempted.append((task, cb))
                    return
            # the drain timed out and already returned: nobody will ever
            # read the orphan list, so fail the task visibly through its
            # callback rather than letting its future hang forever
            task.error = RuntimeError(
                f"pilot {self.uid} retired while task {task.uid} was "
                f"preempting")
            task.transition(TaskState.FAILED, self.store)
            if cb is not None:
                cb(task)

        orphans = list(self.agent.steal())
        # include_sticky: like the queued drain sweep, a dying pilot
        # cannot honor stickiness
        for t in self.agent.preemptable_tasks(include_sticky=True):
            self.agent.preempt(t.uid, _collect)
        deadline = time.monotonic() + timeout
        while not self.agent.wait_idle(timeout=0.1):
            orphans += self.agent.steal()
            for t in self.agent.preemptable_tasks(include_sticky=True):
                self.agent.preempt(t.uid, _collect)   # late starters
            if time.monotonic() > deadline:
                break
        with plock:
            collecting[0] = False
            orphans += preempted
        drained = self.agent.wait_idle(timeout=0)
        self.agent.shutdown(wait=False)
        self.store.record_event(EVENTS.PILOT_RETIRE, pilot=self.uid,
                                drained=drained)
        self.store.close()
        self._closed = True
        return orphans

    def close(self):
        if self._closed:
            return
        self._closed = True
        self.draining = True
        # a LOST pilot's outstanding count never drains (its zombie
        # bodies settle against CANCELED records, hung ones never do) —
        # don't park the pool close on it
        self.agent.shutdown(wait=not self.lost)
        self.store.close()


def _recovery_clone(task: TaskRecord) -> TaskRecord:
    """Fresh record (same uid) for re-running a task recovered from a
    LOST pilot: the zombie body may still be executing on the lost
    pilot's workers and mutating the original record, so the survivor's
    attempt must share no mutable state with it.  The zombie's eventual
    finish settles against the CANCELED original and fires no callback
    (abandon_running popped it)."""
    return TaskRecord(
        uid=task.uid, kind=task.kind, fn=task.fn, args=task.args,
        kwargs=dict(task.kwargs), resources=task.resources,
        timestamps=dict(task.timestamps),
        depends_on=list(task.depends_on),
        retries=task.retries, max_retries=task.max_retries,
        retry_policy=task.retry_policy,
        attempt_errors=list(task.attempt_errors),
        worker_deaths=task.worker_deaths,
        res_kind=task.res_kind, app_kind=task.app_kind,
        pilot_uid=task.pilot_uid, sticky=task.sticky,
        affinity=task.affinity, affinity_bytes=task.affinity_bytes,
        checkpointable=task.checkpointable,
        ckpt_key=task.ckpt_key, inproc_only=task.inproc_only)


class PilotPool:
    """N pilots with heterogeneous descriptions + kind-aware late binding.

    The pool is also the steal coordinator and the elastic-membership
    authority: agents' idle hooks call ``request_work`` to migrate queued
    tasks off a policy-ordered victim, ``add_pilot``/``retire`` grow and
    shrink the pilot set at runtime, and migrate hooks let the TaskManager
    keep its bookkeeping (journal keys, task map) correct when a task's
    pilot binding changes after submission.  The pool is pure mechanism:
    every *which pilot* decision is delegated to ``self.policy``."""

    def __init__(self,
                 descs: Optional[Sequence[PilotDescription]] = None,
                 pilots: Optional[Sequence[Pilot]] = None,
                 steal: bool = True,
                 preempt: bool = True,
                 policy: Union[None, str, PlacementPolicy] = None,
                 heartbeat_timeout_s: Optional[float] = None,
                 heartbeat_interval_s: Optional[float] = None,
                 data_plane: bool = True,
                 data_threshold: Optional[int] = None):
        if pilots is None and descs is None:
            descs = [PilotDescription()]
        self.pilots: List[Pilot] = (list(pilots) if pilots is not None
                                    else [Pilot(d) for d in descs])
        if not self.pilots:
            raise ValueError("PilotPool needs at least one pilot")
        # the pool-wide data plane (docs/dataplane.md): task results at or
        # above the threshold are published once as ObjectRefs; spilled
        # blobs live next to the first journaled pilot's journal so they
        # survive restart with it
        self.objectstore: Optional[ObjectStore] = None
        if data_plane:
            spill = next((p.desc.journal + ".obj" for p in self.pilots
                          if p.desc.journal), None)
            self.objectstore = ObjectStore(
                spill_dir=spill,
                **({"threshold": data_threshold}
                   if data_threshold is not None else {}))
        self.retired: List[Pilot] = []
        self.steal_enabled = steal
        # preempt-and-migrate rides on the steal machinery: when a
        # queued-only pass finds nothing, a RUNNING checkpointable task
        # may be cooperatively preempted and resumed on the thief
        self.preempt_enabled = preempt
        self._preempt_inflight: Dict[str, int] = {}   # thief uid -> slots
                                                      # requested, not yet
                                                      # arrived
        self.policy = resolve_policy(policy)
        self._lock = threading.RLock()
        self._migrate_hooks: List[Callable] = []
        self._closed = False
        self._lost_pending: List[str] = []   # LOST, not yet replaced —
                                             # PoolScaler's replace-on-
                                             # loss trigger consumes it
        # heartbeat supervision: with a timeout set, a monitor thread
        # probes every agent's liveness beat (ping + stale-age judgment)
        # and declares silent pilots LOST.  None (default) disables it.
        self._hb_timeout = heartbeat_timeout_s
        self._hb_interval = (heartbeat_interval_s
                             if heartbeat_interval_s is not None
                             else (heartbeat_timeout_s / 4.0
                                   if heartbeat_timeout_s else None))
        self._hb_stop = threading.Event()
        self._hb_thread: Optional[threading.Thread] = None
        for p in self.pilots:
            self._wire(p)
        if self._hb_timeout:
            self._hb_thread = threading.Thread(target=self._health_loop,
                                               daemon=True)
            self._hb_thread.start()

    def _wire(self, p: Pilot):
        if self.objectstore is not None:
            # one shared store: agents publish/materialize through it, the
            # journal spills through it, checkpoints dedupe against it
            p.objectstore = self.objectstore
            p.agent.objectstore = self.objectstore
            p.store.objectstore = self.objectstore
            p.ckpt.objectstore = self.objectstore
        if self.steal_enabled:
            p.agent.idle_cb = (
                lambda free, _p=p: self.request_work(_p, free))
        # infrastructure-failed retries prefer a different pilot: the
        # agent's retry classifier hands the attempt here instead of
        # requeueing it on the pilot whose worker or slot just failed
        p.agent.reroute_cb = (
            lambda task, cb, _p=p: self._reroute_retry(_p, task, cb))

    def __len__(self):
        with self._lock:
            return len(self.pilots)

    def active(self) -> List[Pilot]:
        with self._lock:
            return list(self.pilots)

    def all_pilots(self) -> List[Pilot]:
        """Active + retired — journal lookups and event queries must cover
        pilots that no longer exist."""
        with self._lock:
            return list(self.pilots) + list(self.retired)

    def by_uid(self, uid: str) -> Optional[Pilot]:
        return next((p for p in self.all_pilots() if p.uid == uid), None)

    def _compatible(self, task: TaskRecord) -> List[Pilot]:
        pilots = self.active()
        compat = [p for p in pilots if p.accepts(task)]
        if not compat:
            raise RuntimeError(
                f"no pilot accepts task {task.uid} "
                f"(kind={task.kind!r}, res_kind={task.res_kind!r}; pool "
                f"kinds={[p.desc.kinds for p in pilots]!r})")
        # prefer pilots whose heartbeat is fresh — a crashed or silent
        # pilot is a bad destination even before the monitor formally
        # declares it LOST.  Fall back to the unfiltered set rather than
        # refusing: mark_lost will re-route whatever lands badly.
        healthy = filter_healthy(compat, self._hb_timeout)
        return healthy or compat

    def route(self, task: TaskRecord) -> Pilot:
        """The policy's pick among pilots whose description accepts the
        task (least-loaded under the default policy)."""
        return self.policy.place(task, self._compatible(task))

    def route_bulk(self, tasks: Sequence[TaskRecord]
                   ) -> List[Union[Pilot, Exception]]:
        """Greedy policy assignment for a whole batch: the running load
        estimate includes the demand routed earlier in this batch, so a
        bulk submission spreads across compatible pilots instead of
        piling onto whichever was idle when the batch arrived.  An
        unroutable task yields its RuntimeError in place of a pilot, so
        one bad task never aborts the rest of the batch."""
        pilots = self.active()
        loads = {p.uid: p.load() for p in pilots}
        caps = {p.uid: max(1, p.scheduler.capacity) for p in pilots}
        items: List[Tuple[TaskRecord, object]] = []
        for t in tasks:
            try:
                items.append((t, self._compatible(t)))
            except RuntimeError as e:
                items.append((t, e))
        return self.policy.place_bulk(items, loads, caps)

    # --------------------------- work stealing -------------------------- #
    def add_migrate_hook(self, cb: Callable):
        """cb(task, src_pilot, dst_pilot) fires for every migrated task,
        after pilot_uid is re-stamped and before resubmission — the
        TaskManager uses it to re-record journal keys on the new pilot."""
        with self._lock:
            self._migrate_hooks.append(cb)

    def _migrate(self, task: TaskRecord, src: Pilot, dst: Pilot,
                 cb: Optional[Callable], reason: str,
                 _depth: int = 0) -> bool:
        """Move one task to dst; True iff dst actually accepted it.  The
        migrate hooks run *before* submission (the journal-key record must
        land on dst before the task can complete there), but the STOLEN
        event is only emitted for accepted migrations, so event counts
        never overstate what moved."""
        task.pilot_uid = dst.uid
        with self._lock:
            hooks = list(self._migrate_hooks)
        for h in hooks:
            h(task, src, dst)
        if task.checkpointable:
            # the checkpoint travels with the task: the destination store
            # adopts the newest snapshot (wherever a previous migration
            # left it) so ``ckpt.restore()`` works there, and every other
            # pilot drops its copy — a move, not a copy, so victim
            # journals and payload dirs never accumulate checkpoints of
            # tasks that long since migrated away
            self.ensure_checkpoint(task, dst)
        if not dst.agent.submit(task, done_cb=cb):
            # dst began draining/closing between routing and submission —
            # the agent refused rather than heaping the task, so place it
            # somewhere else (or fail it visibly if nowhere is left)
            self._place_orphan(task, cb, src, reason, _depth + 1)
            return False
        dst.store.record_event(EVENTS.STOLEN, uid=task.uid, src=src.uid,
                               dst=dst.uid, reason=reason)
        return True

    def _place_orphan(self, task: TaskRecord, cb: Optional[Callable],
                      src: Pilot, reason: str, _depth: int = 0):
        """Route a task displaced by a drain (or a refused migration) onto
        a surviving pilot — preferring pilots whose capacity can actually
        fit it, so an oversized orphan is not parked on a pilot that could
        only ever run it after a grow().  Fails the task through its
        callback when no pilot accepts it or every candidate refuses."""
        err: Optional[Exception] = None
        if _depth <= len(self.all_pilots()) + 2:
            try:
                cands = self._compatible(task)
                fitting = [p for p in cands
                           if task.resources.slots <= p.scheduler.capacity]
                dst = self.policy.place(task, fitting or cands)
                self._migrate(task, src, dst, cb, reason, _depth)
                return
            except RuntimeError as e:
                err = e
        task.error = err or RuntimeError(
            f"no pilot could take displaced task {task.uid}")
        chain_attempt_errors(task)
        task.transition(TaskState.FAILED)
        if cb is not None:
            cb(task)

    def request_work(self, thief: Pilot, free_slots: Optional[int] = None
                     ) -> int:
        """Steal queued-but-not-dispatched tasks from policy-ordered
        victims into ``thief`` (most-loaded first under the default
        policy).  Each candidate task additionally passes the policy's
        per-task ``steal_eligible`` gate — a LocalityAware policy only
        migrates a data-affine task when the victim's backlog-per-slot
        (the imbalance) beats the affinity penalty, while the hard
        ``sticky`` stamp is enforced by Agent.steal itself.  Returns
        slots' worth of work moved.  Called from agents' idle hooks
        (outside any agent lock) and from the PoolScaler."""
        if self._closed or thief.draining:
            return 0
        free = (free_slots if free_slots is not None
                else thief.scheduler.n_free)
        if free <= 0:
            return 0
        with self._lock:
            cands = [p for p in self.pilots if p is not thief]
        # snapshot demands once: queued_demand scans the victim's wait
        # heap under its cv, so don't re-pay it in the sort key and again
        # per loop iteration
        demand = {p.uid: p.agent.queued_demand() for p in cands}
        moved = 0
        for victim in self.policy.pick_victim(thief, cands, demand):
            if moved >= free:
                break
            if demand.get(victim.uid, 0) == 0:
                continue    # policy orders victims; don't assume sorted
            imbalance = (demand[victim.uid]
                         / max(1, victim.scheduler.capacity))
            batch = victim.agent.steal(
                pred=lambda t, _th=thief, _v=victim, _imb=imbalance: (
                    _th.accepts(t)
                    and t.resources.slots <= _th.scheduler.capacity
                    and self.policy.steal_eligible(t, _th, _v, _imb)),
                max_slots=free - moved)
            for task, cb in batch:
                if self._migrate(task, victim, thief, cb, reason="steal"):
                    moved += task.resources.slots
        if moved == 0 and self.preempt_enabled:
            # queued-only pass found nothing movable: fall through to
            # preempt-and-migrate — a RUNNING checkpointable task can be
            # re-bound mid-flight, resuming from its saved step here
            moved += self._request_preempt(thief, free)
        return moved

    def _reserve_preempt(self, uid: str, n: int, free: int) -> bool:
        """Atomically reserve ``n`` slots of ``uid``'s preempt budget;
        False when concurrent requests already consumed it.  The check
        and the increment share one lock section — a stale read here
        would let an idle hook racing a scaler tick over-preempt past
        the thief's free capacity."""
        with self._lock:
            cur = self._preempt_inflight.get(uid, 0)
            if n > free - cur:
                return False
            self._preempt_inflight[uid] = cur + n
        return True

    def _release_preempt(self, uid: str, n: int):
        with self._lock:
            left = self._preempt_inflight.get(uid, 0) - n
            if left > 0:
                self._preempt_inflight[uid] = left
            else:
                self._preempt_inflight.pop(uid, None)

    def _request_preempt(self, thief: Pilot, free: int) -> int:
        """Pick one RUNNING checkpoint-eligible task (policy-chosen;
        sticky/replica exclusion enforced by the victim's agent) and
        request cooperative preemption: the task unwinds at its next
        checkpoint boundary and the handoff migrates it to ``thief``,
        where it resumes from the step it saved.  Returns the slots'
        worth of work *requested* — arrival is asynchronous, so an
        in-flight counter keeps repeated idle callbacks from preempting
        more work than the thief can hold."""
        with self._lock:
            inflight = self._preempt_inflight.get(thief.uid, 0)
            cands_p = [p for p in self.pilots
                       if p is not thief and not p.draining]
        budget = free - inflight
        if budget <= 0:
            return 0
        cands: List[Tuple[TaskRecord, Pilot]] = []
        loads: Dict[str, float] = {}
        for victim in cands_p:
            # preemption only pays when the victim has *queued* demand to
            # flow into the freed slots (queued yet unstolen means it is
            # pinned there: sticky, kind-incompatible, or affinity-gated).
            # Without backlog, moving a running task is pure thrash — and
            # two idle pilots would ping-pong it between them forever.
            queued = victim.agent.queued_demand()
            if queued <= 0:
                continue
            # the same imbalance currency steal_eligible is specified in:
            # queued backlog per slot of capacity (total demand would
            # count the candidate task itself and over-permit affine
            # moves the queued-steal gate refuses)
            loads[victim.uid] = queued / max(1, victim.scheduler.capacity)
            for t in victim.agent.preemptable_tasks():
                if (thief.accepts(t)
                        and t.resources.slots <= budget
                        and t.resources.slots <= thief.scheduler.capacity):
                    cands.append((t, victim))
        if not cands:
            return 0
        pick = self.policy.pick_preempt(thief, cands, loads)
        if pick is None:
            return 0
        task, victim = pick
        slots = task.resources.slots

        def handoff(t, cb, _v=victim, _th=thief, _n=slots):
            self._release_preempt(_th.uid, _n)
            if t is None:
                return      # request overtaken by a normal finish: the
                            # budget above is released, nothing migrates
            self._migrate(t, _v, _th, cb, reason="preempt")

        if not self._reserve_preempt(thief.uid, slots, free):
            return 0        # a concurrent request consumed the budget
        if not victim.agent.preempt(task.uid, handoff):
            self._release_preempt(thief.uid, slots)
            return 0
        return slots

    def rebalance(self) -> int:
        """Pull work to every hungry pilot (free slots, empty wait heap) —
        the PoolScaler's periodic safety net for idle hooks that fired
        before any sibling had a backlog."""
        moved = 0
        for p in self.active():
            if p.draining:
                continue
            free = p.scheduler.n_free
            if free > 0 and p.agent.queued_demand() == 0:
                moved += self.request_work(p, free)
        return moved

    # ------------------------- elastic membership ------------------------ #
    def add_pilot(self, desc: PilotDescription,
                  seed_durations: bool = True) -> Pilot:
        """Spawn a pilot into the live pool (records PILOT_START).

        The newcomer's duration model is seeded cross-pilot by kind from
        its siblings' observations (n-weighted merge), so an elastically
        spawned pilot makes cost-model decisions — placement pricing,
        per-kind straggler deadlines, predictive scaling — from its first
        task instead of re-learning what the fleet already measured."""
        with self._lock:
            if self._closed:
                raise RuntimeError("pool is closed")
            siblings = list(self.pilots)
            p = Pilot(desc)
            self.pilots.append(p)
        if seed_durations:
            for s in siblings:
                for kind, (mean, var, n) in s.store.duration_model().items():
                    p.store.seed_durations(kind, mean, var, n)
        self._wire(p)
        return p

    def retire(self, pilot: Pilot, timeout: float = 30.0) -> bool:
        """Drain + retire a pilot: it stops accepting work, its queued
        tasks migrate to the surviving pilots, running tasks finish, then
        it closes (records PILOT_RETIRE).  The last pilot never retires."""
        with self._lock:
            if pilot not in self.pilots or len(self.pilots) <= 1:
                return False
            self.pilots.remove(pilot)
            self.retired.append(pilot)
        orphans = pilot.drain(timeout=timeout)
        for task, cb in orphans:
            self._place_orphan(task, cb, pilot, reason="drain")
        self._rehost_objects(pilot)
        return True

    # -------------------------- failure domains -------------------------- #
    def mark_lost(self, pilot: Pilot, reason: str = "missed-heartbeat"
                  ) -> bool:
        """Declare a pilot LOST and recover its work onto the survivors.

        Unlike ``retire`` there is no drain: the pilot is presumed dead
        (crashed loop, silent heartbeat), so its agent is halted, queued
        tasks are stolen wholesale onto survivors, and RUNNING tasks are
        abandoned — checkpointable ones re-adopt their last durable
        snapshot on a new pilot, the rest consume a retry or fail with a
        PilotLost chained into their attempt history.  The PILOT_LOST
        event lands in the lost pilot's own journal (like PILOT_RETIRE)
        so replay after a restart sees the loss.  Returns False when the
        pilot is not an active member (already lost/retired) or the pool
        is closed."""
        with self._lock:
            if self._closed or pilot not in self.pilots:
                return False
            self.pilots.remove(pilot)
            self.retired.append(pilot)
            self._lost_pending.append(pilot.uid)
        pilot.lost = True
        pilot.draining = True
        pilot.agent.stop_accepting()
        pilot.agent.halt()
        # queued first (pred=None also sweeps the backoff-delayed heap),
        # then the abandoned RUNNING set — their zombie bodies settle
        # quietly because abandon_running already CANCELed the records
        queued = pilot.agent.steal()
        abandoned = pilot.agent.abandon_running()
        pilot.store.record_event(EVENTS.PILOT_LOST, pilot=pilot.uid,
                                 reason=reason, queued=len(queued),
                                 running=len(abandoned))
        for task, cb in queued:
            self._place_orphan(task, cb, pilot, reason="pilot-lost")
        for task, cb in abandoned:
            self._recover_running(task, cb, pilot)
        self._rehost_objects(pilot)
        return True

    def _rehost_objects(self, departed: Pilot):
        """Hand a departing pilot's live objects to a survivor.

        Published results the departed pilot owned stay dereferenceable:
        in-memory copies (and disk spills) live in the pool-shared store,
        so re-hosting is an ownership transfer — the survivor becomes the
        locality anchor for future byte-weighted placement and transfer
        accounting (docs/dataplane.md)."""
        if self.objectstore is None:
            return
        with self._lock:
            survivor = next((p for p in self.pilots
                             if not p.draining and not p.lost), None)
        if survivor is not None:
            n = self.objectstore.rehost(departed.uid, survivor.uid)
            if n:
                survivor.store.record_event(
                    EVENTS.OBJECTS_REHOSTED, pilot=survivor.uid,
                    src=departed.uid, objects=n)

    def _recover_running(self, task: TaskRecord, cb: Optional[Callable],
                         src: Pilot):
        """Recover one task that was RUNNING when its pilot was lost.

        The original record was CANCELed by ``abandon_running`` (its
        zombie body may still be executing in a dead worker); recovery
        operates on a fresh clone with the *same uid* so journal keys,
        checkpoint keys, and caller futures all stay valid while nothing
        mutable is shared with the zombie.  A checkpointable task resumes
        from its last durable snapshot without consuming a retry — the
        work survived, only the pilot died.  A non-checkpointable task
        lost real progress: the PilotLost counts against its retry
        budget, or fails it terminally with the full attempt history
        chained."""
        clone = _recovery_clone(task)
        err = PilotLost(
            f"pilot {src.uid} lost while {task.uid} was running")
        if clone.checkpointable:
            clone.transition(TaskState.TRANSLATED)
            self._place_orphan(clone, cb, src, reason="pilot-lost")
            return
        clone.attempt_errors.append(err)
        policy = clone.retry_policy
        fatal = policy is not None and policy.is_fatal(err)
        if not fatal and clone.retries < clone.max_retries:
            clone.retries += 1
            clone.transition(TaskState.TRANSLATED)
            self._place_orphan(clone, cb, src, reason="pilot-lost")
            return
        clone.error = err
        chain_attempt_errors(clone)
        clone.transition(TaskState.FAILED, src.store)
        if cb is not None:
            cb(clone)

    def _reroute_retry(self, src: Pilot, task: TaskRecord,
                       cb: Optional[Callable]):
        """Place an infrastructure-failed retry on a *different* pilot.

        The agent's retry classifier calls this (via ``reroute_cb``) for
        WorkerDied / PilotLost / SlotFailure attempts whose RetryPolicy
        asks for ``retry_different_pilot``: the pilot whose worker just
        died is the worst candidate for the next attempt.  Falls back to
        the orphan path (which may land back on ``src``) when no other
        pilot is compatible."""
        try:
            cands = [p for p in self._compatible(task) if p is not src]
        except RuntimeError:
            cands = []
        if cands:
            fitting = [p for p in cands
                       if task.resources.slots <= p.scheduler.capacity]
            dst = self.policy.place(task, fitting or cands)
            self._migrate(task, src, dst, cb, reason="retry")
        else:
            self._place_orphan(task, cb, src, reason="retry")

    def take_lost(self) -> List[str]:
        """Drain the pending lost-pilot uids (PoolScaler's replace-on-loss
        trigger reads this exactly once per loss)."""
        with self._lock:
            pending, self._lost_pending = self._lost_pending, []
            return pending

    def _health_loop(self):
        """Heartbeat monitor: ping agents whose beat is merely stale (a
        healthy loop re-stamps on wake, so the next probe sees a fresh
        beat) and declare LOST those that crashed or stayed silent past
        the full timeout."""
        while not self._hb_stop.wait(self._hb_interval):
            for p in self.active():
                if p.draining:
                    continue
                a = p.agent
                if a.crashed:
                    self.mark_lost(p, reason="crash")
                    continue
                age = time.monotonic() - a.last_beat
                if age > self._hb_timeout:
                    self.mark_lost(p, reason="missed-heartbeat")
                elif age > self._hb_interval:
                    a.ping()

    # ----------------------------- checkpoints --------------------------- #
    def checkpoint_step(self, key: str) -> Optional[int]:
        """Latest checkpointed step for ``key`` across every pilot's
        CheckpointStore — including retired pilots, since a migrated
        task's checkpoint lives wherever it last ran.  None when no
        checkpoint is recorded anywhere (payloads are not touched)."""
        steps = [s for p in self.all_pilots()
                 for s in [p.ckpt.step(key)] if s is not None]
        return max(steps) if steps else None

    def ensure_checkpoint(self, task: TaskRecord, dst: Pilot):
        """*Move* the newest checkpoint for the task to ``dst``: every
        other pilot's copy is adopted (max step wins — ``adopt`` keeps
        the newer side) and then discarded.  Used by migrations and by
        the restart path (a journal-replayed checkpoint may live on a
        different pilot than the one the task now routes to).  Move
        semantics keep exactly one live copy pool-wide, so completion
        GC on the final pilot retires the key everywhere and victim
        journals never accumulate stale snapshots."""
        if not task.checkpointable:
            return
        key = task.ckpt_key or task.uid
        others = [p for p in self.all_pilots() if p is not dst]
        for p in others:
            dst.ckpt.adopt(key, p.ckpt)
        for p in others:
            p.ckpt.discard(key)

    # ------------------------------ queries ------------------------------ #
    def utilization(self) -> Dict[str, float]:
        """Per-pilot busy-slot fraction across the (possibly changed)
        pilot set, keyed by pilot uid; retired pilots report 0.0."""
        return {p.uid: p.scheduler.utilization() for p in self.all_pilots()}

    def events(self) -> List[dict]:
        """Unified event stream merged across all pilots' stores,
        including retired pilots."""
        out = []
        for p in self.all_pilots():
            for e in p.store.events_snapshot():
                out.append({**e, "pilot": e.get("pilot") or p.uid})
        return sorted(out, key=lambda e: e["t"])

    def close(self):
        with self._lock:
            if self._closed:
                return
            self._closed = True
            ps = list(self.pilots) + list(self.retired)
        self._hb_stop.set()
        if self._hb_thread is not None:
            self._hb_thread.join(timeout=5.0)
        for p in ps:
            p.close()
        if self.objectstore is not None:
            self.objectstore.close()


@dataclass
class ScalerConfig:
    """PoolScaler knobs (see docs/elasticity.md, docs/placement.md).

    template          — PilotDescription cloned for every spawned pilot
                        (journal paths get a per-spawn suffix)
    templates         — multi-template scaling: the candidate descriptions
                        a scale-up chooses among; the pool's placement
                        policy picks the one whose ``kinds`` cover the
                        most starving queued demand (None = [template])
    min_pilots        — never retire below this many pilots
    max_pilots        — never spawn beyond this many pilots
    scale_up_wait_s   — spawn when the queue-wait signal exceeds this:
                        the *predicted* wait to absorb a pilot's queued
                        backlog (duration model, see docs/scheduling.md)
                        or the observed wait of its oldest queued task,
                        whichever is larger — so a long queue of slow
                        work triggers the spawn the moment it is priced,
                        not after the threshold has already been wasted
    predictive        — False restores the pure observed-wait signal
                        (PR-6 behavior); the duration model is then
                        ignored by scaling decisions
    scale_down_idle_s — retire a pilot idle (no running or queued work)
                        for this long
    spawn_cooldown_s  — minimum time between spawns, so one long queue
                        does not burst to max_pilots before the first new
                        pilot can absorb work
    interval_s        — fallback watch cadence; the scaler is otherwise
                        woken by StateStore events
    retire_spawned_only — only retire pilots the scaler itself spawned
                        (user-configured pilots are never drained)
    """
    template: PilotDescription = field(default_factory=PilotDescription)
    templates: Optional[List[PilotDescription]] = None
    min_pilots: int = 1
    max_pilots: int = 4
    scale_up_wait_s: float = 0.25
    predictive: bool = True
    scale_down_idle_s: float = 1.0
    spawn_cooldown_s: float = 0.5
    interval_s: float = 0.05
    retire_spawned_only: bool = True


class PoolScaler:
    """Elastic autoscaler: grows and shrinks the *pilot set* (not just
    slots) under load.  Watches the pools' unified StateStore event
    streams — every appended event kicks the scaler awake — and each tick
    (1) rebalances queued work onto hungry pilots, (2) spawns a pilot from
    the template when queue wait exceeds the threshold, (3) drains and
    retires pilots idle past the threshold."""

    def __init__(self, pool: PilotPool, config: Optional[ScalerConfig] = None):
        self.pool = pool
        self.cfg = config or ScalerConfig()
        self.decisions: List[dict] = []     # audit log of scale actions
        self._kick = threading.Event()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._spawned: Set[str] = set()
        self._idle_since: Dict[str, float] = {}
        self._watched: Set[int] = set()
        self._last_spawn = 0.0

    def start(self) -> "PoolScaler":
        self._attach()
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        self._kick.set()
        if self._thread.is_alive():
            self._thread.join(timeout=5.0)

    # ------------------------------ loop -------------------------------- #
    def _attach(self):
        """Subscribe to every pilot's event stream (idempotent; newly
        spawned pilots are picked up on the next tick)."""
        for p in self.pool.active():
            if id(p.store) not in self._watched:
                self._watched.add(id(p.store))
                p.store.add_listener(lambda _rec: self._kick.set())

    def _loop(self):
        while not self._stop.is_set():
            self._kick.wait(self.cfg.interval_s)
            self._kick.clear()
            if self._stop.is_set():
                return
            try:
                self._tick()
            except Exception as e:   # noqa: BLE001 — the scaler must never
                # take down the runtime; record the fault and keep watching
                self.decisions.append({"action": "error", "error": repr(e),
                                       "t": time.monotonic()})

    def _tick(self):
        self._attach()
        self.pool.rebalance()       # stealing first: it is always cheaper
        now = time.monotonic()      # than spawning a pilot

        # replace-on-loss: a LOST pilot's capacity is restored from a
        # template immediately — loss is not load, so the trigger bypasses
        # the spawn cooldown and the queue-wait threshold.  The template
        # choice still goes through the placement policy so a lost GPU
        # pilot is replaced by one whose kinds cover the starving demand.
        for lost_uid in self.pool.take_lost():
            if len(self.pool) >= self.cfg.max_pilots:
                self.decisions.append({"action": "replace_lost_skipped",
                                       "lost": lost_uid,
                                       "reason": "max_pilots", "t": now})
                continue
            starving = [kd for p in self.pool.active()
                        for kd in p.agent.queued_task_kinds()]
            template = self.pool.policy.pick_template(
                starving, self.cfg.templates or [self.cfg.template])
            p = self.pool.add_pilot(self._spawn_desc(template))
            self._spawned.add(p.uid)
            self._last_spawn = now
            self.decisions.append({"action": "replace_lost",
                                   "lost": lost_uid, "pilot": p.uid,
                                   "template": template.name, "t": now})
            self.pool.request_work(p, p.scheduler.n_free)

        pilots = self.pool.active()

        # scale up: the queue-wait signal passed the threshold even after
        # rebalancing, so no existing pilot can absorb the backlog soon.
        # The signal is *predicted* wait (queued slots priced by the
        # duration model) — a 50-task queue of known-slow work trips the
        # threshold immediately instead of after scale_up_wait_s of
        # already-wasted waiting — floored by the observed wait of the
        # oldest queued task, which covers cold models.  Which template
        # spawns is a placement decision: the policy picks the one whose
        # kinds cover the most starving queued demand.
        wait = max((self._wait_signal(p, now) for p in pilots),
                   default=0.0)
        if (wait > self.cfg.scale_up_wait_s
                and len(pilots) < self.cfg.max_pilots
                and now - self._last_spawn >= self.cfg.spawn_cooldown_s):
            starving = [kd for p in pilots
                        for kd in p.agent.queued_task_kinds()]
            template = self.pool.policy.pick_template(
                starving, self.cfg.templates or [self.cfg.template])
            p = self.pool.add_pilot(self._spawn_desc(template))
            self._spawned.add(p.uid)
            self._last_spawn = now
            self.decisions.append({"action": "scale_up", "pilot": p.uid,
                                   "template": template.name,
                                   "kinds": list(template.kinds or ())
                                   or None,
                                   "queue_wait_s": wait, "t": now})
            self.pool.request_work(p, p.scheduler.n_free)

        # scale down: drain + retire pilots idle past the threshold
        for p in pilots:
            if p.draining:
                continue
            if p.load() > 0:
                self._idle_since.pop(p.uid, None)
                continue
            since = self._idle_since.setdefault(p.uid, now)
            if (now - since >= self.cfg.scale_down_idle_s
                    and len(self.pool) > self.cfg.min_pilots
                    and (not self.cfg.retire_spawned_only
                         or p.uid in self._spawned)):
                if self.pool.retire(p):
                    self._idle_since.pop(p.uid, None)
                    self.decisions.append({"action": "retire",
                                           "pilot": p.uid, "t": now})

    def _wait_signal(self, p: Pilot, now: float) -> float:
        """Scale-up pressure from one pilot, in seconds of queue wait."""
        observed = p.agent.oldest_queued_wait(now)
        if not self.cfg.predictive:
            return observed
        return max(observed, p.predicted_queue_wait())

    def _spawn_desc(self, template: Optional[PilotDescription] = None
                    ) -> PilotDescription:
        d = template if template is not None else self.cfg.template
        n = len(self._spawned)
        return dataclasses.replace(
            d,
            name=f"{d.name or 'elastic'}{n}",
            journal=f"{d.journal}.{n}" if d.journal else None)


class PilotManager:
    def __init__(self):
        self.pilots: Dict[str, Pilot] = {}

    def submit_pilot(self, desc: PilotDescription) -> Pilot:
        p = Pilot(desc)
        self.pilots[p.uid] = p
        return p

    def submit_pilots(self, descs: Sequence[PilotDescription],
                      steal: bool = True,
                      preempt: bool = True,
                      policy: Union[None, str, PlacementPolicy] = None,
                      heartbeat_timeout_s: Optional[float] = None,
                      data_plane: bool = True,
                      data_threshold: Optional[int] = None
                      ) -> PilotPool:
        pool = PilotPool(descs=descs, steal=steal, preempt=preempt,
                         policy=policy,
                         heartbeat_timeout_s=heartbeat_timeout_s,
                         data_plane=data_plane,
                         data_threshold=data_threshold)
        for p in pool.pilots:
            self.pilots[p.uid] = p
        return pool

    def cancel(self, uid: str):
        p = self.pilots.pop(uid, None)
        if p:
            p.close()

    def close(self):
        for uid in list(self.pilots):
            self.cancel(uid)


class TaskManager:
    """Routes task descriptions to pilots' agents; tracks completion with a
    single condition variable (an event wait, not a per-task poll)."""

    def __init__(self, pool: Union[PilotPool, Pilot]):
        if isinstance(pool, Pilot):
            pool = PilotPool(pilots=[pool])
        self.pool = pool
        self.tasks: Dict[str, TaskRecord] = {}
        self._cv = threading.Condition()
        self._done: Set[str] = set()
        self._outstanding = 0
        self._wf_keys: Dict[str, str] = {}
        # keep journal replay correct under work stealing: when a task
        # migrates, its record (with the workflow key) must land on the
        # pilot that will actually run it
        self.pool.add_migrate_hook(self._on_migrate)

    def _on_migrate(self, task: TaskRecord, src: Pilot, dst: Pilot):
        key = self._wf_keys.get(task.uid)
        if key is not None:
            dst.store.record(task, workflow_key=key)

    @property
    def pilot(self) -> Pilot:
        """The primary pilot (single-pilot compatibility accessor)."""
        return self.pool.pilots[0]

    # ---------------------------- submission ---------------------------- #
    def _completion_cb(self, done_cb: Optional[Callable]):
        def _cb(t: TaskRecord):
            uid = t.uid if t.replica_of is None else t.replica_of
            self._wf_keys.pop(uid, None)    # terminal: migrations are over
            with self._cv:
                if uid not in self._done:
                    self._done.add(uid)
                    self._outstanding -= 1
                    self._cv.notify_all()
            if done_cb is not None:
                done_cb(t)
        return _cb

    def _bind(self, task: TaskRecord,
              workflow_key: Optional[str] = None,
              pilot: Optional[Pilot] = None) -> Pilot:
        """Late-bind a task to the least-loaded compatible pilot."""
        pilot = pilot if pilot is not None else self.pool.route(task)
        task.pilot_uid = pilot.uid
        self.tasks[task.uid] = task
        pilot.store.record_event(EVENTS.ROUTED, uid=task.uid, pilot=pilot.uid,
                                 kind=task.kind)
        if workflow_key is not None:
            self._wf_keys[task.uid] = workflow_key
            if task.checkpointable:
                # checkpoints of keyed tasks use the stable workflow key,
                # so a restarted run's re-submission (fresh uid) resumes
                # the interrupted task from its last saved step; the
                # routed pilot adopts the newest snapshot wherever the
                # last run left it
                if task.ckpt_key in (None, task.uid):
                    task.ckpt_key = workflow_key
                self.pool.ensure_checkpoint(task, pilot)
            pilot.store.record(task, workflow_key=workflow_key)
        return pilot

    def _fail_unroutable(self, task: TaskRecord, err: Exception,
                         done_cb: Optional[Callable]):
        """Resolve an unroutable task as FAILED through its callback — the
        submit path may run in a flush timer or dependency callback thread
        where a raised exception would be swallowed and hang the future."""
        task.error = err
        self.tasks[task.uid] = task
        task.transition(TaskState.FAILED)
        with self._cv:
            self._done.add(task.uid)
            self._cv.notify_all()        # a wait(uids=[...]) may be parked
        if done_cb is not None:
            done_cb(task)

    def submit(self, task: TaskRecord,
               done_cb: Optional[Callable] = None,
               workflow_key: Optional[str] = None) -> TaskRecord:
        cb = self._completion_cb(done_cb)
        # a routed pilot may start draining between route() and submit();
        # the agent then refuses instead of heaping the task, and we
        # simply route again (draining pilots are no longer compatible)
        for _ in range(len(self.pool.all_pilots()) + 2):
            try:
                pilot = self.pool.route(task)
            except RuntimeError as e:
                self._fail_unroutable(task, e, done_cb)
                return task
            self._bind(task, workflow_key, pilot=pilot)
            with self._cv:
                self._outstanding += 1
            task.transition(TaskState.TRANSLATED, pilot.store)
            if pilot.agent.submit(task, done_cb=cb):
                return task
            with self._cv:
                self._outstanding -= 1      # refused: unwind and retry
        self._fail_unroutable(
            task, RuntimeError(f"every pilot refused task {task.uid}"),
            done_cb)
        return task

    def submit_bulk(self, tasks: List[TaskRecord],
                    done_cb: Optional[Callable] = None,
                    workflow_keys: Optional[Dict[str, str]] = None
                    ) -> List[TaskRecord]:
        """One agent submission per pilot for a whole batch."""
        per_pilot: Dict[str, Tuple[Pilot, List[TaskRecord]]] = {}
        routed = 0
        for t, pilot in zip(tasks, self.pool.route_bulk(tasks)):
            if isinstance(pilot, Exception):
                self._fail_unroutable(t, pilot, done_cb)
                continue
            self._bind(t, (workflow_keys or {}).get(t.uid), pilot=pilot)
            per_pilot.setdefault(pilot.uid, (pilot, []))[1].append(t)
            t.transition(TaskState.TRANSLATED, pilot.store)
            routed += 1
        with self._cv:
            self._outstanding += routed
        cb = self._completion_cb(done_cb)
        for pilot, batch in per_pilot.values():
            if not pilot.agent.submit_bulk(batch, done_cb=cb):
                # the whole batch's pilot began draining mid-submission:
                # re-place each task on a surviving pilot
                for t in batch:
                    self.pool._place_orphan(t, cb, pilot, reason="reroute")
        return tasks

    # ------------------------------ waiting ------------------------------ #
    def wait(self, uids=None, timeout: Optional[float] = None) -> bool:
        """Block until the given (default: all) tasks complete — a single
        condition-variable wait, not a per-task Event scan."""
        with self._cv:
            if uids is None:
                return self._cv.wait_for(lambda: self._outstanding == 0,
                                         timeout)
            want = [u for u in uids if u in self.tasks]
            return self._cv.wait_for(
                lambda: all(u in self._done for u in want), timeout)
