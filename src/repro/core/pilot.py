"""Pilot abstraction — client-side managers (the RP split kept intact).

PilotManager acquires *pilots* (device blocks held for the workload's
lifetime — on a real cluster, a jax.distributed slice; here, the process's
device set, virtualized into slots).  TaskManager submits translated tasks
to a pilot's Agent and tracks their futures.  The separation mirrors RP:
managers run client-side, the Agent runs "on the resource".

Heterogeneous resources enter through the PilotPool: a pool owns N pilots
with distinct PilotDescriptions (e.g. a CPU pilot for pre/post-processing
Python tasks and a device pilot for SPMD tasks).  Each description may
restrict the task kinds it accepts; the TaskManager *late-binds* every
translated task to the least-loaded compatible pilot at submission time —
the paper's "heterogeneous tasks on heterogeneous resources" claim made
operational.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple, Union

import jax

from .agent import Agent
from .futures import ResourceSpec, TaskRecord, TaskState, new_uid
from .scheduler import SlotScheduler
from .spmd_executor import SPMDFunctionExecutor
from .store import StateStore


@dataclass
class PilotDescription:
    n_slots: int = 0                  # 0 = one slot per visible device
    devices: Optional[list] = None    # explicit device set (sub-pilot)
    journal: Optional[str] = None     # StateStore journal path (restart)
    max_workers: int = 32
    cache_executables: bool = True
    backfill_window: int = 16
    straggler_factor: float = 3.0
    kinds: Optional[Tuple[str, ...]] = None  # accepted task/resource kinds
                                             # (e.g. ("python", "bash") or
                                             # ("spmd",)); None = accept all
    name: Optional[str] = None        # human-readable pilot label


class Pilot:
    def __init__(self, desc: PilotDescription, uid: Optional[str] = None):
        self.uid = uid or new_uid(desc.name or "pilot")
        self.desc = desc
        devices = desc.devices if desc.devices is not None else jax.devices()
        n = desc.n_slots or len(devices)
        self.scheduler = SlotScheduler(n)
        self.executor = SPMDFunctionExecutor(devices,
                                             cache=desc.cache_executables)
        self.store = StateStore(desc.journal)
        self.agent = Agent(self.scheduler, self.executor, self.store,
                           max_workers=desc.max_workers,
                           backfill_window=desc.backfill_window,
                           straggler_factor=desc.straggler_factor).start()
        self.t_start = time.monotonic()
        self.store.record_event("PILOT_START", pilot=self.uid, n_slots=n,
                                kinds=list(desc.kinds or ()) or None)

    # routing ----------------------------------------------------------- #
    def accepts(self, task: TaskRecord) -> bool:
        """Compatible iff the description accepts the task's kind, its
        pre-translation app kind (bash apps execute as kind="python"), or
        its stamped resource kind (None = accepts everything)."""
        if self.desc.kinds is None:
            return True
        return any(k is not None and k in self.desc.kinds
                   for k in (task.kind, task.app_kind, task.res_kind))

    def load(self) -> float:
        """Demanded slots (queued + running) / capacity — the least-loaded
        routing metric."""
        return self.agent.load() / max(1, self.scheduler.capacity)

    # elastic scaling --------------------------------------------------- #
    def grow(self, n_slots: int):
        self.store.record_event("GROW", pilot=self.uid, n=n_slots)
        return self.scheduler.grow(n_slots)

    def shrink(self, n_slots: int):
        self.store.record_event("SHRINK", pilot=self.uid, n=n_slots)
        return self.scheduler.shrink(n_slots)

    @property
    def n_slots(self) -> int:
        return self.scheduler.capacity

    def close(self):
        self.agent.shutdown()
        self.store.close()


class PilotPool:
    """N pilots with heterogeneous descriptions + kind-aware late binding."""

    def __init__(self,
                 descs: Optional[Sequence[PilotDescription]] = None,
                 pilots: Optional[Sequence[Pilot]] = None):
        if pilots is None and descs is None:
            descs = [PilotDescription()]
        self.pilots: List[Pilot] = (list(pilots) if pilots is not None
                                    else [Pilot(d) for d in descs])
        if not self.pilots:
            raise ValueError("PilotPool needs at least one pilot")
        self._closed = False

    def __len__(self):
        return len(self.pilots)

    def by_uid(self, uid: str) -> Optional[Pilot]:
        return next((p for p in self.pilots if p.uid == uid), None)

    def _compatible(self, task: TaskRecord) -> List[Pilot]:
        compat = [p for p in self.pilots if p.accepts(task)]
        if not compat:
            raise RuntimeError(
                f"no pilot accepts task {task.uid} "
                f"(kind={task.kind!r}, res_kind={task.res_kind!r}; pool "
                f"kinds={[p.desc.kinds for p in self.pilots]!r})")
        return compat

    def route(self, task: TaskRecord) -> Pilot:
        """Least-loaded pilot whose description accepts the task."""
        return min(self._compatible(task), key=lambda p: p.load())

    def route_bulk(self, tasks: Sequence[TaskRecord]
                   ) -> List[Union[Pilot, Exception]]:
        """Greedy least-loaded assignment for a whole batch: the running
        load estimate includes the demand routed earlier in this batch, so
        a bulk submission spreads across compatible pilots instead of
        piling onto whichever was idle when the batch arrived.  An
        unroutable task yields its RuntimeError in place of a pilot, so
        one bad task never aborts the rest of the batch."""
        loads = {p.uid: p.load() for p in self.pilots}
        caps = {p.uid: max(1, p.scheduler.capacity) for p in self.pilots}
        out: List[Union[Pilot, Exception]] = []
        for t in tasks:
            try:
                p = min(self._compatible(t), key=lambda p: loads[p.uid])
            except RuntimeError as e:
                out.append(e)
                continue
            loads[p.uid] += t.resources.slots / caps[p.uid]
            out.append(p)
        return out

    def utilization(self) -> Dict[str, float]:
        """Per-pilot busy-slot fraction, keyed by pilot uid."""
        return {p.uid: p.scheduler.utilization() for p in self.pilots}

    def events(self) -> List[dict]:
        """Unified event stream merged across all pilots' stores."""
        out = []
        for p in self.pilots:
            for e in p.store.events:
                out.append({**e, "pilot": e.get("pilot") or p.uid})
        return sorted(out, key=lambda e: e["t"])

    def close(self):
        if self._closed:
            return
        self._closed = True
        for p in self.pilots:
            p.close()


class PilotManager:
    def __init__(self):
        self.pilots: Dict[str, Pilot] = {}

    def submit_pilot(self, desc: PilotDescription) -> Pilot:
        p = Pilot(desc)
        self.pilots[p.uid] = p
        return p

    def submit_pilots(self, descs: Sequence[PilotDescription]) -> PilotPool:
        pool = PilotPool(descs=descs)
        for p in pool.pilots:
            self.pilots[p.uid] = p
        return pool

    def cancel(self, uid: str):
        p = self.pilots.pop(uid, None)
        if p:
            p.close()

    def close(self):
        for uid in list(self.pilots):
            self.cancel(uid)


class TaskManager:
    """Routes task descriptions to pilots' agents; tracks completion with a
    single condition variable (an event wait, not a per-task poll)."""

    def __init__(self, pool: Union[PilotPool, Pilot]):
        if isinstance(pool, Pilot):
            pool = PilotPool(pilots=[pool])
        self.pool = pool
        self.tasks: Dict[str, TaskRecord] = {}
        self._cv = threading.Condition()
        self._done: Set[str] = set()
        self._outstanding = 0

    @property
    def pilot(self) -> Pilot:
        """The primary pilot (single-pilot compatibility accessor)."""
        return self.pool.pilots[0]

    # ---------------------------- submission ---------------------------- #
    def _completion_cb(self, done_cb: Optional[Callable]):
        def _cb(t: TaskRecord):
            uid = t.uid if t.replica_of is None else t.replica_of
            with self._cv:
                if uid not in self._done:
                    self._done.add(uid)
                    self._outstanding -= 1
                    self._cv.notify_all()
            if done_cb is not None:
                done_cb(t)
        return _cb

    def _bind(self, task: TaskRecord,
              workflow_key: Optional[str] = None,
              pilot: Optional[Pilot] = None) -> Pilot:
        """Late-bind a task to the least-loaded compatible pilot."""
        pilot = pilot if pilot is not None else self.pool.route(task)
        task.pilot_uid = pilot.uid
        self.tasks[task.uid] = task
        pilot.store.record_event("ROUTED", uid=task.uid, pilot=pilot.uid,
                                 kind=task.kind)
        if workflow_key is not None:
            pilot.store.record(task, workflow_key=workflow_key)
        return pilot

    def _fail_unroutable(self, task: TaskRecord, err: Exception,
                         done_cb: Optional[Callable]):
        """Resolve an unroutable task as FAILED through its callback — the
        submit path may run in a flush timer or dependency callback thread
        where a raised exception would be swallowed and hang the future."""
        task.error = err
        self.tasks[task.uid] = task
        task.transition(TaskState.FAILED)
        with self._cv:
            self._done.add(task.uid)
            self._cv.notify_all()        # a wait(uids=[...]) may be parked
        if done_cb is not None:
            done_cb(task)

    def submit(self, task: TaskRecord,
               done_cb: Optional[Callable] = None,
               workflow_key: Optional[str] = None) -> TaskRecord:
        try:
            pilot = self.pool.route(task)
        except RuntimeError as e:
            self._fail_unroutable(task, e, done_cb)
            return task
        self._bind(task, workflow_key, pilot=pilot)
        with self._cv:
            self._outstanding += 1
        task.transition(TaskState.TRANSLATED, pilot.store)
        pilot.agent.submit(task, done_cb=self._completion_cb(done_cb))
        return task

    def submit_bulk(self, tasks: List[TaskRecord],
                    done_cb: Optional[Callable] = None,
                    workflow_keys: Optional[Dict[str, str]] = None
                    ) -> List[TaskRecord]:
        """One agent submission per pilot for a whole batch."""
        per_pilot: Dict[str, Tuple[Pilot, List[TaskRecord]]] = {}
        routed = 0
        for t, pilot in zip(tasks, self.pool.route_bulk(tasks)):
            if isinstance(pilot, Exception):
                self._fail_unroutable(t, pilot, done_cb)
                continue
            self._bind(t, (workflow_keys or {}).get(t.uid), pilot=pilot)
            per_pilot.setdefault(pilot.uid, (pilot, []))[1].append(t)
            t.transition(TaskState.TRANSLATED, pilot.store)
            routed += 1
        with self._cv:
            self._outstanding += routed
        cb = self._completion_cb(done_cb)
        for pilot, batch in per_pilot.values():
            pilot.agent.submit_bulk(batch, done_cb=cb)
        return tasks

    # ------------------------------ waiting ------------------------------ #
    def wait(self, uids=None, timeout: Optional[float] = None) -> bool:
        """Block until the given (default: all) tasks complete — a single
        condition-variable wait, not a per-task Event scan."""
        with self._cv:
            if uids is None:
                return self._cv.wait_for(lambda: self._outstanding == 0,
                                         timeout)
            want = [u for u in uids if u in self.tasks]
            return self._cv.wait_for(
                lambda: all(u in self._done for u in want), timeout)
