"""App decorators — the Parsl programming model, plus the resource-spec
extension the paper adds for RP (§IV-D: "we extended Parsl's API to allow
users to define those parameters").

  @python_app                      — single-slot Python function
  @spmd_app(slots=8, mesh=(4, 2))  — SPMD function over a device sub-mesh;
                                     body receives the sub-mesh first arg
  @bash_app                        — function returning a shell command line

Every decorator accepts ``retry_policy=RetryPolicy(...)`` as the richer
sibling of the bare ``retries=N`` count: exponential backoff with jitter,
infra-vs-app error classification (infra failures retry on a *different*
pilot), fatal-exception short-circuit, and poison-task quarantine
(docs/resilience.md).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence, Tuple

from .dfk import current_dfk
from .futures import AppFuture, ResourceSpec, RetryPolicy


def _mk_app(fn: Callable, kind: str, resources: ResourceSpec,
            retries: int, executor: Optional[str],
            retry_policy: Optional[RetryPolicy] = None):
    fn.__app_kind__ = kind
    fn.__resources__ = resources
    fn.__executor__ = executor

    @functools.wraps(fn)
    def invoke(*args, **kwargs) -> AppFuture:
        return current_dfk().submit(fn, args, kwargs, resources=resources,
                                    retries=retries, executor=executor,
                                    retry_policy=retry_policy)

    invoke.__wrapped_app__ = fn
    return invoke


def python_app(fn=None, *, retries: int = 0, executor: Optional[str] = None,
               slots: int = 1, sticky: bool = False,
               affinity: Sequence[str] = (), checkpointable: bool = False,
               retry_policy: Optional[RetryPolicy] = None):
    """sticky=True pins every invocation to the pilot it was routed to:
    the task is never migrated by inter-pilot work stealing (use for tasks
    with pilot-local state or data affinity).  ``affinity`` is the soft
    sibling: pilot uids/names this app's input data lives on; a
    LocalityAware placement policy scores routing toward them (merged
    with the producer pilots the dep manager discovers at run time).
    checkpointable=True hands the body a ``ckpt`` keyword (Checkpoint
    context: ``ckpt.restore()`` / ``ckpt.save(step, state)``) — partial
    progress survives straggler replication, cooperative preemption, and
    restarts (see docs/checkpointing.md).  ``retry_policy`` supersedes
    ``retries`` with backoff + classification (docs/resilience.md)."""
    def deco(f):
        return _mk_app(f, "python",
                       ResourceSpec(slots=slots, cpu_only=True,
                                    sticky=sticky,
                                    affinity=tuple(affinity),
                                    checkpointable=checkpointable),
                       retries, executor, retry_policy)
    return deco(fn) if fn is not None else deco


def spmd_app(fn=None, *, slots: int = 1,
             mesh: Optional[Tuple[int, int]] = None, retries: int = 0,
             executor: Optional[str] = None, priority: int = 0,
             jit: bool = True, sticky: bool = False,
             affinity: Sequence[str] = (), checkpointable: bool = False,
             retry_policy: Optional[RetryPolicy] = None):
    """jit=False for bodies that manage their own jit (e.g. a training
    segment calling a pre-jitted step) or that are not traceable.
    sticky=True exempts the task from inter-pilot work stealing;
    ``affinity`` names pilots holding this app's input arrays (soft
    data-locality hint for LocalityAware placement).  checkpointable=True
    hands the body a ``ckpt`` Checkpoint context (see python_app); the
    context is not traceable, so such bodies run un-jitted at the wrapper
    level and manage their own jit per step."""
    def deco(f):
        f.__spmd_jit__ = jit
        return _mk_app(f, "spmd",
                       ResourceSpec(slots=slots, mesh_shape=mesh,
                                    priority=priority, sticky=sticky,
                                    affinity=tuple(affinity),
                                    checkpointable=checkpointable),
                       retries, executor, retry_policy)
    return deco(fn) if fn is not None else deco


def bash_app(fn=None, *, retries: int = 0, executor: Optional[str] = None,
             retry_policy: Optional[RetryPolicy] = None):
    def deco(f):
        return _mk_app(f, "bash", ResourceSpec(slots=1, cpu_only=True),
                       retries, executor, retry_policy)
    return deco(fn) if fn is not None else deco
