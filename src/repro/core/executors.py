"""Executor interface (concurrent.futures-style, as Parsl uses) + the
ThreadPool baseline executor (the HTEX stand-in used for comparison runs).
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor as _TPE
from typing import Callable, List, Optional, Tuple

from .futures import AppFuture, ResourceSpec, TaskRecord
from .translator import bind_future, translate


class ParslTask:
    """What the DFK hands an executor: the app + resolved args, plus the
    executor-kind hint the DFK resolved for it (threaded through so bulk
    batches and pilot routing can see where the task was bound) and the
    data-affinity hint (the pilots that produced this task's inputs,
    recorded by the dep manager for locality-aware placement)."""

    __slots__ = ("fn", "args", "kwargs", "resources", "retries", "key",
                 "executor", "affinity", "affinity_bytes", "retry_policy")

    def __init__(self, fn, args, kwargs, resources=None, retries=0,
                 key: Optional[str] = None, executor: Optional[str] = None,
                 affinity: Tuple[str, ...] = (), retry_policy=None,
                 affinity_bytes=None):
        self.fn, self.args, self.kwargs = fn, args, kwargs
        self.resources = resources
        self.retries = retries
        self.key = key
        self.executor = executor
        self.affinity = affinity
        self.affinity_bytes = affinity_bytes   # {producer pilot: bytes}
        self.retry_policy = retry_policy


class Executor:
    label = "base"
    supports_bulk = False

    def submit(self, ptask: ParslTask, future: AppFuture):
        raise NotImplementedError

    def submit_bulk(self, pairs: List[Tuple[ParslTask, AppFuture]]):
        for pt, fut in pairs:
            self.submit(pt, fut)

    def shutdown(self):
        pass


class ThreadPoolExecutor(Executor):
    """Single-node thread pool (no slot management, no SPMD placement) —
    the baseline Parsl-HTEX-like executor Exp-2 compares RPEX against."""

    label = "threads"

    def __init__(self, max_workers: int = 8):
        self._pool = _TPE(max_workers=max_workers)

    def submit(self, ptask: ParslTask, future: AppFuture):
        task = translate(ptask.fn, ptask.args, ptask.kwargs,
                         ptask.resources, ptask.retries)
        future.task = task

        def run():
            from .futures import TaskState
            task.transition(TaskState.RUNNING)
            try:
                if task.kind == "spmd":
                    import jax
                    mesh = jax.make_mesh((1, 1), ("data", "model"),
                                         devices=jax.devices()[:1])
                    res = task.fn(mesh, *task.args, **task.kwargs)
                else:
                    res = task.fn(*task.args, **task.kwargs)
                task.result = res
                task.transition(TaskState.DONE)
                future.set_result(res)
            except BaseException as e:  # noqa: BLE001
                task.error = e
                task.transition(TaskState.FAILED)
                future.set_exception(e)

        self._pool.submit(run)

    def shutdown(self):
        self._pool.shutdown(wait=False)
