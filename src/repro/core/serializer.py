"""Pluggable serializer — what crosses the agent→worker process boundary.

RP learned this lesson the hard way (its ``utils/serializer`` grew pickle,
dill and cloudpickle backends): the moment task functions execute in a
different process, *serialization policy* becomes runtime policy.  A plain
``pickle`` refuses closures, lambdas and ``__main__`` functions — i.e.
most task bodies a workflow script actually writes — and silently pins
device arrays.  This module is the single place those rules live, shared
by the process transport (transport.py) for functions, arguments, results,
checkpoint payloads and exceptions.

Design points (each one a failure mode seen in the wild):

* **Callable-by-value fallback.**  ``dumps`` first lets pickle serialize a
  function by reference (importable module-level functions stay cheap and
  version-robust).  Functions pickle-by-ref cannot express — closures,
  lambdas, ``__main__``/unimportable functions — are captured *by value*:
  ``marshal``-ed code object, closure cell contents, defaults, and the
  referenced subset of the function's globals (modules travel as import
  references; unserializable globals are dropped and resolve to the
  child's builtins or a NameError at call time, never a submit failure).

* **Exception round-tripping.**  A task failure in a worker process must
  surface in the parent with its *remote* traceback, not a bare
  ``EOFError``.  ``pack_exception`` carries the formatted remote traceback
  alongside the exception; unpacking re-attaches it as ``__cause__`` (a
  ``RemoteTraceback``) so the user-visible chain reads exactly like
  ``concurrent.futures``' remote errors.  Exceptions that cannot
  round-trip (unpicklable state, constructor signature surprises) degrade
  to a ``RemoteError`` carrier with the original repr + traceback.

* **jax pytree leaves are host-transferred before crossing.**  A
  ``jax.Array`` leaf anywhere in args/results/checkpoint state is
  converted to ``numpy`` on the sending side (``jax.device_get``), so the
  receiving process never needs a live XLA client just to look at a
  value, and a forked worker never touches the parent's runtime.  The
  hook only engages when jax is already imported in the sending process.

* **Graceful unserializable-result degradation.**  ``pack_result`` never
  raises: a result that cannot cross the boundary completes the task with
  an ``UnserializableResult`` placeholder (repr preserved) instead of
  failing it — the same contract the journal already applies to
  non-JSON-serializable results (docs/performance.md: the line is
  slimmed, the value is unpinned, a restart re-executes).
"""
from __future__ import annotations

import builtins
import importlib
import io
import marshal
import pickle
import sys
import traceback
import types
from typing import Any, Optional, Tuple


class SerializationError(Exception):
    """The object cannot cross the process boundary."""


class RemoteTraceback(Exception):
    """Formatted traceback of an exception raised in a worker process,
    attached as ``__cause__`` of the re-raised exception (the
    ``concurrent.futures`` convention, so tracebacks render as
    'The above exception was the direct cause of ...')."""

    def __init__(self, tb: str):
        super().__init__(tb)
        self.tb = tb

    def __str__(self):
        return "\n" + self.tb


class RemoteError(RuntimeError):
    """Carrier for a remote exception that could not itself round-trip
    (unpicklable state or constructor); the message preserves the
    original type and repr, the attached RemoteTraceback the stack."""


class UnserializableResult:
    """Placeholder completing a proc-mode task whose result could not
    cross the boundary: the task is DONE, the repr is kept for
    observability, and — exactly like the journal's slimmed line — any
    consumer that needs the real value must recompute it."""

    def __init__(self, type_name: str, repr_str: str):
        self.type_name = type_name
        self.repr = repr_str

    def __repr__(self):
        return (f"<UnserializableResult {self.type_name}: "
                f"{self.repr[:120]}>")


_EMPTY_CELL = ("__repro_empty_cell__",)


def _load_module(name: str):
    try:
        return importlib.import_module(name)
    except Exception:  # noqa: BLE001 — a missing module in the receiver
        return None    # resolves to None; call-time NameError, not a crash


class _ModuleRef:
    """Modules travel as import-by-name references."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name


def _code_names(code) -> set:
    """Global names a code object (and its nested code objects) may read."""
    names = set(code.co_names)
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            names |= _code_names(const)
    return names


def _make_function(code_bytes: bytes, name: str, qualname: str,
                   defaults, kwdefaults, closure_vals: tuple,
                   globals_items: tuple, module: str):
    """Receiver-side reconstruction of a by-value function."""
    code = marshal.loads(code_bytes)
    g = {"__builtins__": builtins, "__name__": module or "__remote__"}
    for k, v in globals_items:
        g[k] = _load_module(v.name) if isinstance(v, _ModuleRef) else v
    cells = tuple(
        types.CellType() if v == _EMPTY_CELL else types.CellType(v)
        for v in closure_vals)
    fn = types.FunctionType(code, g, name, defaults, cells or None)
    fn.__kwdefaults__ = kwdefaults
    fn.__qualname__ = qualname
    # a recursive by-value function calls itself through its globals
    if name not in g:
        g[name] = fn
    return fn


def _pickles_by_ref(fn: types.FunctionType) -> bool:
    """True when standard pickle-by-reference will work on both sides:
    a module-level function of an importable, non-__main__ module."""
    if "<locals>" in getattr(fn, "__qualname__", ""):
        return False
    if fn.__module__ in (None, "__main__", "__mp_main__"):
        return False
    mod = sys.modules.get(fn.__module__)
    return mod is not None and getattr(mod, fn.__name__, None) is fn


_BASIC = (type(None), bool, int, float, complex, str, bytes)


class _Pickler(pickle.Pickler):
    """pickle + (jax→host, module-by-name, function-by-value) overrides."""

    def reducer_override(self, obj):
        jx = sys.modules.get("jax")
        if jx is not None and isinstance(obj, jx.Array):
            # host transfer before crossing: the receiver gets numpy and
            # never needs (or touches) an XLA runtime
            return (_identity, (jx.device_get(obj),))
        if isinstance(obj, types.ModuleType):
            return (_load_module, (obj.__name__,))
        if isinstance(obj, types.FunctionType) and not _pickles_by_ref(obj):
            return _reduce_function(obj)
        return NotImplemented


def _identity(x):
    return x


def _reduce_function(fn: types.FunctionType):
    closure = []
    for cell in (fn.__closure__ or ()):
        try:
            closure.append(cell.cell_contents)
        except ValueError:          # an empty (not yet bound) cell
            closure.append(_EMPTY_CELL)
    gl = []
    for name in sorted(_code_names(fn.__code__)):
        if name not in fn.__globals__:
            continue                # builtin / local — resolves receiver-side
        v = fn.__globals__[name]
        if isinstance(v, types.ModuleType):
            gl.append((name, _ModuleRef(v.__name__)))
        elif isinstance(v, (types.FunctionType, type)) or isinstance(v, _BASIC):
            gl.append((name, v))    # recursive reducer / by-ref handles these
        else:
            try:                    # arbitrary global state: probe, drop
                dumps(v)            # what cannot travel (call-time
                gl.append((name, v))    # NameError beats submit failure)
            except Exception:  # noqa: BLE001
                continue
    return (_make_function,
            (marshal.dumps(fn.__code__), fn.__name__, fn.__qualname__,
             fn.__defaults__, fn.__kwdefaults__, tuple(closure), tuple(gl),
             fn.__module__))


# --------------------------------- api ---------------------------------- #
def dumps(obj: Any) -> bytes:
    buf = io.BytesIO()
    try:
        _Pickler(buf, protocol=pickle.HIGHEST_PROTOCOL).dump(obj)
    except SerializationError:
        raise
    except Exception as e:  # noqa: BLE001 — normalize every pickle failure
        raise SerializationError(
            f"cannot serialize {type(obj).__name__}: {e!r}") from e
    return buf.getvalue()


def loads(blob: bytes) -> Any:
    return pickle.loads(blob)


def pack_task(fn, args: tuple, kwargs: dict) -> bytes:
    """One blob for the worker's run request; raises SerializationError
    (the transport then falls back to in-process execution)."""
    return dumps((fn, args, kwargs))


def pack_result(obj: Any) -> Tuple[Optional[bytes],
                                   Optional[Tuple[str, str]]]:
    """(blob, None) normally; (None, (type_name, repr)) when the result
    cannot cross — the graceful-degradation path, never an exception."""
    try:
        return dumps(obj), None
    except (SerializationError, RecursionError):
        try:
            r = repr(obj)
        except Exception:  # noqa: BLE001
            r = "<repr failed>"
        return None, (type(obj).__name__, r[:500])


def pack_exception(exc: BaseException) -> bytes:
    """Always succeeds: the exception itself when it round-trips, a
    RemoteError carrier (type + repr preserved) when it cannot."""
    tb = "".join(traceback.format_exception(type(exc), exc,
                                            exc.__traceback__))
    try:
        blob = dumps((exc, tb))
        loads(blob)                 # verify the round trip *now*: a
        return blob                 # constructor surprise must not
    except Exception:  # noqa: BLE001 — surface as a parent-side crash
        carrier = RemoteError(f"{type(exc).__name__}: {exc}")
        return dumps((carrier, tb))


def unpack_exception(blob: bytes) -> BaseException:
    exc, tb = loads(blob)
    exc.__cause__ = RemoteTraceback(tb)
    exc.remote_traceback = tb
    return exc
