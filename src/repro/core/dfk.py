"""DataFlowKernel — the Parsl-side engine (§II-B / Fig. 1 of the paper).

Wraps every app invocation in an AppFuture, maintains the task DAG (edges =
futures passed between apps), submits a task to its executor only when its
dependencies resolve, and tracks every task's state.

Two submission modes toward RPEX:
  * stream (paper's current behavior): each ready task submitted one by one;
  * bulk (paper's named future work): ready tasks are batched per tick and
    flushed with one submit_bulk call — Exp-2 measures the difference.

Restart: if the executor exposes a journaled StateStore and the DFK is given
a ``run_id``, tasks are keyed "<run_id>/<app>:<index>"; resubmitted tasks
whose key is already DONE in the journal resolve immediately from the
recorded result (checkpoint/restart at the workflow level).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from .executors import Executor, ParslTask, ThreadPoolExecutor
from .futures import AppFuture, ResourceSpec, TaskRecord, TaskState, new_uid
from .translator import translate

_current: List["DataFlowKernel"] = []


def _find_futures(obj, out=None):
    """AppFutures anywhere inside nested lists/tuples/dicts."""
    out = out if out is not None else []
    if isinstance(obj, AppFuture):
        out.append(obj)
    elif isinstance(obj, (list, tuple)):
        for x in obj:
            _find_futures(x, out)
    elif isinstance(obj, dict):
        for x in obj.values():
            _find_futures(x, out)
    return out


def _resolve(obj):
    """Substitute resolved results for futures, preserving structure
    (including NamedTuples, e.g. optimizer states)."""
    if isinstance(obj, AppFuture):
        return obj.result()
    if isinstance(obj, list):
        return [_resolve(x) for x in obj]
    if isinstance(obj, tuple):
        vals = [_resolve(x) for x in obj]
        if hasattr(obj, "_fields"):          # NamedTuple
            return type(obj)(*vals)
        return tuple(vals)
    if isinstance(obj, dict):
        return {k: _resolve(v) for k, v in obj.items()}
    return obj


def current_dfk() -> "DataFlowKernel":
    if not _current:
        raise RuntimeError("no active DataFlowKernel; use `with DataFlowKernel(...)`")
    return _current[-1]


class DataFlowKernel:
    def __init__(self, executors: Optional[Dict[str, Executor]] = None,
                 default_executor: Optional[str] = None,
                 bulk: bool = False, bulk_window: float = 0.002,
                 run_id: Optional[str] = None):
        self.executors = executors or {"threads": ThreadPoolExecutor()}
        self.default_executor = default_executor or next(iter(self.executors))
        self.bulk = bulk
        self.bulk_window = bulk_window
        self.run_id = run_id
        self._lock = threading.Lock()
        self._invocation_idx: Dict[str, int] = {}
        self._pending_bulk: Dict[str, List[Tuple[ParslTask, AppFuture]]] = {}
        self._flushers: Dict[str, threading.Timer] = {}   # per executor
        self.tasks: Dict[str, TaskRecord] = {}   # DAG nodes
        self.edges: List[Tuple[str, str]] = []   # (producer, consumer)
        self.t_start = time.monotonic()

    # --------------------------- context mgmt --------------------------- #
    def __enter__(self):
        _current.append(self)
        return self

    def __exit__(self, *exc):
        self.shutdown()
        _current.remove(self)
        return False

    def shutdown(self):
        self.flush()
        for ex in self.executors.values():
            ex.shutdown()

    # ----------------------------- submission --------------------------- #
    def submit(self, fn, args: tuple = (), kwargs: Optional[dict] = None,
               resources: Optional[ResourceSpec] = None, retries: int = 0,
               executor: Optional[str] = None,
               sticky: Optional[bool] = None) -> AppFuture:
        kwargs = kwargs or {}
        if sticky is not None:
            # per-invocation steal-eligibility override: threaded through the
            # ResourceSpec so the translator stamps it onto the pilot task
            base = (resources or getattr(fn, "__resources__", None)
                    or ResourceSpec())
            resources = dataclasses.replace(base, sticky=sticky)
        name = getattr(fn, "__name__", "app")
        with self._lock:
            idx = self._invocation_idx.get(name, 0)
            self._invocation_idx[name] = idx + 1
        key = f"{self.run_id}/{name}:{idx}" if self.run_id else None

        # the DFK-side DAG node (distinct from the pilot-side TaskRecord the
        # translator creates later — mirrors the paper's two task objects)
        node = TaskRecord(uid=new_uid("dfk"), kind="parsl", fn=fn,
                          args=args, kwargs=kwargs,
                          resources=resources or getattr(
                              fn, "__resources__", None) or ResourceSpec())
        future = AppFuture(node)
        self.tasks[node.uid] = node

        # the executor-kind hint: explicit arg > app decorator > default
        label = (executor or getattr(fn, "__executor__", None)
                 or self.default_executor)
        ex = self.executors[label]

        # replay from journal (workflow-level restart); a multi-pilot
        # executor exposes completed_result over every pilot's journal
        lookup = getattr(ex, "completed_result", None)
        if lookup is None:
            store = getattr(getattr(ex, "pilot", None), "store", None)
            lookup = store.completed_result if store is not None else None
        if key is not None and lookup is not None:
            found, result = lookup(key)
            if found:
                node.result = result
                node.transition(TaskState.DONE)
                future.set_result(result)
                return future

        # dependency resolution: any AppFuture in args/kwargs — including
        # nested inside lists/tuples/dicts — is a dataflow edge
        deps = [f for f in _find_futures((args, kwargs)) if not f.done()]
        for d in deps:
            self.edges.append((d.uid, node.uid))
            node.depends_on.append(d.uid)

        def launch():
            try:
                r_args = tuple(_resolve(a) for a in args)
                r_kwargs = {k: _resolve(v) for k, v in kwargs.items()}
            except BaseException as e:   # upstream failure propagates
                node.transition(TaskState.FAILED)
                if not future.done():
                    future.set_exception(e)
                return
            pt = ParslTask(fn, r_args, r_kwargs, node.resources, retries, key,
                           executor=label)
            node.transition(TaskState.TRANSLATED)
            self._dispatch(ex, pt, future)

        if not deps:
            launch()
        else:
            remaining = [len(deps)]
            rlock = threading.Lock()

            def on_dep(_):
                with rlock:
                    remaining[0] -= 1
                    ready = remaining[0] == 0
                if ready:
                    launch()

            for d in deps:
                d.add_done_callback(on_dep)
        return future

    # ------------------------------- bulk -------------------------------- #
    def _dispatch(self, ex: Executor, pt: ParslTask, future: AppFuture):
        if self.bulk and ex.supports_bulk:
            label = pt.executor or ex.label
            with self._lock:
                self._pending_bulk.setdefault(label, []).append((pt, future))
                if label not in self._flushers:
                    t = threading.Timer(self.bulk_window, self.flush, [label])
                    t.daemon = True
                    self._flushers[label] = t
                    t.start()
        else:
            ex.submit(pt, future)

    def flush(self, executor: Optional[str] = None):
        """Flush pending bulk batches — all executors, or just one.  Safe to
        call concurrently per executor: each label's batch is popped under
        the lock, so a timer flush and an explicit flush never double-submit
        and one executor's flush never blocks another's."""
        with self._lock:
            labels = ([executor] if executor is not None
                      else list(self._pending_bulk))
            batches = {}
            for label in labels:
                pairs = self._pending_bulk.pop(label, [])
                if pairs:
                    batches[label] = pairs
                timer = self._flushers.pop(label, None)
                if timer is not None:
                    timer.cancel()
        for label, pairs in batches.items():
            self.executors[label].submit_bulk(pairs)

    # ------------------------------ graph ------------------------------- #
    def dag(self):
        return {"nodes": list(self.tasks), "edges": list(self.edges)}
