"""DataFlowKernel — the Parsl-side engine (§II-B / Fig. 1 of the paper).

Wraps every app invocation in an AppFuture, maintains the task DAG (edges =
futures passed between apps), submits a task to its executor only when its
dependencies resolve, and tracks every task's state.

Dependency resolution is *batched* (PR 3): instead of registering one
done-callback per (consumer, dependency) edge — N lock round-trips to
launch a wide fan-in — the DFK keeps one dependency manager: each waiting
consumer holds an atomic remaining-deps counter, each producer future
carries a single DFK-level callback, and when a producer completes every
consumer it feeds is decremented in one pass under one lock.  Consumers
that become ready launch as one submit_bulk per executor in that same
pass (bulk mode) or are submitted in order (stream mode) — a 256-wide
fan-out launches in one pass, not 256 callback chains, and a wide fan-in
aggregator skips the window wait entirely (its batch is already
coalesced).  Near-simultaneous producer completions additionally
*combine*: concurrent done-callbacks enqueue their producer and return
while one drainer thread micro-batches every queued decrement pass — a
256-wide fan-in completing across agent workers costs one drain loop,
not 256 contended wakeups, and the uncontended single-producer path pays
nothing extra.

The dep manager also records *where* each producer ran: at launch, every
input future's ``pilot_uid`` becomes the consumer's data-affinity hint
(threaded through ParslTask into the translator's ``affinity`` stamp) so
a LocalityAware placement policy can put consumers next to their inputs.

Bulk window flushing is likewise a single persistent flusher thread with
one deadline per executor, replacing the fresh ``threading.Timer`` the
old code spawned per window (and its flush-vs-timer double-submit
hazard).

Two submission modes toward RPEX:
  * stream (paper's current behavior): each ready task submitted one by one;
  * bulk (paper's named future work): ready tasks are batched per tick and
    flushed with one submit_bulk call — Exp-2 measures the difference.

Restart: if the executor exposes a journaled StateStore and the DFK is given
a ``run_id``, tasks are keyed "<run_id>/<app>:<index>"; resubmitted tasks
whose key is already DONE in the journal resolve immediately from the
recorded result (checkpoint/restart at the workflow level).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from .executors import Executor, ParslTask, ThreadPoolExecutor
from .futures import (AppFuture, ResourceSpec, RetryPolicy, TaskRecord,
                      TaskState, new_uid)
from .objectstore import ObjectRef, estimate_size, materialize

_current: List["DataFlowKernel"] = []


def _find_futures(obj, out=None):
    """AppFutures anywhere inside nested lists/tuples/dicts."""
    out = out if out is not None else []
    if isinstance(obj, AppFuture):
        out.append(obj)
    elif isinstance(obj, (list, tuple)):
        for x in obj:
            _find_futures(x, out)
    elif isinstance(obj, dict):
        for x in obj.values():
            _find_futures(x, out)
    return out


def _resolve(obj):
    """Substitute resolved results for futures, preserving structure
    (including NamedTuples, e.g. optimizer states).  A future holding a
    published result contributes its *ObjectRef*, not the payload — the
    edge ships a handle, and the executing pilot derefs it there (where
    cross-pilot bytes are attributable)."""
    if isinstance(obj, AppFuture):
        return obj.raw_result()
    if isinstance(obj, list):
        return [_resolve(x) for x in obj]
    if isinstance(obj, tuple):
        vals = [_resolve(x) for x in obj]
        if hasattr(obj, "_fields"):          # NamedTuple
            return type(obj)(*vals)
        return tuple(vals)
    if isinstance(obj, dict):
        return {k: _resolve(v) for k, v in obj.items()}
    return obj


def current_dfk() -> "DataFlowKernel":
    if not _current:
        raise RuntimeError("no active DataFlowKernel; use `with DataFlowKernel(...)`")
    return _current[-1]


class _DepNode:
    """A submitted-but-waiting consumer: its launch closure plus the count
    of producers it still waits on.  ``remaining`` is only touched under
    the DFK's dependency lock."""

    __slots__ = ("remaining", "launch")

    def __init__(self, remaining: int, launch: Callable):
        self.remaining = remaining
        self.launch = launch


class DataFlowKernel:
    def __init__(self, executors: Optional[Dict[str, Executor]] = None,
                 default_executor: Optional[str] = None,
                 bulk: bool = False, bulk_window: float = 0.002,
                 run_id: Optional[str] = None,
                 byte_affinity: bool = True):
        self.executors = executors or {"threads": ThreadPoolExecutor()}
        self.default_executor = default_executor or next(iter(self.executors))
        self.bulk = bulk
        self.bulk_window = bulk_window
        self.run_id = run_id
        self.byte_affinity = byte_affinity
                                    # weight data-affinity by input bytes
                                    # (False = legacy uid counting — the
                                    # exp11 placement baseline)
        self._lock = threading.Lock()
        self._invocation_idx: Dict[str, int] = {}
        self.tasks: Dict[str, TaskRecord] = {}   # DAG nodes
        self.edges: List[Tuple[str, str]] = []   # (producer, consumer)
        self.edge_bytes: List[Tuple[str, str, int]] = []
                                    # (producer uid, consumer uid, bytes)
                                    # per dataflow edge at launch time
        self.edge_bytes_total = 0
        self.t_start = time.monotonic()
        # restart observability: keys that were interrupted last run and
        # carry a checkpoint — their tasks re-execute but resume from the
        # recorded step (the value) instead of step 0
        self.resumed_from_checkpoint: Dict[str, int] = {}

        # dependency manager: producer future -> consumers waiting on it.
        # Keyed by the future object (identity), not its uid: executors
        # re-point future.task at the translated pilot task on launch, so
        # the uid is not stable between registration and completion.
        self._dep_lock = threading.Lock()
        self._consumers: Dict[AppFuture, List[_DepNode]] = {}
        # cross-producer coalescing: completed producers queue here; the
        # first completer becomes the drainer and micro-batches every
        # decrement that arrives while it drains (see _on_producer_done)
        self._producer_q: List[AppFuture] = []
        self._dep_draining = False
        self.dep_coalesced = 0      # producers combined into another
                                    # thread's drain pass (stat, tests)

        # bulk buffers + the single persistent flusher thread
        self._flush_cv = threading.Condition()
        self._pending_bulk: Dict[str, List[Tuple[ParslTask, AppFuture]]] = {}
        self._due: Dict[str, float] = {}         # label -> flush deadline
        self._flusher: Optional[threading.Thread] = None
        self._stopped = False

    # --------------------------- context mgmt --------------------------- #
    def __enter__(self):
        _current.append(self)
        return self

    def __exit__(self, *exc):
        self.shutdown()
        _current.remove(self)
        return False

    def shutdown(self):
        self.flush()
        with self._flush_cv:
            self._stopped = True
            self._flush_cv.notify_all()
        if self._flusher is not None:
            self._flusher.join(timeout=5.0)
            self._flusher = None
        self.flush()                  # anything raced in during teardown
        for ex in self.executors.values():
            ex.shutdown()

    # ----------------------------- submission --------------------------- #
    def submit(self, fn, args: tuple = (), kwargs: Optional[dict] = None,
               resources: Optional[ResourceSpec] = None, retries: int = 0,
               executor: Optional[str] = None,
               sticky: Optional[bool] = None,
               retry_policy: Optional[RetryPolicy] = None) -> AppFuture:
        kwargs = kwargs or {}
        if sticky is not None:
            # per-invocation steal-eligibility override: threaded through the
            # ResourceSpec so the translator stamps it onto the pilot task
            base = (resources or getattr(fn, "__resources__", None)
                    or ResourceSpec())
            resources = dataclasses.replace(base, sticky=sticky)
        name = getattr(fn, "__name__", "app")
        with self._lock:
            idx = self._invocation_idx.get(name, 0)
            self._invocation_idx[name] = idx + 1
        key = f"{self.run_id}/{name}:{idx}" if self.run_id else None

        # the DFK-side DAG node (distinct from the pilot-side TaskRecord the
        # translator creates later — mirrors the paper's two task objects)
        node = TaskRecord(uid=new_uid("dfk"), kind="parsl", fn=fn,
                          args=args, kwargs=kwargs,
                          resources=resources or getattr(
                              fn, "__resources__", None) or ResourceSpec())
        future = AppFuture(node)
        self.tasks[node.uid] = node

        # the executor-kind hint: explicit arg > app decorator > default
        label = (executor or getattr(fn, "__executor__", None)
                 or self.default_executor)
        ex = self.executors[label]

        # replay from journal (workflow-level restart); a multi-pilot
        # executor exposes completed_result over every pilot's journal
        lookup = getattr(ex, "completed_result", None)
        if lookup is None:
            store = getattr(getattr(ex, "pilot", None), "store", None)
            lookup = store.completed_result if store is not None else None
        if key is not None and lookup is not None:
            found, result = lookup(key)
            if found:
                node.result = result
                node.transition(TaskState.DONE)
                future.set_result(result)
                return future
            # not completed, but checkpointed: the task re-executes below
            # and its Checkpoint context restores the saved step — record
            # the partial restart so callers can see what resumed
            peek = getattr(ex, "checkpoint_step", None)
            if peek is not None:
                step = peek(key)
                if step is not None:
                    self.resumed_from_checkpoint[key] = step

        # dependency resolution: any AppFuture in args/kwargs — including
        # nested inside lists/tuples/dicts — is a dataflow edge
        inputs = _find_futures((args, kwargs))
        deps = [f for f in inputs if not f.done()]
        for d in deps:
            self.edges.append((d.uid, node.uid))
            node.depends_on.append(d.uid)

        def launch() -> Optional[Tuple[str, ParslTask, AppFuture]]:
            try:
                r_args = tuple(_resolve(a) for a in args)
                r_kwargs = {k: _resolve(v) for k, v in kwargs.items()}
            except BaseException as e:   # upstream failure propagates
                node.transition(TaskState.FAILED)
                if not future.done():
                    future.set_exception(e)
                return None
            # data-affinity hint: the pilots that produced this task's
            # inputs (every input is resolved by now, so each producer's
            # pilot binding is final — stolen tasks report the pilot that
            # actually ran them), weighted by input bytes so placement can
            # follow the *largest* input (docs/dataplane.md)
            per_pilot: Dict[str, int] = {}
            ref_oids: List[Tuple[Any, str]] = []
            edge_recs: List[Tuple[str, str, int]] = []
            for f in inputs:
                raw = f.raw_result()
                if isinstance(raw, ObjectRef):
                    size = raw.size
                    if raw._store is not None:
                        # one consumer edge per input occurrence: released
                        # when this consumer's future completes, driving
                        # the store's DONE-event ref-count GC
                        ref_oids.append((raw._store, raw.oid))
                else:
                    size = estimate_size(raw)
                puid = getattr(f.task, "pilot_uid", None)
                if puid:
                    per_pilot[puid] = per_pilot.get(puid, 0) + size
                edge_recs.append((f.task.uid, node.uid, size))
            if edge_recs:
                with self._lock:
                    self.edge_bytes.extend(edge_recs)
                    self.edge_bytes_total += sum(s for _, _, s in edge_recs)
            if self.byte_affinity:
                affinity = tuple(sorted(per_pilot, key=per_pilot.get,
                                        reverse=True))
                affinity_bytes = per_pilot or None
            else:
                affinity = tuple(dict.fromkeys(
                    p for p in (getattr(f.task, "pilot_uid", None)
                                for f in inputs) if p))
                affinity_bytes = None
            for s, oid in ref_oids:
                s.add_consumers(oid)
            if ref_oids:
                def _release(_f, _refs=tuple(ref_oids)):
                    for s, oid in _refs:
                        s.release(oid)
                future.add_done_callback(_release)
            if not getattr(self.executors[label], "resolves_refs", False):
                # executors without a data plane (e.g. the thread-pool
                # baseline) get payloads, not handles
                r_args = materialize(r_args, None)
                r_kwargs = materialize(r_kwargs, None)
            pt = ParslTask(fn, r_args, r_kwargs, node.resources, retries, key,
                           executor=label, affinity=affinity,
                           retry_policy=retry_policy,
                           affinity_bytes=affinity_bytes)
            node.transition(TaskState.TRANSLATED)
            return label, pt, future

        if not deps:
            item = launch()
            if item is not None:
                self._dispatch_ready([item], immediate=False)
            return future

        dep_node = _DepNode(len(deps), launch)
        hook: List[AppFuture] = []           # producers needing our callback
        with self._dep_lock:
            for d in deps:
                waiting = self._consumers.get(d)
                if waiting is None:
                    self._consumers[d] = [dep_node]
                    hook.append(d)
                else:
                    waiting.append(dep_node)
        for d in hook:
            d.add_done_callback(self._on_producer_done)
        for d in deps:
            # a producer that completed between registration above and its
            # callback being attached (or whose callback already drained)
            # is settled here; _on_producer_done is idempotent — each node
            # is popped and decremented at most once per registration
            if d.done():
                self._on_producer_done(d)
        return future

    # ------------------------ dependency manager ------------------------- #
    def _on_producer_done(self, fut: AppFuture):
        """One producer completed.  Producers are decremented in micro-
        batches: the completing thread enqueues its future and, if no
        drain is in flight, becomes the drainer — any producer that
        completes while it drains is combined into the same loop (its
        thread returns immediately).  A wide fan-in whose producers
        finish across N agent workers thus pays one decrement pass and
        one launch batch instead of N contended lock round-trips; the
        solitary-completion fast path is a single loop iteration with no
        handoff or window wait, keeping dependency launch latency flat."""
        with self._dep_lock:
            self._producer_q.append(fut)
            if self._dep_draining:
                self.dep_coalesced += 1
                return
            self._dep_draining = True
        try:
            while True:
                with self._dep_lock:
                    batch, self._producer_q = self._producer_q, []
                    if not batch:
                        self._dep_draining = False
                        return
                    ready = []
                    for f in batch:
                        waiting = self._consumers.pop(f, None)
                        if not waiting:
                            continue
                        for n in waiting:
                            n.remaining -= 1
                            if n.remaining == 0:
                                ready.append(n)
                if not ready:
                    continue
                items = [item for item in (n.launch() for n in ready)
                         if item is not None]
                if items:
                    # dependency-ready batches are already coalesced —
                    # submit them in this pass, not after a stream window
                    self._dispatch_ready(items, immediate=True)
        except BaseException:
            # never leave the drain flag wedged: a later completion must
            # be able to pick up whatever is still queued
            with self._dep_lock:
                self._dep_draining = False
            raise

    def _submit_batch(self, items: List[Tuple[str, ParslTask, AppFuture]]):
        """One submit_bulk per executor for a coalesced batch (stream
        submission for executors without bulk support)."""
        per_label: Dict[str, List[Tuple[ParslTask, AppFuture]]] = {}
        for label, pt, future in items:
            ex = self.executors[label]
            if ex.supports_bulk:
                per_label.setdefault(label, []).append((pt, future))
            else:
                ex.submit(pt, future)
        for label, pairs in per_label.items():
            self.executors[label].submit_bulk(pairs)

    def _dispatch_ready(self, items: List[Tuple[str, ParslTask, AppFuture]],
                        immediate: bool):
        """Route launched tasks to their executors.  An ``immediate``
        (dependency-ready) batch is already coalesced: it goes out as one
        submit_bulk per executor in the calling pass — wide fan-ins launch
        without a window wait or a flusher handoff.  Stream submissions in
        bulk mode land in the per-executor buffer, coalescing until the
        flusher thread's per-label deadline."""
        if not self.bulk:
            # stream mode never buffers — skip the flush lock entirely
            for label, pt, future in items:
                self.executors[label].submit(pt, future)
            return
        if immediate:
            self._submit_batch(items)
            return
        direct: List[Tuple[str, ParslTask, AppFuture]] = []
        now = time.monotonic()
        buffered = False
        with self._flush_cv:
            for label, pt, future in items:
                ex = self.executors[label]
                if self.bulk and ex.supports_bulk and not self._stopped:
                    self._pending_bulk.setdefault(label, []).append(
                        (pt, future))
                    if label not in self._due:
                        self._due[label] = now + self.bulk_window
                    buffered = True
                else:
                    direct.append((label, pt, future))
            if buffered:
                if self._flusher is None:
                    self._flusher = threading.Thread(
                        target=self._flusher_loop, daemon=True)
                    self._flusher.start()
                self._flush_cv.notify_all()
        for label, pt, future in direct:
            self.executors[label].submit(pt, future)

    # ------------------------------- bulk -------------------------------- #
    def _flusher_loop(self):
        """The single persistent flusher: waits until the earliest
        per-executor deadline, pops every due batch under the lock, and
        submits them outside it.  Replaces one threading.Timer per window."""
        while True:
            with self._flush_cv:
                while not self._due and not self._stopped:
                    self._flush_cv.wait()
                if self._stopped and not self._due:
                    return
                now = time.monotonic()
                due_now = [l for l, d in self._due.items() if d <= now]
                if not due_now and not self._stopped:
                    self._flush_cv.wait(min(self._due.values()) - now)
                    continue
                batches = {}
                for label in (due_now or list(self._due)):
                    pairs = self._pending_bulk.pop(label, [])
                    self._due.pop(label, None)
                    if pairs:
                        batches[label] = pairs
            for label, pairs in batches.items():
                self.executors[label].submit_bulk(pairs)

    def flush(self, executor: Optional[str] = None):
        """Flush pending bulk batches — all executors, or just one.  Safe to
        call concurrently per executor and concurrently with the flusher
        thread: each label's batch is popped under the lock, so a deadline
        flush and an explicit flush never double-submit and one executor's
        flush never blocks another's."""
        with self._flush_cv:
            labels = ([executor] if executor is not None
                      else list(self._pending_bulk))
            batches = {}
            for label in labels:
                pairs = self._pending_bulk.pop(label, [])
                if pairs:
                    batches[label] = pairs
                self._due.pop(label, None)
        for label, pairs in batches.items():
            self.executors[label].submit_bulk(pairs)

    # ------------------------------ graph ------------------------------- #
    def dag(self):
        return {"nodes": list(self.tasks), "edges": list(self.edges)}
