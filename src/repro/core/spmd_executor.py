"""SPMD function executor — the paper's MPI-function-executor, TPU-native.

The paper's executor launches one persistent MPI world, then carves
Intra-communicators so many heterogeneous MPI Python functions run
concurrently.  Here the persistent world is the pilot's device set; an
"Intra-communicator" is a sub-mesh carved from it; collectives inside task
functions are ``jax.lax`` ops under ``shard_map``.

The paper's §V-A performance lesson — *build the communicator once, reuse
it, cache it* — is structural here: sub-meshes and specialized callables are
cached keyed by (function, sub-mesh, abstract inputs).  The first dispatch
of a key pays trace+compile (the paper's `Launching`/`ibrun` analog); every
subsequent task with the same signature is a cheap cached call.  The
``cache=False`` mode exists only for the Exp-1 ablation that reproduces the
paper's cold-communicator overhead.

On the CPU container, slots may outnumber real devices: slot blocks then
map onto the available devices (dedup'd), preserving scheduling semantics
while executing on what exists — the same code drives a real pod.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from .futures import ResourceSpec, TaskRecord


class SPMDFunctionExecutor:
    def __init__(self, devices=None, cache: bool = True):
        self.devices = list(devices if devices is not None else jax.devices())
        self.cache_enabled = cache
        self._mesh_cache: Dict[Tuple, Any] = {}
        self._call_cache: Dict[Tuple, Callable] = {}
        self._lock = threading.Lock()
        self.stats = {"compiles": 0, "cache_hits": 0}

    # ----------------------------- sub-mesh ----------------------------- #
    def submesh(self, slot_ids: Tuple[int, ...],
                mesh_shape: Optional[Tuple[int, int]] = None):
        """Carve the sub-mesh ('Intra-communicator') for a slot block."""
        nreal = len(self.devices)
        devs = []
        seen = set()
        for s in slot_ids:
            d = self.devices[s % nreal]
            if id(d) not in seen:
                seen.add(id(d))
                devs.append(d)
        n = len(devs)
        if mesh_shape and mesh_shape[0] * mesh_shape[1] <= n:
            shape = mesh_shape
        else:
            shape = (n, 1)
        key = (tuple(d.id for d in devs[: shape[0] * shape[1]]), shape)
        with self._lock:
            if self.cache_enabled and key in self._mesh_cache:
                return self._mesh_cache[key]
        mesh = jax.make_mesh(shape, ("data", "model"),
                             devices=devs[: shape[0] * shape[1]])
        with self._lock:
            if self.cache_enabled:
                self._mesh_cache[key] = mesh
        return mesh

    # ----------------------------- dispatch ----------------------------- #
    def _specialize(self, fn: Callable, mesh, jit: bool):
        """One compiled callable per (fn, mesh) — the communicator cache."""
        key = (id(fn), tuple(d.id for d in mesh.devices.flat),
               mesh.shape_tuple)
        with self._lock:
            if self.cache_enabled and key in self._call_cache:
                self.stats["cache_hits"] += 1
                return self._call_cache[key]
        if jit:
            wrapped = jax.jit(lambda *a, **kw: fn(mesh, *a, **kw))
        else:
            wrapped = lambda *a, **kw: fn(mesh, *a, **kw)  # noqa: E731
        with self._lock:
            # double-checked: a concurrent miss may have registered first —
            # reuse its callable so both share one compiled executable
            if self.cache_enabled and key in self._call_cache:
                self.stats["cache_hits"] += 1
                return self._call_cache[key]
            self.stats["compiles"] += 1
            if self.cache_enabled:
                self._call_cache[key] = wrapped
        return wrapped

    def execute(self, task: TaskRecord) -> Any:
        """Run a task body on its allocated slots.  Blocking; called from an
        agent worker thread (the MPI-Worker analog)."""
        kwargs = dict(task.kwargs)
        jit = kwargs.pop("_jit", True)
        if task.ckpt_ctx is not None:
            # checkpointable body: inject the live Checkpoint context.
            # The context is not traceable, so the wrapper-level jit is
            # skipped — step bodies manage their own jit.
            kwargs["ckpt"] = task.ckpt_ctx
            jit = False
        if task.kind == "spmd":
            mesh = self.submesh(task.slot_ids, task.resources.mesh_shape)
            call = self._specialize(task.fn, mesh, jit)
            out = call(*task.args, **kwargs)
        else:  # plain python / bash-wrapped function: single slot
            out = task.fn(*task.args, **kwargs)
        out = jax.block_until_ready(out) if _has_arrays(out) else out
        return out


def _has_arrays(x) -> bool:
    return any(isinstance(l, jax.Array) for l in jax.tree.leaves(x))
