"""repro.core — RPEX-JAX: Parsl/DFK + RADICAL-Pilot integration, TPU-native.

Public API:
    DataFlowKernel, python_app, spmd_app, bash_app   (Parsl side)
    RPEXExecutor, PilotDescription                   (the integration)
    PilotManager, TaskManager, Agent, SlotScheduler  (RP side)
    PlacementPolicy, LeastLoaded, LocalityAware      (placement layer)
    ObjectStore, ObjectRef                           (data plane)
"""
from .agent import Agent
from .apps import bash_app, python_app, spmd_app
from .checkpoint import Checkpoint, CheckpointStore, TaskPreempted
from .dfk import DataFlowKernel, current_dfk
from .executors import Executor, ParslTask, ThreadPoolExecutor
from .faults import FaultInjector, PilotLost, SlotFailure
from .futures import (AppFuture, ResourceSpec, RetryPolicy, TaskRecord,
                      TaskState, model_kind, new_uid)
from .objectstore import (BlobLeaf, ObjectRef, ObjectStore, estimate_size,
                          materialize)
from .pilot import (Pilot, PilotDescription, PilotManager, PilotPool,
                    PoolScaler, ScalerConfig, TaskManager)
from .placement import (CostModelPolicy, LeastLoaded, LocalityAware,
                        PlacementPolicy, affinity_match, filter_healthy,
                        prefer_free_slots, prefer_specialized,
                        remote_bytes, resolve_policy)
from .rpex import RPEXExecutor
from .scheduler import SlotScheduler
from .spmd_executor import SPMDFunctionExecutor
from .serializer import (RemoteError, RemoteTraceback, SerializationError,
                         UnserializableResult)
from .store import EVENTS, StateStore, overhead_from_events, union_intervals
from .translator import bind_future, detect_kind, translate
from .transport import (InprocTransport, ProcessTransport, WorkerDied,
                        make_transport)

# Opt-in concurrency watchdog (REPRO_LOCK_WATCHDOG=1): instruments every
# lock the runtime allocates from here on and validates task-state
# transitions.  Installed after the submodule imports above so the
# STATE_MACHINE hook finds futures fully loaded; lock *construction*
# happens at runtime, so nothing is missed by installing last.
from ..analysis.watchdog import maybe_install_from_env as _wd_install
_wd_install()
del _wd_install

__all__ = [
    "Agent", "AppFuture", "BlobLeaf", "Checkpoint", "CheckpointStore",
    "CostModelPolicy",
    "DataFlowKernel", "EVENTS", "Executor", "FaultInjector",
    "InprocTransport",
    "LeastLoaded",
    "LocalityAware", "ObjectRef", "ObjectStore", "ParslTask", "Pilot",
    "PilotDescription",
    "PilotLost",
    "PilotManager", "PilotPool", "PlacementPolicy", "PoolScaler",
    "ProcessTransport", "RPEXExecutor", "RemoteError", "RemoteTraceback",
    "ResourceSpec", "RetryPolicy", "SPMDFunctionExecutor", "ScalerConfig",
    "SerializationError", "SlotFailure", "SlotScheduler", "StateStore",
    "TaskManager",
    "TaskPreempted", "TaskRecord", "TaskState",
    "ThreadPoolExecutor", "UnserializableResult", "WorkerDied",
    "affinity_match", "bash_app", "bind_future",
    "current_dfk", "detect_kind", "estimate_size", "filter_healthy",
    "make_transport", "materialize",
    "model_kind", "new_uid",
    "overhead_from_events",
    "prefer_free_slots", "prefer_specialized", "python_app",
    "remote_bytes",
    "resolve_policy", "spmd_app", "translate", "union_intervals",
]
