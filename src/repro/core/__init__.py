"""repro.core — RPEX-JAX: Parsl/DFK + RADICAL-Pilot integration, TPU-native.

Public API:
    DataFlowKernel, python_app, spmd_app, bash_app   (Parsl side)
    RPEXExecutor, PilotDescription                   (the integration)
    PilotManager, TaskManager, Agent, SlotScheduler  (RP side)
"""
from .agent import Agent
from .apps import bash_app, python_app, spmd_app
from .dfk import DataFlowKernel, current_dfk
from .executors import Executor, ParslTask, ThreadPoolExecutor
from .futures import (AppFuture, ResourceSpec, TaskRecord, TaskState,
                      new_uid)
from .pilot import (Pilot, PilotDescription, PilotManager, PilotPool,
                    TaskManager)
from .rpex import RPEXExecutor
from .scheduler import SlotScheduler
from .spmd_executor import SPMDFunctionExecutor
from .store import StateStore
from .translator import bind_future, detect_kind, translate

__all__ = [
    "Agent", "AppFuture", "DataFlowKernel", "Executor", "ParslTask",
    "Pilot", "PilotDescription", "PilotManager", "PilotPool", "RPEXExecutor",
    "ResourceSpec", "SPMDFunctionExecutor", "SlotScheduler", "StateStore",
    "TaskManager", "TaskRecord", "TaskState", "ThreadPoolExecutor",
    "bash_app", "bind_future", "current_dfk", "detect_kind", "new_uid",
    "python_app", "spmd_app", "translate",
]
