"""Per-pool object store — the data plane (docs/dataplane.md).

Results used to travel *by value*: every producer→consumer edge pickled
the full result through the AppFuture (and, in proc mode, through a
pipe), and the journal's serializability probe walked it again.  RP's
data-staging model and Colmena's Redis result queues both land on the
same fix: a task's result is published **once** into a shared store and
everything downstream moves a *handle* — an ``ObjectRef`` carrying the
size, a dtype/pytree summary, and the owning pilot.

Semantics (pilots are threads of one process, so "transfer" is exact
bookkeeping of the bytes a multi-host deployment would move):

* **same-pilot deref is zero-copy** — the consumer gets the producer's
  in-memory object, no serialization, no copy;
* **cross-pilot deref fetches once** — the first deref from a foreign
  pilot counts ``ref.size`` toward ``bytes_moved`` and caches the object
  on that pilot, so N consumers on one pilot pay one transfer;
* **ref-counting rides the DFK dep graph** — the dep manager registers
  one consumer per edge at launch and releases it when the consumer's
  future completes; an object whose every registered consumer edge has
  completed is GC-eligible: it is spilled to disk (if not already
  durable) and its memory dropped.  A later deref re-materializes from
  the spill.  Objects with no registered consumers (a workflow's final
  results) stay live until ``close()``;
* **spill is content-addressed** — payloads land next to the journal in
  ``<journal>.obj/`` as ``blob_<sha1>.pkl`` plus a tiny ``<oid>.ref``
  pointer, written tmp+fsync+rename (the checkpoint durability idiom).
  Checkpoint leaves stored through ``put_blob`` share the same blob
  namespace, so a checkpointed state leaf that equals a published result
  costs one file, not two;
* **lost pilots re-host** — ``rehost`` moves a dead pilot's live objects
  to a survivor (memory hand-over in-process; the spill covers a
  restart), so resilience recovery never dangles refs.

The journal path cooperates (store.py): a DONE record whose result is an
``ObjectRef`` journals the ref *metadata* (oid, size, kind) and the
write-behind writer ensures the payload is spilled before the line
lands — durable-before-event, and exactly one serialization pass where
the old path walked a large result two or three times.
"""
from __future__ import annotations

import hashlib
import itertools
import os
import pickle
import tempfile
import threading
from typing import Any, Dict, Iterator, Optional, Set, Tuple

from . import serializer

DEFAULT_THRESHOLD = 64 * 1024       # publish results at/above this size

_oid_counter = itertools.count()


def _new_oid() -> str:
    return f"obj.{os.getpid()}.{next(_oid_counter):06d}"


# ----------------------------- size estimate ----------------------------- #
def estimate_size(value: Any, _depth: int = 0) -> int:
    """Cheap recursive byte estimate of a pytree-ish value: array leaves
    by ``nbytes``, bytes/str by length, containers by sum — never a
    serialization pass.  Non-leaf objects without a size signal count a
    token 32 bytes (small enough to stay inline)."""
    nb = getattr(value, "nbytes", None)
    if nb is not None:
        try:
            return int(nb)
        except (TypeError, ValueError):
            pass
    if isinstance(value, (bytes, bytearray, memoryview)):
        return len(value)
    if isinstance(value, str):
        return len(value)
    if _depth < 4:
        if isinstance(value, dict):
            return sum(estimate_size(v, _depth + 1)
                       for v in value.values()) + 64
        if isinstance(value, (list, tuple, set, frozenset)):
            return sum(estimate_size(v, _depth + 1) for v in value) + 64
    return 32


def _kind_summary(value: Any) -> str:
    """Human/journal-facing summary: dtype+shape for array leaves, type
    name otherwise."""
    dtype = getattr(value, "dtype", None)
    shape = getattr(value, "shape", None)
    if dtype is not None and shape is not None:
        return f"ndarray[{dtype}]{tuple(shape)}"
    return type(value).__name__


def _freeze(value: Any):
    """Mark a published ndarray read-only.  Published values are shared
    by reference: every same-pilot consumer derefs the same object, and
    proc-transport workers map one shared-memory mirror of it — a mutation
    anywhere would corrupt every other reader.  Freezing turns that
    silent race into an immediate ``ValueError`` (consumers that want to
    mutate copy first), and is what makes the transport's park-once
    segment cache safe.  Non-array values are left alone — the same
    contract holds, just unenforced."""
    flags = getattr(value, "flags", None)
    if flags is not None and getattr(flags, "writeable", False):
        try:
            value.flags.writeable = False
        except (AttributeError, ValueError):
            pass                        # views of foreign buffers etc.


# -------------------------------- ObjectRef ------------------------------ #
class ObjectRef:
    """Handle to a published value: everything placement and the journal
    need (size, kind, owning pilot) without the payload.  The in-process
    backpointer to the store is dropped on pickle — a ref that crossed a
    process boundary resolves only through a store sharing the spill
    directory."""

    __slots__ = ("oid", "size", "kind", "pilot_uid", "_store")

    def __init__(self, oid: str, size: int, kind: str,
                 pilot_uid: Optional[str], store: "Optional[ObjectStore]"):
        self.oid = oid
        self.size = size
        self.kind = kind
        self.pilot_uid = pilot_uid
        self._store = store

    def deref(self, pilot_uid: Optional[str] = None) -> Any:
        if self._store is None:
            raise RuntimeError(
                f"ObjectRef {self.oid} has no live store (crossed a "
                f"process boundary without a shared spill dir)")
        return self._store.get(self, pilot_uid=pilot_uid)

    def __getstate__(self):
        return (self.oid, self.size, self.kind, self.pilot_uid)

    def __setstate__(self, state):
        self.oid, self.size, self.kind, self.pilot_uid = state
        self._store = None

    def __repr__(self):
        return (f"<ObjectRef {self.oid} {self.kind} {self.size}B "
                f"@{self.pilot_uid}>")


class _Entry:
    __slots__ = ("value", "size", "kind", "owner", "consumers",
                 "registered", "sha", "cached_on", "dropped")

    def __init__(self, value, size, kind, owner):
        self.value = value
        self.size = size
        self.kind = kind
        self.owner = owner          # pilot uid holding the primary copy
        self.consumers = 0          # outstanding DFK consumer edges
        self.registered = 0         # total edges ever registered
        self.sha: Optional[str] = None   # set once spilled (blob id)
        self.cached_on: Set[str] = set()  # pilots holding a fetched copy
        self.dropped = False        # memory copy GC'd (spill is truth)


# ------------------------------- ObjectStore ----------------------------- #
class ObjectStore:
    """One per PilotPool.  Thread-safe; all counters under one lock —
    publish/deref are rare relative to scheduling events, and deref's
    fast path (same-pilot, in memory) does no copying under the lock."""

    def __init__(self, spill_dir: Optional[str] = None,
                 threshold: int = DEFAULT_THRESHOLD):
        self.threshold = threshold
        self._lock = threading.Lock()
        self._objects: Dict[str, _Entry] = {}
        self._blobs: Set[str] = set()       # shas known to be on disk
        self._spill_dir = spill_dir
        self._tmpdir: Optional[tempfile.TemporaryDirectory] = None
        self._closed = False
        # stats — exp6/exp11 and the docs' observability contract
        self.published = 0
        self.bytes_published = 0
        self.bytes_moved = 0        # cross-pilot fetches, counted once
                                    # per (object, consumer pilot)
        self.spills = 0
        self.rehosted = 0

    # ------------------------------ publish ----------------------------- #
    def maybe_publish(self, value: Any, owner: Optional[str]) -> Any:
        """Publish ``value`` when its estimated size reaches the
        threshold, else return it unchanged (small results stay inline —
        ``AppFuture.quick_result`` remains lock-free for them)."""
        if value is None or isinstance(value, ObjectRef):
            return value
        size = estimate_size(value)
        if size < self.threshold:
            return value
        return self.publish(value, owner, size=size)

    def publish(self, value: Any, owner: Optional[str],
                size: Optional[int] = None) -> ObjectRef:
        size = estimate_size(value) if size is None else size
        _freeze(value)
        oid = _new_oid()
        with self._lock:
            self._objects[oid] = _Entry(value, size, _kind_summary(value),
                                        owner)
            self.published += 1
            self.bytes_published += size
        return ObjectRef(oid, size, _kind_summary(value), owner, self)

    # -------------------------------- deref ----------------------------- #
    def get(self, ref, pilot_uid: Optional[str] = None) -> Any:
        """Dereference.  ``pilot_uid`` names the consuming pilot for byte
        accounting; ``None`` is a client-side read (uncounted).  Unknown
        oids fall back to the spill directory — the replay/restart path."""
        oid = ref.oid if isinstance(ref, ObjectRef) else ref
        with self._lock:
            e = self._objects.get(oid)
            if e is not None and not e.dropped:
                value = e.value
                self._account(e, pilot_uid)
                return value
        # cold: re-materialize from spill (outside the lock — disk read)
        value = self._load_spilled(oid)
        with self._lock:
            e = self._objects.get(oid)
            if e is None:
                e = _Entry(value, estimate_size(value),
                           _kind_summary(value), None)
                self._objects[oid] = e
            elif e.dropped:
                e.value = value
                e.dropped = False
            self._account(e, pilot_uid)
            return e.value

    def _account(self, e: _Entry, pilot_uid: Optional[str]):
        """Caller holds the lock: count a cross-pilot fetch once per
        (object, pilot)."""
        if (pilot_uid is not None and pilot_uid != e.owner
                and pilot_uid not in e.cached_on):
            e.cached_on.add(pilot_uid)
            self.bytes_moved += e.size

    # ------------------------------ refcount ----------------------------- #
    def add_consumers(self, oid: str, n: int = 1):
        """DFK dep manager: ``n`` more consumer edges will read this
        object.  Unknown oids (replayed workflows) are ignored."""
        with self._lock:
            e = self._objects.get(oid)
            if e is not None:
                e.consumers += n
                e.registered += n

    def release(self, oid: str):
        """One consumer edge completed.  At zero outstanding edges the
        object is GC'd: spilled (if not yet durable) and dropped from
        memory.  Releases past zero are ignored — the exactly-once
        contract is enforced here, not trusted from callers."""
        gc_entry = None
        with self._lock:
            e = self._objects.get(oid)
            if e is None or e.consumers <= 0:
                return
            e.consumers -= 1
            if e.consumers == 0 and not e.dropped:
                gc_entry = e
        if gc_entry is not None:
            self._gc(oid, gc_entry)

    def _gc(self, oid: str, e: _Entry):
        if self._closed:
            return                  # teardown: consumers are gone too
        try:
            self.ensure_spilled(oid)
        except serializer.SerializationError:
            return                  # unspillable: keep the memory copy
        except OSError:
            return                  # spill dir tearing down concurrently:
                                    # keep the memory copy, close() wins
        with self._lock:
            if e.consumers == 0:    # no new edge registered meanwhile
                e.value = None
                e.dropped = True
                e.cached_on.clear()

    # -------------------------------- spill ------------------------------ #
    @property
    def spill_dir(self) -> str:
        if self._spill_dir is None:
            self._tmpdir = tempfile.TemporaryDirectory(prefix="repro-obj-")
            self._spill_dir = self._tmpdir.name
        os.makedirs(self._spill_dir, exist_ok=True)
        return self._spill_dir

    def _blob_path(self, sha: str) -> str:
        return os.path.join(self.spill_dir, f"blob_{sha}.pkl")

    def _ref_path(self, oid: str) -> str:
        return os.path.join(self.spill_dir, f"{oid}.ref")

    def _write_atomic(self, path: str, data: bytes):
        d = os.path.dirname(path)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as fh:
                fh.write(data)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def put_blob(self, value: Any) -> Tuple[str, int]:
        """Content-addressed persist: ``(sha, size)``.  A blob already on
        disk is not rewritten — this is the dedupe point shared by result
        spills and checkpoint pytree leaves.  Frozen arrays are pickled
        from a writable copy: ndarray pickles encode flag state, and the
        publish-time freeze must not make a spilled result hash
        differently from the byte-identical checkpoint leaf."""
        flags = getattr(value, "flags", None)
        if flags is not None and not getattr(flags, "writeable", True):
            try:
                value = value.copy()
            except (AttributeError, TypeError):
                pass
        blob = serializer.dumps(value)
        sha = hashlib.sha1(blob).hexdigest()
        with self._lock:
            known = sha in self._blobs
        if not known:
            path = self._blob_path(sha)
            if not os.path.exists(path):
                self._write_atomic(path, blob)
                with self._lock:
                    self.spills += 1
            with self._lock:
                self._blobs.add(sha)
        return sha, len(blob)

    def get_blob(self, sha: str) -> Any:
        with open(self._blob_path(sha), "rb") as fh:
            return pickle.load(fh)

    def ensure_spilled(self, oid: str) -> Optional[str]:
        """Make ``oid`` durable (idempotent): payload blob + ``.ref``
        pointer on disk before returning.  The journal writer calls this
        before the DONE line lands.  Returns the blob sha (None for
        unknown oids).  Raises SerializationError for unspillable
        values."""
        with self._lock:
            e = self._objects.get(oid)
            if e is None or e.sha is not None:
                return e.sha if e is not None else None
            value = e.value
        sha, _ = self.put_blob(value)
        self._write_atomic(self._ref_path(oid), sha.encode("ascii"))
        with self._lock:
            e.sha = sha
        return sha

    def _load_spilled(self, oid: str) -> Any:
        ref_path = self._ref_path(oid)
        try:
            with open(ref_path, "rb") as fh:
                sha = fh.read().decode("ascii").strip()
        except OSError:
            raise KeyError(f"object {oid} is not in the store and has "
                           f"no spill under {self._spill_dir}") from None
        value = self.get_blob(sha)
        _freeze(value)                  # reloads are published values too
        return value

    def has_spilled(self, oid: str) -> bool:
        with self._lock:
            e = self._objects.get(oid)
            if e is not None and e.sha is not None:
                return True
        return (self._spill_dir is not None
                and os.path.exists(self._ref_path(oid)))

    # ------------------------------- rehost ------------------------------ #
    def rehost(self, lost_uid: str, survivor_uid: Optional[str]) -> int:
        """A pilot died or retired: move ownership of its live objects to
        ``survivor_uid`` so existing refs keep resolving without a
        cross-pilot charge against a dead owner.  In-process the value is
        already reachable (hand-over, not copy); a dropped value stays
        loadable from its spill.  Returns the number re-homed."""
        n = 0
        with self._lock:
            for e in self._objects.values():
                if e.owner == lost_uid:
                    e.owner = survivor_uid
                    e.cached_on.discard(survivor_uid)
                    n += 1
            self.rehosted += n
        return n

    # ------------------------------- helpers ----------------------------- #
    def live_count(self) -> int:
        with self._lock:
            return sum(1 for e in self._objects.values() if not e.dropped)

    def entry(self, oid: str) -> Optional[_Entry]:
        """Test/introspection access to the raw entry."""
        with self._lock:
            return self._objects.get(oid)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "published": self.published,
                "bytes_published": self.bytes_published,
                "bytes_moved": self.bytes_moved,
                "spills": self.spills,
                "rehosted": self.rehosted,
                "live": sum(1 for e in self._objects.values()
                            if not e.dropped),
            }

    def close(self):
        self._closed = True         # late releases become no-op GCs
        with self._lock:
            self._objects.clear()
            self._blobs.clear()
        if self._tmpdir is not None:
            self._tmpdir.cleanup()
            self._tmpdir = None
            self._spill_dir = None


class BlobLeaf:
    """Content-addressed placeholder for a large pytree leaf persisted
    through ``ObjectStore.put_blob`` — the checkpoint store writes these
    into its pickled skeletons so a state leaf that equals a published
    result (or a leaf shared by many steps) costs one blob on disk, not
    one copy per checkpoint.  Rehydrates with ``get_blob``."""

    __slots__ = ("sha", "size", "kind")

    def __init__(self, sha: str, size: int, kind: str):
        self.sha, self.size, self.kind = sha, size, kind

    def __getstate__(self):
        return (self.sha, self.size, self.kind)

    def __setstate__(self, state):
        self.sha, self.size, self.kind = state

    def __repr__(self):
        return f"<BlobLeaf {self.sha[:12]} {self.kind} {self.size}B>"


# ------------------------- ref plumbing helpers -------------------------- #
def iter_refs(obj: Any, _depth: int = 0) -> Iterator[ObjectRef]:
    """Yield every ObjectRef in a (shallow) args/kwargs structure."""
    if isinstance(obj, ObjectRef):
        yield obj
        return
    if _depth >= 3:
        return
    if isinstance(obj, dict):
        for v in obj.values():
            yield from iter_refs(v, _depth + 1)
    elif isinstance(obj, (list, tuple)):
        for v in obj:
            yield from iter_refs(v, _depth + 1)


def materialize(obj: Any, store: Optional[ObjectStore],
                pilot_uid: Optional[str] = None, _depth: int = 0) -> Any:
    """Replace every ObjectRef in args/kwargs with its value, charging
    cross-pilot bytes to ``pilot_uid`` — called on the *executing* pilot,
    so byte attribution survives stealing, retries, and migration."""
    if isinstance(obj, ObjectRef):
        s = obj._store or store
        if s is None:
            raise RuntimeError(f"cannot materialize {obj!r}: no store")
        return s.get(obj, pilot_uid=pilot_uid)
    if _depth >= 3:
        return obj
    if isinstance(obj, dict):
        out = {k: materialize(v, store, pilot_uid, _depth + 1)
               for k, v in obj.items()}
        return out if any(o is not n for o, n in
                          zip(obj.values(), out.values())) else obj
    if isinstance(obj, (list, tuple)):
        out = [materialize(v, store, pilot_uid, _depth + 1) for v in obj]
        if all(o is n for o, n in zip(obj, out)):
            return obj
        if isinstance(obj, list):
            return out
        if hasattr(obj, "_fields"):         # NamedTuple
            return type(obj)(*out)
        return tuple(out)
    return obj
