"""WorkerTransport — pluggable agent→worker call path.

The Agent schedules; a *transport* executes.  This is the paper's
master/worker split (RP's MPI executor: the agent process schedules, OS
processes run task bodies) extracted behind one seam so a Pilot can own
either of:

  * ``InprocTransport`` (default) — the original persistent thread pool.
    Task bodies run in the agent's process; byte-for-byte the pre-split
    behavior, and the only mode for spmd tasks (a sub-mesh is bound to
    this process's XLA client).

  * ``ProcessTransport`` — a lazily-grown pool of OS worker processes,
    one duplex pipe each.  Python/bash task bodies execute *off the GIL*:
    the local pool thread that drives a task blocks in ``Connection.recv``
    (GIL released) while the child burns a core, so bulk CPU-bound
    throughput scales with cores instead of serializing behind the
    interpreter lock (the exp3 ceiling ROADMAP item 3 calls out).

Both transports share the local thread-pool machinery (``_PoolBase``):
the agent's bookkeeping — state transitions, finish paths, replica and
preempt logic — always runs in these local threads, so every Agent
invariant holds identically in both modes; only the body call in
``execute()`` differs.  The pool is bounded *and reaped*: a thread idle
longer than ``idle_s`` with no undispatched work retires itself, so a
64-task burst does not leave 64 live threads at steady state.

Process-mode protocol (FIFO pipe, one in-flight run per worker, per-run
``seq`` so a stale message from a previous run can never poison the
next task on a reused worker):

  parent → child:  ("run", seq, payload, checkpointable, key, snapshot)
                   ("preempt", seq)            cooperative preempt flag
                   ("save_ack", seq, preempt)  checkpoint persisted
                   ("stop",)
  child → parent:  ("save", seq, step, blob)   body called ckpt.save
                   ("done", seq, blob)         result crossed back
                   ("done_raw", seq, info)     result could not cross
                   ("preempted", seq, step)    body unwound at a save
                   ("error", seq, blob)        packed remote exception

Checkpoint proxying keeps the inproc persist-then-raise contract across
the boundary: the child's ``ckpt.save`` *blocks* until the parent has
persisted the step through the pilot's CheckpointStore and acked with
the current preempt flag — only then does the body continue (or unwind
with ``TaskPreempted``), so a handed-off task always has its last step
durable parent-side.  ``restore`` is a snapshot shipped with the run
request (the latest parent-side checkpoint).  Preempt requests travel
``Checkpoint._forward`` → pipe → the child's flag, honored at its next
``save``/``preempt_requested`` poll — exactly the inproc cadence.

Worker death (crash, OOM-kill, fault injection) surfaces as an EOF on
the pipe: the in-flight task FAILs visibly with ``WorkerDied`` (feeding
the agent's normal retry/replica paths), the slot is released by the
usual finish path, the corpse is discarded, and the pool lazily
respawns on the next checkout.  spmd tasks (``TaskRecord.inproc_only``,
stamped by the translator) and bodies the serializer cannot ship fall
back to in-process execution rather than failing the task.
"""
from __future__ import annotations

import multiprocessing
import queue
import threading
import warnings
from typing import Callable, Optional

from . import serializer
from .checkpoint import TaskPreempted

_SENTINEL = object()


class WorkerDied(RuntimeError):
    """A process-mode worker died while (or before) running a task; the
    task FAILs through the agent's normal fault path and may retry."""


class _PoolBase:
    """Local persistent thread pool (the MPI-Worker analog) shared by
    both transports: lazy growth to ``max_workers``, bounded idle (a
    worker idle > ``idle_s`` with nothing undispatched reaps itself),
    and dropped handles for exited threads."""

    def __init__(self, max_workers: int = 32, idle_s: float = 30.0):
        self.max_workers = max_workers
        self.idle_s = idle_s
        self.executor = None            # set by start()
        self._run_cb: Optional[Callable] = None
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._lock = threading.Lock()
        self._threads: set = set()
        self._ready = 0                 # dispatched, not yet claimed
        self._executing = 0             # claimed, still running
        self._closed = False            # set by shutdown(); later dispatch
                                        # raises instead of stranding the
                                        # task behind a leftover poison pill

    # ------------------------------ protocol ----------------------------- #
    def start(self, run_cb: Callable, executor) -> "_PoolBase":
        """Bind the agent's per-task runner and its (inproc) executor.
        Threads stay lazy; nothing spawns until the first dispatch."""
        self._run_cb = run_cb
        self.executor = executor
        return self

    def dispatch(self, task):
        """Hand a scheduled task to the pool.  Grows until the thread set
        covers all claimed work (executing + undispatched), so tasks
        scheduled in one pass run concurrently."""
        with self._lock:
            if self._closed:
                # a post-shutdown dispatch would race the poison pills: a
                # freshly-spawned thread can consume a leftover sentinel
                # and retire, stranding the task in the queue forever
                raise RuntimeError("transport pool is shut down")
            self._ready += 1
            want = self._executing + self._ready
            if len(self._threads) < min(self.max_workers, want):
                th = threading.Thread(target=self._worker_loop, daemon=True)
                self._threads.add(th)
                th.start()
        self._q.put(task)

    def execute(self, task):
        raise NotImplementedError

    def shutdown(self):
        with self._lock:
            self._closed = True         # reject future dispatches before
            n = len(self._threads)      # any pill can hit the queue
        for _ in range(n):              # one poison pill per live thread;
            self._q.put(_SENTINEL)      # a racing self-reap leaves a spare
                                        # pill in the queue, harmlessly

    @property
    def n_threads(self) -> int:
        """Live pool threads (the hygiene-regression observable)."""
        with self._lock:
            return len(self._threads)

    @property
    def n_idle(self) -> int:
        with self._lock:
            return len(self._threads) - self._executing

    # ------------------------------ internals ---------------------------- #
    def _worker_loop(self):
        me = threading.current_thread()
        while True:
            try:
                item = self._q.get(timeout=self.idle_s)
            except queue.Empty:
                with self._lock:
                    if self._ready == 0:
                        # idle past the bound with nothing undispatched:
                        # retire.  dispatch() increments _ready under
                        # this lock *before* the queue put, so a racing
                        # dispatch either sees us gone (and spawns a
                        # replacement) or we see its claim and keep
                        # waiting — a task is never stranded.
                        self._threads.discard(me)
                        return
                continue                # claimed work is in flight to the
                                        # queue — wait another round
            if item is _SENTINEL:
                with self._lock:
                    self._threads.discard(me)
                return
            with self._lock:
                self._ready -= 1
                self._executing += 1
            try:
                self._run_cb(item)
            finally:
                with self._lock:
                    self._executing -= 1


class InprocTransport(_PoolBase):
    """The original in-process pool: body runs on the pool thread via the
    agent's SPMDFunctionExecutor.  Default; behavior-compatible."""

    name = "inproc"

    def execute(self, task):
        return self.executor.execute(task)


class _ProcWorker:
    __slots__ = ("proc", "conn", "send_lock", "seq")

    def __init__(self, proc, conn):
        self.proc = proc
        self.conn = conn
        self.send_lock = threading.Lock()   # driver (save_ack) and the
        self.seq = 0                        # preempt forwarder both send


class ProcessTransport(_PoolBase):
    """Process pool: each local pool thread drives at most one worker
    process over a duplex pipe; the body executes in the child, off the
    GIL.  Workers spawn lazily up to ``max_workers``, are reused across
    tasks, and are discarded + lazily respawned on death."""

    name = "proc"

    def __init__(self, max_workers: int = 32, idle_s: float = 30.0,
                 start_method: Optional[str] = None):
        super().__init__(max_workers, idle_s)
        # fork is the cheap default on linux (the child never touches the
        # parent's XLA runtime: the serializer host-transfers jax leaves
        # before they cross); spawn is the conservative opt-in
        self._mp = multiprocessing.get_context(start_method or "fork")
        self._pcond = threading.Condition()
        self._free: list = []           # idle workers (LIFO: warm reuse)
        self._all: set = set()          # every live worker (shutdown sweep)
        self._total = 0                 # live + being-spawned workers

    # ------------------------------ execution ---------------------------- #
    def execute(self, task):
        if task.inproc_only or task.kind == "spmd":
            # a sub-mesh is bound to the parent's XLA client — spmd never
            # crosses (the translator stamps inproc_only accordingly)
            return self.executor.execute(task)
        kwargs = dict(task.kwargs)
        kwargs.pop("_jit", None)        # spmd-only knob; meaningless here
        kwargs.pop("ckpt", None)        # the child injects its own proxy
        try:
            payload = serializer.pack_task(task.fn, task.args, kwargs)
        except serializer.SerializationError:
            # body cannot ship — degrade to in-process execution instead
            # of failing the task (same spirit as the result-side
            # degradation: correctness first, parallelism best-effort)
            return self.executor.execute(task)
        w = self._checkout()
        try:
            result = self._drive(w, task, payload)
        except WorkerDied:
            self._discard(w)
            raise                       # agent's fault path: FAIL + retry
        except BaseException:           # noqa: BLE001 — remote error or
            self._checkin(w)            # TaskPreempted: worker is healthy
            raise
        self._checkin(w)
        return result

    def _drive(self, w: _ProcWorker, task, payload: bytes):
        """Run one task on one worker: send the run request, then pump
        the pipe until a terminal message.  Raises WorkerDied on EOF."""
        w.seq += 1
        seq = w.seq
        ctx = task.ckpt_ctx
        key = task.ckpt_key or task.uid
        snapshot = None
        if ctx is not None:
            got = ctx.restore()         # parent-side latest checkpoint
            if got is not None:
                try:
                    snapshot = (got[0], serializer.dumps(got[1]))
                except serializer.SerializationError:
                    snapshot = None     # unshippable state: fresh start
        self._send(w, ("run", seq, payload, ctx is not None, key, snapshot))
        if ctx is not None:
            def _fwd():
                try:
                    self._send(w, ("preempt", seq))
                except WorkerDied:
                    pass                # the recv loop will surface it
            ctx._forward = _fwd
            if ctx.preempt_requested():
                _fwd()                  # request landed before the hook —
                                        # re-send now that the run is out
        try:
            while True:
                try:
                    msg = w.conn.recv()
                except (EOFError, OSError) as e:
                    raise WorkerDied(
                        f"worker pid {w.proc.pid} died while running "
                        f"{task.uid}") from e
                if msg[1] != seq:
                    continue            # stale leftover from a prior run
                tag = msg[0]
                if tag == "save":
                    _, _, step, blob = msg
                    if ctx is not None and blob is not None:
                        # persist through the pilot's CheckpointStore
                        # BEFORE acking: the child's save() blocks until
                        # the step is durable here (persist-then-raise,
                        # same as inproc).  blob=None means the state
                        # could not cross — ack anyway, the body keeps
                        # running with a non-durable step (the store's
                        # own memory-only fallback has the same shape).
                        ctx.store.save(key, step, serializer.loads(blob))
                    pre = ctx is not None and ctx.preempt_requested()
                    self._send(w, ("save_ack", seq, pre))
                elif tag == "done":
                    return serializer.loads(msg[2])
                elif tag == "done_raw":
                    return serializer.UnserializableResult(*msg[2])
                elif tag == "preempted":
                    raise TaskPreempted(key, msg[2])
                elif tag == "error":
                    raise serializer.unpack_exception(msg[2])
        finally:
            if ctx is not None:
                ctx._forward = None
    # ----------------------------- worker pool --------------------------- #
    def _send(self, w: _ProcWorker, msg):
        try:
            with w.send_lock:
                w.conn.send(msg)
        except (OSError, ValueError, BrokenPipeError) as e:
            raise WorkerDied(
                f"worker pid {w.proc.pid} pipe closed") from e

    def _checkout(self) -> _ProcWorker:
        with self._pcond:
            while True:
                while self._free:
                    w = self._free.pop()
                    if w.proc.is_alive():
                        return w
                    self._all.discard(w)    # died while idle: silent drop
                    self._total -= 1
                    self._close(w)
                if self._total < self.max_workers:
                    self._total += 1
                    break
                self._pcond.wait(1.0)       # a thread beyond max_workers
                                            # waits for a checkin (cannot
                                            # happen while threads share
                                            # the same bound, but cheap)
        try:
            w = self._spawn()
        except BaseException:
            with self._pcond:
                self._total -= 1
                self._pcond.notify()
            raise
        with self._pcond:
            self._all.add(w)
        return w

    def _checkin(self, w: _ProcWorker):
        with self._pcond:
            self._free.append(w)
            self._pcond.notify()

    def _discard(self, w: _ProcWorker):
        """Drop a dead (or poisoned) worker; the pool respawns lazily on
        the next checkout."""
        with self._pcond:
            self._all.discard(w)
            self._total -= 1
            self._pcond.notify()
        self._close(w)

    def _spawn(self) -> _ProcWorker:
        parent, child = self._mp.Pipe(duplex=True)
        p = self._mp.Process(target=_proc_worker_main, args=(child,),
                             daemon=True)
        with warnings.catch_warnings():
            # jax warns on os.fork() in its multithreaded parent; the
            # child only pumps the pipe and runs user bodies — it never
            # calls into the parent's XLA runtime (array leaves are
            # host-transferred by the serializer before crossing)
            warnings.simplefilter("ignore", RuntimeWarning)
            p.start()
        child.close()
        return _ProcWorker(p, parent)

    @staticmethod
    def _close(w: _ProcWorker):
        try:
            w.conn.close()
        except OSError:
            pass
        if w.proc.is_alive():
            w.proc.terminate()
        w.proc.join(timeout=1.0)

    @property
    def n_procs(self) -> int:
        with self._pcond:
            return self._total

    def worker_pids(self, busy_only: bool = False) -> list:
        """Pids of live worker processes — the chaos harness's worker-kill
        and task-hang schedules pick their victims here (its presence is
        also how the FaultInjector recognizes a proc-transport pilot).
        ``busy_only`` restricts to workers currently driving a task."""
        with self._pcond:
            live = [w for w in self._all if w.proc.is_alive()]
            if busy_only:
                idle = {id(w) for w in self._free}
                live = [w for w in live if id(w) not in idle]
            return [w.proc.pid for w in live]

    def shutdown(self):
        super().shutdown()              # poison the local threads first
        with self._pcond:
            workers = list(self._all)
            self._all.clear()
            self._free.clear()
            self._total = 0
        for w in workers:
            try:
                with w.send_lock:
                    w.conn.send(("stop",))
            except (OSError, ValueError):
                pass
        for w in workers:
            w.proc.join(timeout=1.0)
            self._close(w)


# ----------------------------- child side -------------------------------- #
class _RemoteCheckpoint:
    """Child-side Checkpoint proxy: same interface the body sees inproc
    (restore/save/preempt_requested), backed by the pipe.  ``save``
    blocks for the parent's ack so persist-then-raise survives the
    boundary."""

    def __init__(self, conn, key: str, seq: int, snapshot):
        self.key = key
        self._conn = conn
        self._seq = seq
        self._snapshot = snapshot       # (step, state) shipped with "run"
        self._preempt = False

    def restore(self):
        return self._snapshot

    def save(self, step: int, state):
        blob, _ = serializer.pack_result(state)     # None = cannot cross;
        self._conn.send(("save", self._seq, step, blob))  # parent skips
        while True:                                       # the persist
            msg = self._conn.recv()
            if msg[0] == "save_ack" and msg[1] == self._seq:
                if msg[2] or self._preempt:
                    self._preempt = True
                    raise TaskPreempted(self.key, step)
                return
            if msg[0] == "preempt":
                if msg[1] == self._seq:
                    self._preempt = True
                continue                # stale seq: a prior run's flag

    def preempt_requested(self) -> bool:
        while self._conn.poll(0):       # drain any pending preempt flag;
            msg = self._conn.recv()     # no ack is outstanding here, so
            if msg[0] == "preempt" and msg[1] == self._seq:
                self._preempt = True    # only "preempt" can be queued
        return self._preempt


def _proc_worker_main(conn):
    """Worker-process entry: one run at a time, reused across tasks."""
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError, KeyboardInterrupt):
            return
        if msg[0] == "stop":
            conn.close()
            return
        if msg[0] != "run":
            continue                    # stale preempt from a finished run
        _, seq, payload, checkpointable, key, snapshot = msg
        try:
            fn, args, kwargs = serializer.loads(payload)
            if checkpointable:
                snap = None
                if snapshot is not None:
                    snap = (snapshot[0], serializer.loads(snapshot[1]))
                kwargs["ckpt"] = _RemoteCheckpoint(conn, key, seq, snap)
            result = fn(*args, **kwargs)
            blob, degraded = serializer.pack_result(result)
            if blob is None:
                conn.send(("done_raw", seq, degraded))
            else:
                conn.send(("done", seq, blob))
        except TaskPreempted as e:
            conn.send(("preempted", seq, e.step))
        except KeyboardInterrupt:
            return
        except BaseException as e:      # noqa: BLE001 — ship it back whole
            try:
                conn.send(("error", seq, serializer.pack_exception(e)))
            except (OSError, ValueError):
                return                  # parent is gone


# ------------------------------- factory ---------------------------------- #
TRANSPORTS = ("inproc", "proc")


def make_transport(name: Optional[str], max_workers: int = 32,
                   idle_s: float = 30.0,
                   start_method: Optional[str] = None):
    """Build a transport from a PilotDescription's knobs."""
    if name in (None, "inproc"):
        return InprocTransport(max_workers, idle_s)
    if name == "proc":
        return ProcessTransport(max_workers, idle_s, start_method)
    raise ValueError(
        f"unknown transport {name!r}; expected one of {TRANSPORTS}")
