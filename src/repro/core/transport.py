"""WorkerTransport — pluggable agent→worker call path.

The Agent schedules; a *transport* executes.  This is the paper's
master/worker split (RP's MPI executor: the agent process schedules, OS
processes run task bodies) extracted behind one seam so a Pilot can own
either of:

  * ``InprocTransport`` (default) — the original persistent thread pool.
    Task bodies run in the agent's process; byte-for-byte the pre-split
    behavior, and the only mode for spmd tasks (a sub-mesh is bound to
    this process's XLA client).

  * ``ProcessTransport`` — a lazily-grown pool of OS worker processes,
    one duplex pipe each.  Python/bash task bodies execute *off the GIL*:
    the local pool thread that drives a task blocks in ``Connection.recv``
    (GIL released) while the child burns a core, so bulk CPU-bound
    throughput scales with cores instead of serializing behind the
    interpreter lock (the exp3 ceiling ROADMAP item 3 calls out).

Both transports share the local thread-pool machinery (``_PoolBase``):
the agent's bookkeeping — state transitions, finish paths, replica and
preempt logic — always runs in these local threads, so every Agent
invariant holds identically in both modes; only the body call in
``execute()`` differs.  The pool is bounded *and reaped*: a thread idle
longer than ``idle_s`` with no undispatched work retires itself, so a
64-task burst does not leave 64 live threads at steady state.

Process-mode protocol (FIFO pipe, one in-flight run per worker, per-run
``seq`` so a stale message from a previous run can never poison the
next task on a reused worker):

  parent → child:  ("run", seq, payload, checkpointable, key, snapshot,
                    shm_threshold)
                   ("preempt", seq)            cooperative preempt flag
                   ("save_ack", seq, preempt)  checkpoint persisted
                   ("stop",)
  child → parent:  ("save", seq, step, blob)   body called ckpt.save
                   ("done", seq, blob)         result crossed back
                   ("done_shm", seq, meta)     large ndarray result in a
                                               shared-memory segment
                   ("done_raw", seq, info)     result could not cross
                   ("preempted", seq, step)    body unwound at a save
                   ("error", seq, blob)        packed remote exception

Shared-memory fast path (docs/dataplane.md): with ``shm_threshold`` set,
large C-contiguous ndarray *arguments* are parked in shared-memory
segments parent-side and cross the pipe as small ``_ShmLeaf`` markers
the child maps read-only — one memcpy instead of pickle-serialize +
chunked pipe writes + deserialize.  Frozen arrays (published by the
object store, which freezes on publish) are parked *once per object* in
``_SegCache`` and the one segment serves every consumer; mutable arrays
park one-shot per run.  Large ndarray *results* come back the same way:
the child writes the array into a segment named ``{prefix}r{pid}_{seq}``
and ships only the metadata.  Ownership is strict so nothing leaks: the
parent unlinks one-shot argument segments when the run reaches a
terminal state (the child is done reading by then), cached segments when
their array dies (weakref) or at shutdown, and result segments after
copying out — or, when a worker dies mid-run (SIGKILL, OOM), via
``_discard``'s reap of ``/dev/shm/{prefix}r{pid}_*``.  Workers use raw
``shm_open`` + ``mmap`` (``_RawSeg``) on both sides of their boundary:
the stdlib wrapper registers every attach/create with a
``resource_tracker``, and a forked child that first touches shm
post-fork starts its *own* tracker, which then warns at worker exit
about segments the parent rightly unlinked — so children simply never
register anything.

Checkpoint proxying keeps the inproc persist-then-raise contract across
the boundary: the child's ``ckpt.save`` *blocks* until the parent has
persisted the step through the pilot's CheckpointStore and acked with
the current preempt flag — only then does the body continue (or unwind
with ``TaskPreempted``), so a handed-off task always has its last step
durable parent-side.  ``restore`` is a snapshot shipped with the run
request (the latest parent-side checkpoint).  Preempt requests travel
``Checkpoint._forward`` → pipe → the child's flag, honored at its next
``save``/``preempt_requested`` poll — exactly the inproc cadence.

Worker death (crash, OOM-kill, fault injection) surfaces as an EOF on
the pipe: the in-flight task FAILs visibly with ``WorkerDied`` (feeding
the agent's normal retry/replica paths), the slot is released by the
usual finish path, the corpse is discarded, and the pool lazily
respawns on the next checkout.  spmd tasks (``TaskRecord.inproc_only``,
stamped by the translator) and bodies the serializer cannot ship fall
back to in-process execution rather than failing the task.
"""
from __future__ import annotations

import glob
import itertools
import mmap
import multiprocessing
import os
import queue
import threading
import warnings
import weakref
from typing import Callable, Optional

try:                                     # CPython's posix shm primitive —
    import _posixshmem                   # lets the forked child map
except ImportError:                      # segments without the stdlib
    _posixshmem = None                   # wrapper's resource tracker

from . import serializer
from .checkpoint import TaskPreempted

try:
    import numpy as _np
except ImportError:                      # pragma: no cover - numpy is a
    _np = None                           # hard dep everywhere else

_SENTINEL = object()

# --------------------------- shared memory -------------------------------- #
_SHM_PREFIX = "rpxshm"                   # /dev/shm/rpxshm* is ours to reap
_shm_counter = itertools.count()


class _ShmLeaf:
    """Pipe-crossing marker for an ndarray parked in a shared-memory
    segment: (segment name, shape, dtype str).  Pickles tiny."""

    __slots__ = ("name", "shape", "dtype")

    def __init__(self, name, shape, dtype):
        self.name, self.shape, self.dtype = name, shape, dtype

    def __getstate__(self):
        return (self.name, self.shape, self.dtype)

    def __setstate__(self, s):
        self.name, self.shape, self.dtype = s


def _shm_eligible(v, threshold: int) -> bool:
    return (_np is not None and isinstance(v, _np.ndarray)
            and not v.dtype.hasobject and v.nbytes >= threshold
            and v.flags.c_contiguous)


def _shm_attach(name: str):
    """Attach an existing segment.  Attach *registers* with the resource
    tracker on 3.8–3.12 (bpo-39959), but every worker is a child of the
    pilot process and children inherit the parent's tracker, so the
    registration lands in the same per-name set the creator's did — a
    no-op — and the eventual ``unlink`` unregisters it exactly once."""
    from multiprocessing import shared_memory
    return shared_memory.SharedMemory(name=name)


def _shm_park(arr, name: Optional[str] = None):
    """Copy an ndarray into a fresh segment; returns (leaf, segment)."""
    from multiprocessing import shared_memory
    if name is None:
        name = f"{_SHM_PREFIX}a{os.getpid()}_{next(_shm_counter)}"
    seg = shared_memory.SharedMemory(create=True, size=arr.nbytes, name=name)
    _np.ndarray(arr.shape, dtype=arr.dtype, buffer=seg.buf)[...] = arr
    return _ShmLeaf(seg.name, arr.shape, str(arr.dtype)), seg


class _RawSeg:
    """Child-side segment handle: raw ``shm_open`` + ``mmap``, no
    ``multiprocessing.shared_memory``.  A forked worker that first
    touches shm *after* the fork would otherwise start its own resource
    tracker, which then warns at worker exit about every segment the
    parent (rightly) unlinked.  Children therefore never register
    anything; the parent remains the sole tracker client."""

    __slots__ = ("name", "mm")

    def __init__(self, name, mm):
        self.name, self.mm = name, mm

    @property
    def buf(self):
        return self.mm

    def close(self):
        try:
            self.mm.close()
        except (BufferError, OSError):
            pass                        # a live view pins the map; the
                                        # array's GC drops it

    def unlink(self):
        _posixshmem.shm_unlink("/" + self.name)


def _shm_attach_child(name: str):
    """Read-only attach from a worker process, tracker-free."""
    if _posixshmem is None:             # pragma: no cover - linux has it
        return _shm_attach(name)
    fd = _posixshmem.shm_open("/" + name, os.O_RDONLY, mode=0)
    try:
        size = os.fstat(fd).st_size
        mm = mmap.mmap(fd, size, prot=mmap.PROT_READ)
    finally:
        os.close(fd)
    return _RawSeg(name, mm)


def _shm_park_child(arr, name: str):
    """Create + fill a segment from a worker process, tracker-free; the
    parent owns the unlink (or ``_shm_reap`` does, if we die first)."""
    if _posixshmem is None:             # pragma: no cover - linux has it
        return _shm_park(arr, name=name)
    fd = _posixshmem.shm_open("/" + name,
                              os.O_RDWR | os.O_CREAT | os.O_EXCL,
                              mode=0o600)
    try:
        os.ftruncate(fd, arr.nbytes)
        mm = mmap.mmap(fd, arr.nbytes)
    finally:
        os.close(fd)
    _np.ndarray(arr.shape, dtype=arr.dtype, buffer=mm)[...] = arr
    return _ShmLeaf(name, arr.shape, str(arr.dtype)), _RawSeg(name, mm)


class _SegCache:
    """Park-once reuse for *frozen* argument arrays.

    The object store freezes every ndarray it publishes
    (``writeable=False``) and same-pilot ``materialize`` hands each
    consumer the very same object, so a fan-out of N proc-mode consumers
    would otherwise pay N identical park copies (a 4 MB park is ~6 ms of
    zero-fill page faults — costlier than the pickle it replaces).  Keyed
    on ``id()`` with a weakref guard: when the array dies (object-store
    GC dropping the value), the callback unlinks the segment.  Mutable
    arrays never enter the cache — they take the one-shot park path."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: dict = {}        # id(arr) -> (leaf, seg, wref)

    def park(self, arr) -> _ShmLeaf:
        key = id(arr)
        with self._lock:
            hit = self._entries.get(key)
            if hit is not None and hit[2]() is arr:
                return hit[0]
            leaf, seg = _shm_park(arr)

            def _evict(_wr, seg=seg):
                _shm_release([seg])     # no lock: may fire mid-GC on any
                                        # thread; the dict slot is swept
                                        # lazily below
            self._entries[key] = (leaf, seg, weakref.ref(arr, _evict))
            if len(self._entries) > 64:
                for k, (_, _, wr) in list(self._entries.items()):
                    if wr() is None:
                        self._entries.pop(k, None)
            return leaf

    def close(self):
        with self._lock:
            entries, self._entries = self._entries, {}
        for _, seg, wr in entries.values():
            if wr() is not None:        # dead entries already unlinked
                _shm_release([seg])     # by their weakref callback


def _shm_substitute(args: tuple, kwargs: dict, threshold: int,
                    cache: Optional[_SegCache] = None):
    """Replace top-level large ndarray args/kwarg values with _ShmLeaf
    markers.  Returns (args, kwargs, created segments) — the caller owns
    the one-shot segments and unlinks them once the run is terminal;
    cache-parked segments (frozen arrays) are owned by the cache."""
    segs = []

    def swap(v):
        if _shm_eligible(v, threshold):
            if cache is not None and not v.flags.writeable:
                return cache.park(v)
            leaf, seg = _shm_park(v)
            segs.append(seg)
            return leaf
        return v

    new_args = tuple(swap(v) for v in args)
    new_kwargs = {k: swap(v) for k, v in kwargs.items()}
    return new_args, new_kwargs, segs


def _shm_release(segs):
    for seg in segs:
        try:
            seg.close()
            seg.unlink()
        except (FileNotFoundError, OSError):
            pass


class WorkerDied(RuntimeError):
    """A process-mode worker died while (or before) running a task; the
    task FAILs through the agent's normal fault path and may retry."""


class _PoolBase:
    """Local persistent thread pool (the MPI-Worker analog) shared by
    both transports: lazy growth to ``max_workers``, bounded idle (a
    worker idle > ``idle_s`` with nothing undispatched reaps itself),
    and dropped handles for exited threads."""

    def __init__(self, max_workers: int = 32, idle_s: float = 30.0):
        self.max_workers = max_workers
        self.idle_s = idle_s
        self.executor = None            # set by start()
        self._run_cb: Optional[Callable] = None
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._lock = threading.Lock()
        self._threads: set = set()
        self._ready = 0                 # dispatched, not yet claimed
        self._executing = 0             # claimed, still running
        self._closed = False            # set by shutdown(); later dispatch
                                        # raises instead of stranding the
                                        # task behind a leftover poison pill

    # ------------------------------ protocol ----------------------------- #
    def start(self, run_cb: Callable, executor) -> "_PoolBase":
        """Bind the agent's per-task runner and its (inproc) executor.
        Threads stay lazy; nothing spawns until the first dispatch."""
        self._run_cb = run_cb
        self.executor = executor
        return self

    def dispatch(self, task):
        """Hand a scheduled task to the pool.  Grows until the thread set
        covers all claimed work (executing + undispatched), so tasks
        scheduled in one pass run concurrently."""
        with self._lock:
            if self._closed:
                # a post-shutdown dispatch would race the poison pills: a
                # freshly-spawned thread can consume a leftover sentinel
                # and retire, stranding the task in the queue forever
                raise RuntimeError("transport pool is shut down")
            self._ready += 1
            want = self._executing + self._ready
            if len(self._threads) < min(self.max_workers, want):
                th = threading.Thread(target=self._worker_loop, daemon=True)
                self._threads.add(th)
                th.start()
        self._q.put(task)

    def execute(self, task):
        raise NotImplementedError

    def shutdown(self):
        with self._lock:
            self._closed = True         # reject future dispatches before
            n = len(self._threads)      # any pill can hit the queue
        for _ in range(n):              # one poison pill per live thread;
            self._q.put(_SENTINEL)      # a racing self-reap leaves a spare
                                        # pill in the queue, harmlessly

    @property
    def n_threads(self) -> int:
        """Live pool threads (the hygiene-regression observable)."""
        with self._lock:
            return len(self._threads)

    @property
    def n_idle(self) -> int:
        with self._lock:
            return len(self._threads) - self._executing

    # ------------------------------ internals ---------------------------- #
    def _worker_loop(self):
        me = threading.current_thread()
        while True:
            try:
                item = self._q.get(timeout=self.idle_s)
            except queue.Empty:
                with self._lock:
                    if self._ready == 0:
                        # idle past the bound with nothing undispatched:
                        # retire.  dispatch() increments _ready under
                        # this lock *before* the queue put, so a racing
                        # dispatch either sees us gone (and spawns a
                        # replacement) or we see its claim and keep
                        # waiting — a task is never stranded.
                        self._threads.discard(me)
                        return
                continue                # claimed work is in flight to the
                                        # queue — wait another round
            if item is _SENTINEL:
                with self._lock:
                    self._threads.discard(me)
                return
            with self._lock:
                self._ready -= 1
                self._executing += 1
            try:
                self._run_cb(item)
            finally:
                with self._lock:
                    self._executing -= 1


class InprocTransport(_PoolBase):
    """The original in-process pool: body runs on the pool thread via the
    agent's SPMDFunctionExecutor.  Default; behavior-compatible."""

    name = "inproc"

    def execute(self, task):
        return self.executor.execute(task)


class _ProcWorker:
    __slots__ = ("proc", "conn", "send_lock", "seq")

    def __init__(self, proc, conn):
        self.proc = proc
        self.conn = conn
        self.send_lock = threading.Lock()   # driver (save_ack) and the
        self.seq = 0                        # preempt forwarder both send


class ProcessTransport(_PoolBase):
    """Process pool: each local pool thread drives at most one worker
    process over a duplex pipe; the body executes in the child, off the
    GIL.  Workers spawn lazily up to ``max_workers``, are reused across
    tasks, and are discarded + lazily respawned on death."""

    name = "proc"

    def __init__(self, max_workers: int = 32, idle_s: float = 30.0,
                 start_method: Optional[str] = None,
                 shm_threshold: Optional[int] = None):
        super().__init__(max_workers, idle_s)
        self.shm_threshold = shm_threshold   # ndarray args/results at or
                                             # above this cross via shared
                                             # memory; None = pickle pipe
        # fork is the cheap default on linux (the child never touches the
        # parent's XLA runtime: the serializer host-transfers jax leaves
        # before they cross); spawn is the conservative opt-in
        self._mp = multiprocessing.get_context(start_method or "fork")
        self._seg_cache = _SegCache()   # park-once for frozen (published)
                                        # argument arrays
        self._pcond = threading.Condition()
        self._free: list = []           # idle workers (LIFO: warm reuse)
        self._all: set = set()          # every live worker (shutdown sweep)
        self._total = 0                 # live + being-spawned workers

    # ------------------------------ execution ---------------------------- #
    def execute(self, task):
        if task.inproc_only or task.kind == "spmd":
            # a sub-mesh is bound to the parent's XLA client — spmd never
            # crosses (the translator stamps inproc_only accordingly)
            return self.executor.execute(task)
        kwargs = dict(task.kwargs)
        kwargs.pop("_jit", None)        # spmd-only knob; meaningless here
        kwargs.pop("ckpt", None)        # the child injects its own proxy
        args = task.args
        segs = []
        if self.shm_threshold is not None:
            # park large ndarray inputs in shared memory: the child
            # re-attaches read-only, so only tiny markers cross the pipe
            args, kwargs, segs = _shm_substitute(args, kwargs,
                                                 self.shm_threshold,
                                                 cache=self._seg_cache)
        try:
            try:
                payload = serializer.pack_task(task.fn, args, kwargs)
            except serializer.SerializationError:
                # body cannot ship — degrade to in-process execution
                # instead of failing the task (same spirit as the
                # result-side degradation: correctness first,
                # parallelism best-effort)
                return self.executor.execute(task)
            w = self._checkout()
            try:
                result = self._drive(w, task, payload)
            except WorkerDied:
                self._discard(w)
                raise                   # agent's fault path: FAIL + retry
            except BaseException:       # noqa: BLE001 — remote error or
                self._checkin(w)        # TaskPreempted: worker is healthy
                raise
            self._checkin(w)
            return result
        finally:
            # the run is terminal (or never started): the child is done
            # reading, so the argument segments can go.  Parent-side
            # unlink is what makes arg segments leak-proof no matter how
            # the child dies.
            _shm_release(segs)

    def _drive(self, w: _ProcWorker, task, payload: bytes):
        """Run one task on one worker: send the run request, then pump
        the pipe until a terminal message.  Raises WorkerDied on EOF."""
        w.seq += 1
        seq = w.seq
        ctx = task.ckpt_ctx
        key = task.ckpt_key or task.uid
        snapshot = None
        if ctx is not None:
            got = ctx.restore()         # parent-side latest checkpoint
            if got is not None:
                try:
                    snapshot = (got[0], serializer.dumps(got[1]))
                except serializer.SerializationError:
                    snapshot = None     # unshippable state: fresh start
        self._send(w, ("run", seq, payload, ctx is not None, key, snapshot,
                       self.shm_threshold))
        if ctx is not None:
            def _fwd():
                try:
                    self._send(w, ("preempt", seq))
                except WorkerDied:
                    pass                # the recv loop will surface it
            ctx._forward = _fwd
            if ctx.preempt_requested():
                _fwd()                  # request landed before the hook —
                                        # re-send now that the run is out
        try:
            while True:
                try:
                    msg = w.conn.recv()
                except (EOFError, OSError) as e:
                    raise WorkerDied(
                        f"worker pid {w.proc.pid} died while running "
                        f"{task.uid}") from e
                if msg[1] != seq:
                    continue            # stale leftover from a prior run
                tag = msg[0]
                if tag == "save":
                    _, _, step, blob = msg
                    if ctx is not None and blob is not None:
                        # persist through the pilot's CheckpointStore
                        # BEFORE acking: the child's save() blocks until
                        # the step is durable here (persist-then-raise,
                        # same as inproc).  blob=None means the state
                        # could not cross — ack anyway, the body keeps
                        # running with a non-durable step (the store's
                        # own memory-only fallback has the same shape).
                        ctx.store.save(key, step, serializer.loads(blob))
                    pre = ctx is not None and ctx.preempt_requested()
                    self._send(w, ("save_ack", seq, pre))
                elif tag == "done":
                    return serializer.loads(msg[2])
                elif tag == "done_shm":
                    name, shape, dtype = msg[2]
                    seg = _shm_attach(name)
                    try:
                        return _np.ndarray(shape, dtype=dtype,
                                           buffer=seg.buf).copy()
                    finally:
                        _shm_release([seg])
                elif tag == "done_raw":
                    return serializer.UnserializableResult(*msg[2])
                elif tag == "preempted":
                    raise TaskPreempted(key, msg[2])
                elif tag == "error":
                    raise serializer.unpack_exception(msg[2])
        finally:
            if ctx is not None:
                ctx._forward = None
    # ----------------------------- worker pool --------------------------- #
    def _send(self, w: _ProcWorker, msg):
        try:
            with w.send_lock:
                w.conn.send(msg)
        except (OSError, ValueError, BrokenPipeError) as e:
            raise WorkerDied(
                f"worker pid {w.proc.pid} pipe closed") from e

    def _checkout(self) -> _ProcWorker:
        with self._pcond:
            while True:
                while self._free:
                    w = self._free.pop()
                    if w.proc.is_alive():
                        return w
                    self._all.discard(w)    # died while idle: silent drop
                    self._total -= 1
                    self._close(w)
                if self._total < self.max_workers:
                    self._total += 1
                    break
                self._pcond.wait(1.0)       # a thread beyond max_workers
                                            # waits for a checkin (cannot
                                            # happen while threads share
                                            # the same bound, but cheap)
        try:
            w = self._spawn()
        except BaseException:
            with self._pcond:
                self._total -= 1
                self._pcond.notify()
            raise
        with self._pcond:
            self._all.add(w)
        return w

    def _checkin(self, w: _ProcWorker):
        with self._pcond:
            self._free.append(w)
            self._pcond.notify()

    def _discard(self, w: _ProcWorker):
        """Drop a dead (or poisoned) worker; the pool respawns lazily on
        the next checkout."""
        with self._pcond:
            self._all.discard(w)
            self._total -= 1
            self._pcond.notify()
        self._close(w)
        self._shm_reap(w.proc.pid)

    @staticmethod
    def _shm_reap(pid: Optional[int]):
        """Unlink any result segments a dead worker left behind: a child
        SIGKILLed between creating ``{prefix}r{pid}_{seq}`` and the
        parent's copy-out is the only leak window, and the deterministic
        name closes it."""
        if pid is None or not os.path.isdir("/dev/shm"):
            return
        for path in glob.glob(f"/dev/shm/{_SHM_PREFIX}r{pid}_*"):
            try:
                # attach + unlink (not a bare os.unlink) so the shared
                # resource tracker's registration is retired with the
                # segment — no "leaked shared_memory" noise at exit
                _shm_release([_shm_attach(os.path.basename(path))])
            except OSError:
                pass

    def _spawn(self) -> _ProcWorker:
        parent, child = self._mp.Pipe(duplex=True)
        p = self._mp.Process(target=_proc_worker_main, args=(child,),
                             daemon=True)
        with warnings.catch_warnings():
            # jax warns on os.fork() in its multithreaded parent; the
            # child only pumps the pipe and runs user bodies — it never
            # calls into the parent's XLA runtime (array leaves are
            # host-transferred by the serializer before crossing)
            warnings.simplefilter("ignore", RuntimeWarning)
            p.start()
        child.close()
        return _ProcWorker(p, parent)

    @staticmethod
    def _close(w: _ProcWorker):
        try:
            w.conn.close()
        except OSError:
            pass
        if w.proc.is_alive():
            w.proc.terminate()
        w.proc.join(timeout=1.0)

    @property
    def n_procs(self) -> int:
        with self._pcond:
            return self._total

    def worker_pids(self, busy_only: bool = False) -> list:
        """Pids of live worker processes — the chaos harness's worker-kill
        and task-hang schedules pick their victims here (its presence is
        also how the FaultInjector recognizes a proc-transport pilot).
        ``busy_only`` restricts to workers currently driving a task."""
        with self._pcond:
            live = [w for w in self._all if w.proc.is_alive()]
            if busy_only:
                idle = {id(w) for w in self._free}
                live = [w for w in live if id(w) not in idle]
            return [w.proc.pid for w in live]

    def shutdown(self):
        super().shutdown()              # poison the local threads first
        with self._pcond:
            workers = list(self._all)
            self._all.clear()
            self._free.clear()
            self._total = 0
        for w in workers:
            try:
                with w.send_lock:
                    w.conn.send(("stop",))
            except (OSError, ValueError):
                pass
        for w in workers:
            w.proc.join(timeout=1.0)
            self._close(w)
            self._shm_reap(w.proc.pid)
        self._seg_cache.close()


# ----------------------------- child side -------------------------------- #
class _RemoteCheckpoint:
    """Child-side Checkpoint proxy: same interface the body sees inproc
    (restore/save/preempt_requested), backed by the pipe.  ``save``
    blocks for the parent's ack so persist-then-raise survives the
    boundary."""

    def __init__(self, conn, key: str, seq: int, snapshot):
        self.key = key
        self._conn = conn
        self._seq = seq
        self._snapshot = snapshot       # (step, state) shipped with "run"
        self._preempt = False

    def restore(self):
        return self._snapshot

    def save(self, step: int, state):
        blob, _ = serializer.pack_result(state)     # None = cannot cross;
        self._conn.send(("save", self._seq, step, blob))  # parent skips
        while True:                                       # the persist
            msg = self._conn.recv()
            if msg[0] == "save_ack" and msg[1] == self._seq:
                if msg[2] or self._preempt:
                    self._preempt = True
                    raise TaskPreempted(self.key, step)
                return
            if msg[0] == "preempt":
                if msg[1] == self._seq:
                    self._preempt = True
                continue                # stale seq: a prior run's flag

    def preempt_requested(self) -> bool:
        while self._conn.poll(0):       # drain any pending preempt flag;
            msg = self._conn.recv()     # no ack is outstanding here, so
            if msg[0] == "preempt" and msg[1] == self._seq:
                self._preempt = True    # only "preempt" can be queued
        return self._preempt


def _proc_worker_main(conn):
    """Worker-process entry: one run at a time, reused across tasks."""
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError, KeyboardInterrupt):
            return
        if msg[0] == "stop":
            conn.close()
            return
        if msg[0] != "run":
            continue                    # stale preempt from a finished run
        _, seq, payload, checkpointable, key, snapshot, shm_thresh = msg
        attached = []
        try:
            fn, args, kwargs = serializer.loads(payload)
            args, kwargs = _shm_rehydrate(args, kwargs, attached)
            if checkpointable:
                snap = None
                if snapshot is not None:
                    snap = (snapshot[0], serializer.loads(snapshot[1]))
                kwargs["ckpt"] = _RemoteCheckpoint(conn, key, seq, snap)
            result = fn(*args, **kwargs)
            if not _shm_ship_result(conn, seq, result, shm_thresh):
                blob, degraded = serializer.pack_result(result)
                if blob is None:
                    conn.send(("done_raw", seq, degraded))
                else:
                    conn.send(("done", seq, blob))
        except TaskPreempted as e:
            conn.send(("preempted", seq, e.step))
        except KeyboardInterrupt:
            return
        except BaseException as e:      # noqa: BLE001 — ship it back whole
            try:
                conn.send(("error", seq, serializer.pack_exception(e)))
            except (OSError, ValueError):
                return                  # parent is gone
        finally:
            for seg in attached:        # close our mapping of the
                try:                    # parent's argument segments —
                    seg.close()         # the parent unlinks them
                except OSError:
                    pass


def _shm_rehydrate(args, kwargs, attached):
    """Child-side inverse of ``_shm_substitute``: attach each _ShmLeaf's
    segment and hand the body a *read-only* zero-copy view (the buffer is
    owned by the parent; a body that wants to mutate copies first)."""
    def hydrate(v):
        if isinstance(v, _ShmLeaf):
            seg = _shm_attach_child(v.name)
            attached.append(seg)
            arr = _np.ndarray(v.shape, dtype=v.dtype, buffer=seg.buf)
            if arr.flags.writeable:     # PROT_READ maps arrive read-only
                arr.flags.writeable = False
            return arr
        return v

    return (tuple(hydrate(v) for v in args),
            {k: hydrate(v) for k, v in kwargs.items()})


def _shm_ship_result(conn, seq, result, threshold) -> bool:
    """Ship a large ndarray result through shared memory: one memcpy into
    a segment named for (pid, seq) — so the parent can reap it if we die
    before it copies out — and a tiny metadata message.  Returns False
    when the result should take the pickle path instead."""
    if threshold is None or not _shm_eligible(result, threshold):
        return False
    try:
        leaf, seg = _shm_park_child(
            result, name=f"{_SHM_PREFIX}r{os.getpid()}_{seq}")
    except OSError:
        return False                    # /dev/shm full or absent: pickle
    try:
        conn.send(("done_shm", seq, (leaf.name, leaf.shape, leaf.dtype)))
    except BaseException:               # noqa: BLE001 — parent gone: no
        _shm_release([seg])             # one will ever unlink it but us
        raise
    seg.close()                         # ownership moved to the parent,
    return True                         # which unlinks after copy-out


# ------------------------------- factory ---------------------------------- #
TRANSPORTS = ("inproc", "proc")


def make_transport(name: Optional[str], max_workers: int = 32,
                   idle_s: float = 30.0,
                   start_method: Optional[str] = None,
                   shm_threshold: Optional[int] = None):
    """Build a transport from a PilotDescription's knobs."""
    if name in (None, "inproc"):
        return InprocTransport(max_workers, idle_s)
    if name == "proc":
        return ProcessTransport(max_workers, idle_s, start_method,
                                shm_threshold=shm_threshold)
    raise ValueError(
        f"unknown transport {name!r}; expected one of {TRANSPORTS}")
