"""Chaos-injection harness — seeded, deterministic fault schedules.

The runtime's failure domains (docs/resilience.md) are exercised by a
``FaultInjector`` driving four injection kinds against a live PilotPool:

  pilot-crash   — the victim pilot's scheduler and monitor loops die
                  silently (``Agent.inject_crash``): heartbeats go stale
                  and the pool's health monitor must declare the pilot
                  LOST and recover its tasks.
  worker-kill   — SIGKILL one live worker process of a proc-transport
                  pilot: the in-flight task fails with ``WorkerDied`` and
                  the retry classifier / poison quarantine take over.
                  No-op (logged) on inproc pilots.
  task-hang     — SIGSTOP a worker process for a duration, then SIGCONT:
                  the task genuinely hangs (no error, no EOF), so
                  straggler replicas and shutdown's stranded-task report
                  are what notice it.  No-op (logged) on inproc pilots.
  slot-failure  — ``Agent.inject_slot_failure`` on random slots: running
                  victims fail mid-flight with ``SlotFailure``.

Schedules are explicit ``at_s`` offsets from ``start()``; victim choice
(when not pinned) and slot choice come from a seeded ``random.Random``,
so a chaos storm replays identically for a given seed.  One timer thread
walks the sorted schedule with event waits — nothing here polls, and
nothing here touches the task path of healthy pilots.

The two error types the injector (and the lost-pilot recovery) surface —
``PilotLost`` and ``SlotFailure`` — live here so the agent's retry
classifier can treat them as *infrastructure* failures (prefer a
different pilot) without import cycles.
"""
from __future__ import annotations

import os
import random
import signal
import threading
import time
from typing import Callable, List, Optional


class PilotLost(RuntimeError):
    """The pilot a task was queued/running on was declared LOST by the
    pool's health supervision (missed heartbeats or injected crash)."""


class SlotFailure(RuntimeError):
    """A slot the task was running on failed (injected node-failure
    analog); classified as an infrastructure error by the retry path."""


class FaultInjector:
    """Deterministic chaos schedule against a PilotPool.

    >>> fi = FaultInjector(pool, seed=7)
    >>> fi.add_pilot_crash(at_s=0.5)             # random victim
    >>> fi.add_worker_kill(at_s=0.2, pilot=p1)   # pinned victim
    >>> fi.add_slot_failure(at_s=0.8, n_slots=2)
    >>> fi.start(); ...workload...; fi.stop()

    ``events`` records every injection actually performed (kind, time,
    victim) — benchmarks and tests assert against it."""

    def __init__(self, pool, seed: int = 0):
        self.pool = pool
        self.rng = random.Random(seed)
        self.events: List[dict] = []
        self._schedule: List[tuple] = []   # (at_s, seq, fn, label)
        self._seq = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._t0: Optional[float] = None

    # ------------------------------ schedule --------------------------- #
    def _add(self, at_s: float, fn: Callable, label: str):
        self._schedule.append((at_s, self._seq, fn, label))
        self._seq += 1
        return self

    def add_pilot_crash(self, at_s: float, pilot=None):
        return self._add(at_s, lambda: self._pilot_crash(pilot),
                         "pilot-crash")

    def add_worker_kill(self, at_s: float, pilot=None):
        return self._add(at_s, lambda: self._worker_kill(pilot),
                         "worker-kill")

    def add_task_hang(self, at_s: float, duration_s: float = 0.5,
                      pilot=None):
        return self._add(at_s, lambda: self._task_hang(pilot, duration_s),
                         "task-hang")

    def add_slot_failure(self, at_s: float, pilot=None, n_slots: int = 1):
        return self._add(at_s, lambda: self._slot_failure(pilot, n_slots),
                         "slot-failure")

    def storm(self, duration_s: float, pilot_crashes: int = 1,
              worker_kills: int = 0, slot_failures: int = 0,
              task_hangs: int = 0, warmup_s: float = 0.1):
        """Spread a mixed fault load over ``duration_s`` (times drawn
        from the seeded rng, so the storm is reproducible)."""
        def times(n):
            return sorted(warmup_s + self.rng.random()
                          * max(0.0, duration_s - warmup_s)
                          for _ in range(n))
        for t in times(pilot_crashes):
            self.add_pilot_crash(t)
        for t in times(worker_kills):
            self.add_worker_kill(t)
        for t in times(slot_failures):
            self.add_slot_failure(t)
        for t in times(task_hangs):
            self.add_task_hang(t)
        return self

    # ------------------------------- driver ----------------------------- #
    def start(self) -> "FaultInjector":
        self._t0 = time.monotonic()
        self._schedule.sort(key=lambda e: (e[0], e[1]))
        self._thread = threading.Thread(target=self._drive, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)

    def _drive(self):
        for at_s, _, fn, label in self._schedule:
            delay = self._t0 + at_s - time.monotonic()
            if delay > 0 and self._stop.wait(delay):
                return
            if self._stop.is_set():
                return
            try:
                fn()
            except Exception as e:   # noqa: BLE001 — chaos must not crash
                self._log(label, error=repr(e))   # the injector itself

    def _log(self, kind: str, **fields):
        self.events.append({"kind": kind,
                            "t": time.monotonic() - (self._t0 or 0.0),
                            **fields})

    # ----------------------------- injections --------------------------- #
    def _pick_pilot(self, pilot, need_proc: bool = False):
        if pilot is not None:
            return pilot
        cands = [p for p in self.pool.active()
                 if not p.draining and not p.agent.crashed]
        if need_proc:
            cands = [p for p in cands
                     if hasattr(p.agent.transport, "worker_pids")]
        return self.rng.choice(cands) if cands else None

    def _pilot_crash(self, pilot):
        p = self._pick_pilot(pilot)
        if p is None:
            self._log("pilot-crash", skipped="no eligible pilot")
            return
        p.agent.inject_crash()
        self._log("pilot-crash", pilot=p.uid)

    def _worker_pid(self, p) -> Optional[int]:
        pids = getattr(p.agent.transport, "worker_pids", lambda: [])()
        return self.rng.choice(sorted(pids)) if pids else None

    def _worker_kill(self, pilot):
        p = self._pick_pilot(pilot, need_proc=True)
        pid = self._worker_pid(p) if p is not None else None
        if pid is None:
            self._log("worker-kill", skipped="no live proc worker")
            return
        try:
            os.kill(pid, signal.SIGKILL)
            self._log("worker-kill", pilot=p.uid, pid=pid)
        except ProcessLookupError:
            self._log("worker-kill", skipped=f"pid {pid} already gone")

    def _task_hang(self, pilot, duration_s: float):
        p = self._pick_pilot(pilot, need_proc=True)
        pid = self._worker_pid(p) if p is not None else None
        if pid is None:
            self._log("task-hang", skipped="no live proc worker")
            return
        try:
            os.kill(pid, signal.SIGSTOP)
        except ProcessLookupError:
            self._log("task-hang", skipped=f"pid {pid} already gone")
            return
        self._log("task-hang", pilot=p.uid, pid=pid, duration_s=duration_s)

        def resume():
            if not self._stop.wait(duration_s):
                pass
            try:
                os.kill(pid, signal.SIGCONT)
            except ProcessLookupError:
                pass
        threading.Thread(target=resume, daemon=True).start()

    def _slot_failure(self, pilot, n_slots: int):
        p = self._pick_pilot(pilot)
        if p is None:
            self._log("slot-failure", skipped="no eligible pilot")
            return
        cap = p.scheduler.capacity
        slots = self.rng.sample(range(cap), min(n_slots, cap))
        victims = p.agent.inject_slot_failure(slots)
        self._log("slot-failure", pilot=p.uid, slots=slots,
                  victims=list(victims))
