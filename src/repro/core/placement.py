"""Placement — the pluggable policy layer for *where* tasks land.

The paper's §IV integration hinges on placement: the translator attaches
resource requirements and RP late-binds tasks to pilots.  Through PR 3
that policy was smeared across four layers — the translator stamped
``res_kind``/``sticky``, ``PilotPool.route``/``route_bulk`` hardcoded
least-loaded, ``request_work`` hardcoded most-loaded-victim stealing, and
the ``PoolScaler`` could only clone one template.  This module extracts
all four decisions behind one protocol so policy and mechanism separate
(the split arXiv:2509.20819 motivates for hybrid AI-HPC workloads):

  ``place(task, pilots)``       — pick the pilot for one task
  ``place_bulk(...)``           — greedy batch placement with running loads
  ``pick_victim(thief, ...)``   — order steal victims for a hungry pilot
  ``steal_eligible(task, ...)`` — per-task migration gate inside a steal
  ``pick_preempt(thief, ...)``  — choose the RUNNING checkpointable task
                                  to preempt-and-migrate when the
                                  queued-only steal pass found nothing
  ``pick_template(...)``        — choose the scale-up template for the
                                  kinds that are actually starving

Three built-in policies:

  * ``LeastLoaded`` — PR-2 behavior, byte-for-byte: min demanded-slots /
    capacity, first-of-equals, most-loaded victim, steal anything
    compatible, clone the (single) template.  The default.
  * ``LocalityAware`` — data-affinity placement: every task may carry an
    ``affinity`` tuple of pilot uids/names (stamped by the translator
    from the pilots that produced its inputs, plus any ``ResourceSpec``
    hints).  Placement scores ``load - locality_weight * match`` so a
    consumer follows its producers' data unless the load gap exceeds the
    locality weight; stealing only migrates an affine task when the
    victim's backlog (imbalance) beats the affinity penalty — the soft
    sibling of the hard ``sticky`` stamp, which still pins absolutely.
  * ``CostModelPolicy`` — schedules on *predicted seconds*, not counted
    slots: wraps either of the above and re-prices placement, stealing,
    preemption, and victim ordering with the StateStore's per-(app_kind,
    pilot) duration model.  See the class docstring and
    docs/scheduling.md.

Tie-breaking composes: any policy takes a sequence of ``tie_break``
callables ``(task, pilot) -> float`` (lower preferred) applied in order
after the primary score — e.g. ``prefer_specialized`` keeps kind-
restricted pilots busy so ``kinds=None`` generalists stay free, and
``prefer_free_slots`` spreads onto warm capacity.  With no tie-breakers
the enumeration order rules, matching the historical ``min()`` behavior.
"""
from __future__ import annotations

import time
from typing import (Callable, Dict, List, Optional, Sequence, Tuple, Union,
                    TYPE_CHECKING)

if TYPE_CHECKING:                       # import cycle: pilot.py imports us
    from .futures import TaskRecord
    from .pilot import Pilot, PilotDescription

TieBreak = Callable[["TaskRecord", "Pilot"], float]

# starving-queue demand: one entry per queued task — (the identifiers the
# task routes under, its slot demand).  See Agent.queued_task_kinds().
KindDemand = Sequence[Tuple[Tuple[str, ...], int]]


def filter_healthy(pilots: Sequence["Pilot"],
                   heartbeat_timeout_s: Optional[float] = None
                   ) -> List["Pilot"]:
    """Health-aware candidate filtering: drop pilots that are visibly
    dead or dying *before* any policy scores them — a crashed agent, or
    (when heartbeat supervision is active) one whose liveness beat has
    aged past the timeout but has not yet been declared LOST.  Routing
    to such a pilot only strands the task until the health monitor's
    recovery sweep re-routes it anyway.  Callers fall back to the
    unfiltered list when nothing healthy remains (the monitor will sort
    the rest out)."""
    now = time.monotonic()
    out = []
    for p in pilots:
        agent = p.agent
        if getattr(agent, "crashed", False):
            continue
        if (heartbeat_timeout_s is not None
                and now - agent.last_beat > heartbeat_timeout_s):
            continue
        out.append(p)
    return out


# ------------------------- composable tie-breakers ------------------------ #

def prefer_specialized(task, pilot) -> float:
    """Prefer pilots whose description restricts kinds (and the narrower
    the restriction the better) — keeps ``kinds=None`` generalists free
    for tasks nothing else accepts."""
    kinds = pilot.desc.kinds
    return float(len(kinds)) if kinds is not None else float("inf")


def prefer_free_slots(task, pilot) -> float:
    """Prefer the pilot with more immediately-free slots."""
    return -float(pilot.scheduler.n_free)


class PlacementPolicy:
    """The placement protocol *and* its default implementation: least
    loaded (demanded slots / capacity), first-of-equals — exactly the
    routing PR 2 hardcoded in ``PilotPool``.  Subclass and override
    ``score`` / ``steal_eligible`` / ``pick_victim`` / ``pick_template``
    to change policy without touching mechanism."""

    name = "least-loaded"

    def __init__(self, tie_breaks: Sequence[TieBreak] = ()):
        self.tie_breaks = tuple(tie_breaks)

    # ------------------------------ scoring --------------------------- #
    def score(self, task: "TaskRecord", pilot: "Pilot",
              load: float) -> float:
        """Primary placement score; lower wins.  ``load`` is the demand
        estimate (running batch estimate during ``place_bulk``)."""
        return load

    def _key(self, task, pilot, load) -> tuple:
        return (self.score(task, pilot, load),
                *(tb(task, pilot) for tb in self.tie_breaks))

    # ------------------------------ placing --------------------------- #
    def place(self, task: "TaskRecord", pilots: Sequence["Pilot"],
              loads: Optional[Dict[str, float]] = None) -> "Pilot":
        """Pick one pilot among the (compatible, non-empty) candidates.
        ``loads`` optionally overrides live loads with a running batch
        estimate; first-of-equals on full ties keeps routing stable."""
        best = None
        best_key = None
        for p in pilots:
            load = loads[p.uid] if loads is not None else p.load()
            key = self._key(task, p, load)
            if best is None or key < best_key:
                best, best_key = p, key
        return best

    def place_bulk(self, items: Sequence[Tuple["TaskRecord", object]],
                   loads: Dict[str, float], caps: Dict[str, int]
                   ) -> List[Union["Pilot", Exception]]:
        """Greedy batch placement: each item is (task, candidates) where
        candidates is a pilot list or the routing Exception to pass
        through; ``loads`` accumulates the demand placed earlier in this
        batch so a bulk submission spreads instead of piling onto
        whichever pilot was idle when the batch arrived."""
        out: List[Union["Pilot", Exception]] = []
        for task, cands in items:
            if isinstance(cands, Exception):
                out.append(cands)
                continue
            p = self.place(task, cands, loads=loads)
            loads[p.uid] += task.resources.slots / caps[p.uid]
            out.append(p)
        return out

    # ------------------------------ stealing -------------------------- #
    def pick_victim(self, thief: "Pilot", pilots: Sequence["Pilot"],
                    demand: Dict[str, int]) -> List["Pilot"]:
        """Order candidate victims for a hungry thief, most attractive
        first; default: most queued backlog first (PR-2 behavior)."""
        return sorted(pilots, key=lambda p: demand.get(p.uid, 0),
                      reverse=True)

    def steal_eligible(self, task: "TaskRecord", thief: "Pilot",
                       victim: "Pilot", imbalance: float) -> bool:
        """Per-task migration gate evaluated inside the victim's steal
        sweep (compatibility, capacity fit, and the hard ``sticky`` pin
        are checked by the mechanism).  ``imbalance`` is the victim's
        queued backlog in load units (queued slots / capacity).  Default:
        any compatible task moves."""
        return True

    # ----------------------------- preemption -------------------------- #
    def pick_preempt(self, thief: "Pilot",
                     candidates: Sequence[Tuple["TaskRecord", "Pilot"]],
                     loads: Dict[str, float]
                     ) -> Optional[Tuple["TaskRecord", "Pilot"]]:
        """Choose one RUNNING task to preempt-and-migrate onto ``thief``
        after a queued-only steal pass found nothing.  ``candidates`` are
        (task, victim) pairs pre-gated by the mechanism (checkpointable,
        non-sticky, non-replica, kind-compatible, capacity fit — the
        Agent enforces the hard pins); ``loads`` maps each victim pilot
        uid to its queued backlog per slot of capacity — the same
        imbalance currency ``steal_eligible`` receives.  Default: take
        from the most-loaded victim, longest-running task first (it has
        the most checkpointed progress to carry over).  Return None to
        decline preemption entirely."""
        best, best_key = None, None
        for t, victim in candidates:
            key = (-loads.get(victim.uid, 0.0),
                   t.timestamps.get("RUNNING", float("inf")))
            if best is None or key < best_key:
                best, best_key = (t, victim), key
        return best

    # ------------------------------ scaling --------------------------- #
    def pick_template(self, starving_kinds: KindDemand,
                      templates: Sequence["PilotDescription"]
                      ) -> "PilotDescription":
        """Choose which template the PoolScaler spawns: the one whose
        ``kinds`` cover the most starving slot-demand, preferring the
        most specialized on ties (then listing order).  With one template
        — or an empty starving queue — this is the PR-2 clone."""
        templates = list(templates)
        if len(templates) == 1 or not starving_kinds:
            return templates[0]

        def covered(desc) -> int:
            if desc.kinds is None:
                return sum(slots for _, slots in starving_kinds)
            return sum(slots for kinds, slots in starving_kinds
                       if any(k in desc.kinds for k in kinds))

        best, best_key = templates[0], None
        for i, d in enumerate(templates):
            nk = len(d.kinds) if d.kinds is not None else float("inf")
            key = (-covered(d), nk, i)
            if best_key is None or key < best_key:
                best, best_key = d, key
        return best

    def __repr__(self):
        return f"<{type(self).__name__} {self.name!r}>"


class LeastLoaded(PlacementPolicy):
    """PR-2 routing, named: an explicit alias so configuration reads
    ``placement=LeastLoaded()`` (or ``placement=\"least-loaded\"``)."""


def affinity_match(task: "TaskRecord", pilot: "Pilot") -> float:
    """Fraction of the task's affinity this pilot satisfies (by pilot uid
    or description name); 0.0 for tasks with no affinity.

    With a ``TaskRecord.affinity_bytes`` stamp (the DFK dep manager's
    {producer pilot: input bytes} map, docs/dataplane.md) the fraction is
    *byte-weighted*: resident input bytes / total input bytes, so a
    consumer follows its largest input rather than counting producers
    equally — one 64 MB array outweighs any number of kilobyte configs.
    Without the stamp, the legacy uid-counted fraction applies."""
    name = pilot.desc.name
    ab = getattr(task, "affinity_bytes", None)
    if ab:
        total = sum(ab.values())
        if total > 0:
            matched = sum(v for k, v in ab.items()
                          if k == pilot.uid or (name and k == name))
            return matched / total
    aff = getattr(task, "affinity", ()) or ()
    if not aff:
        return 0.0
    hits = sum(1 for a in aff if a == pilot.uid or (name and a == name))
    return hits / len(aff)


def remote_bytes(task: "TaskRecord", pilot: "Pilot") -> int:
    """Input bytes NOT resident on ``pilot`` — what a placement there
    would move across pilots (0 for tasks without a byte stamp)."""
    ab = getattr(task, "affinity_bytes", None) or {}
    name = pilot.desc.name
    return sum(v for k, v in ab.items()
               if k != pilot.uid and not (name and k == name))


class LocalityAware(PlacementPolicy):
    """Data-affinity placement: score ``load - locality_weight * match``.

    ``locality_weight`` is denominated in load units (demanded slots per
    slot of capacity): a fully-affine pilot wins until its load exceeds a
    non-affine alternative's by the weight — ``0.0`` degenerates to
    ``LeastLoaded``, a large weight pins consumers to their producers'
    pilots no matter the queue.  Stealing applies the same currency: an
    affine task migrates only when the victim's backlog-per-slot beats
    the affinity penalty the move would pay, so a hungry sibling still
    absorbs a genuinely starving queue but never shuffles data-local
    work for marginal balance."""

    name = "locality"

    def __init__(self, locality_weight: float = 0.5,
                 tie_breaks: Sequence[TieBreak] = ()):
        super().__init__(tie_breaks=tie_breaks)
        if locality_weight < 0:
            raise ValueError(
                f"locality_weight must be >= 0, got {locality_weight}")
        self.locality_weight = locality_weight

    def score(self, task, pilot, load):
        return load - self.locality_weight * affinity_match(task, pilot)

    def steal_eligible(self, task, thief, victim, imbalance):
        penalty = self.locality_weight * (affinity_match(task, victim)
                                          - affinity_match(task, thief))
        return penalty <= 0 or imbalance > penalty

    def pick_preempt(self, thief, candidates, loads):
        """Affinity gates preemption in the same currency as stealing: a
        RUNNING task affine to its victim pilot only migrates when the
        victim's queued backlog per slot beats the affinity penalty of
        the move."""
        eligible = [(t, v) for t, v in candidates
                    if self.steal_eligible(t, thief, v,
                                           loads.get(v.uid, 0.0))]
        return super().pick_preempt(thief, eligible, loads)


class CostModelPolicy(PlacementPolicy):
    """Cost-model scheduling: every decision is priced in *predicted
    seconds* from the StateStore duration model instead of counted slots
    (see docs/scheduling.md).

    Wraps an inner policy (``LeastLoaded`` by default, or
    ``LocalityAware`` to keep data-affinity) and re-expresses its
    decisions in time:

      * ``place``/``place_bulk`` rank pilots by predicted completion:
        backlog seconds (per-kind queued+running slots x that kind's EWMA
        mean run time / capacity) plus the task's own predicted run time
        on that pilot, minus the affinity bonus converted to seconds.
      * ``steal_eligible`` compares the predicted wait a migration saves
        (victim backlog-per-slot x the victim's mixture mean) against the
        affinity penalty in seconds.
      * ``pick_preempt`` ranks victims by predicted *remaining* work
        (kind mean - observed run time), so the task that is nearly done
        stops being the default preemption victim; the checkpoint trail
        breaks ties (fewer saved steps = less banked progress).
      * ``pick_victim`` orders steal victims by queued backlog seconds,
        not queued slot counts.
      * ``place`` additionally prices *data staging*: a candidate pilot
        not holding the task's inputs pays ``remote_bytes(task, pilot) /
        bandwidth_bytes_s`` seconds (the DFK's byte-weighted affinity
        stamps supply the byte map; see docs/dataplane.md).

    Predictions fall back per (pilot, kind): the pilot's own kind EWMA ->
    the candidate fleet's kind aggregate -> the pilot's all-kind mixture
    -> the fleet mixture -> ``default_duration_s``.  With a completely
    cold model every candidate prices at the constant default, and the
    ranking degenerates exactly to the inner policy's count-based order —
    cold starts schedule like PR-2, warm models schedule on time."""

    name = "cost"

    def __init__(self, inner: Union[None, str, PlacementPolicy] = None,
                 default_duration_s: float = 1.0,
                 bandwidth_bytes_s: Optional[float] = 1e9,
                 tie_breaks: Sequence[TieBreak] = ()):
        super().__init__(tie_breaks=tie_breaks)
        self.inner = resolve_policy(inner)
        if isinstance(self.inner, CostModelPolicy):
            raise ValueError("CostModelPolicy cannot wrap itself")
        if default_duration_s <= 0:
            raise ValueError("default_duration_s must be > 0, "
                             f"got {default_duration_s}")
        self.default_duration_s = default_duration_s
        # transfer pricing (docs/dataplane.md): placing a task away from
        # its inputs costs remote_bytes / bandwidth seconds on top of the
        # compute eta — the data plane's byte stamps make staging cost a
        # first-class term.  None disables the term.
        if bandwidth_bytes_s is not None and bandwidth_bytes_s <= 0:
            raise ValueError("bandwidth_bytes_s must be > 0 or None, "
                             f"got {bandwidth_bytes_s}")
        self.bandwidth_bytes_s = bandwidth_bytes_s

    # --------------------------- predictions --------------------------- #
    def _fleet_model(self, pilots) -> Tuple[Dict[str, float],
                                            Optional[float]]:
        """({kind: n-weighted mean across the candidate pilots}, fleet
        mixture mean or None) — the cross-pilot fallback for kinds an
        individual pilot has not run yet."""
        agg: Dict[str, List[float]] = {}
        for p in pilots:
            for kind, (mean, _var, n) in p.store.duration_model().items():
                m = agg.setdefault(kind, [0.0, 0])
                tot = m[1] + n
                m[0] = (m[0] * m[1] + mean * n) / tot
                m[1] = tot
        n_all = sum(m[1] for m in agg.values())
        overall = (sum(m[0] * m[1] for m in agg.values()) / n_all
                   if n_all else None)
        return {k: m[0] for k, m in agg.items()}, overall

    def _run_mean(self, pilot, kind: Optional[str], fleet) -> float:
        """Predicted run time (seconds) of one ``kind`` task on ``pilot``,
        falling back pilot-kind -> fleet-kind -> pilot mixture -> fleet
        mixture -> the constant default (cold start)."""
        if kind is not None:
            st = pilot.store.duration_stats(kind)
            if st is not None:
                return st[0]
            if kind in fleet[0]:
                return fleet[0][kind]
        st = pilot.store.duration_stats(None)
        if st is not None:
            return st[0]
        if fleet[1] is not None:
            return fleet[1]
        return self.default_duration_s

    def _backlog_seconds(self, pilot, fleet) -> float:
        """Predicted seconds of queue wait a new arrival sees: each
        outstanding kind's slots priced at its predicted duration, spread
        over the pilot's capacity."""
        cap = max(1, pilot.scheduler.capacity)
        return sum(slots * self._run_mean(pilot, k, fleet)
                   for k, slots in pilot.agent.demand_by_kind().items()
                   ) / cap

    def _mixture_mean(self, pilot, fleet) -> float:
        """Demand-weighted mean duration of the pilot's current backlog —
        the seconds one load unit (slot per slot of capacity) stands for
        when converting count-based currencies."""
        by_kind = pilot.agent.demand_by_kind()
        tot = sum(by_kind.values())
        if tot:
            return sum(s * self._run_mean(pilot, k, fleet)
                       for k, s in by_kind.items()) / tot
        return self._run_mean(pilot, None, fleet)

    # ------------------------------ placing ----------------------------- #
    def place(self, task, pilots, loads=None, extra_s=None):
        from .futures import model_kind
        pilots = list(pilots)
        fleet = self._fleet_model(pilots)
        kind = model_kind(task)
        locality = (self.inner.locality_weight
                    if isinstance(self.inner, LocalityAware) else 0.0)
        best, best_key = None, None
        for p in pilots:
            run = self._run_mean(p, kind, fleet)
            eta = self._backlog_seconds(p, fleet) + run
            if extra_s is not None:
                eta += extra_s.get(p.uid, 0.0)
            elif loads is not None:
                # a generic caller's batch estimate arrives in load
                # units; price the delta over live load at the pilot's
                # mixture rate
                delta = loads[p.uid] - p.load()
                if delta > 0:
                    eta += delta * self._mixture_mean(p, fleet)
            if locality:
                # affinity bonus in seconds: the inner weight is load
                # units, one unit of this task is worth its run time
                eta -= locality * run * affinity_match(task, p)
            if self.bandwidth_bytes_s is not None:
                # staging cost: non-resident input bytes at the modeled
                # inter-pilot bandwidth
                eta += remote_bytes(task, p) / self.bandwidth_bytes_s
            key = (eta, *(tb(task, p) for tb in self.tie_breaks))
            if best is None or key < best_key:
                best, best_key = p, key
        return best

    def place_bulk(self, items, loads, caps):
        from .futures import model_kind
        out: List[Union["Pilot", Exception]] = []
        extra_s: Dict[str, float] = {}      # seconds this batch already
        for task, cands in items:           # queued onto each pilot
            if isinstance(cands, Exception):
                out.append(cands)
                continue
            p = self.place(task, cands, extra_s=extra_s)
            loads[p.uid] += task.resources.slots / caps[p.uid]
            fleet = self._fleet_model([p])
            extra_s[p.uid] = (extra_s.get(p.uid, 0.0)
                              + task.resources.slots
                              * self._run_mean(p, model_kind(task), fleet)
                              / caps[p.uid])
            out.append(p)
        return out

    # ------------------------------ stealing ---------------------------- #
    def pick_victim(self, thief, pilots, demand):
        """Most predicted-seconds of queued backlog first — a victim with
        few but long queued tasks outranks one with many short ones."""
        pilots = list(pilots)
        fleet = self._fleet_model(pilots + [thief])
        return sorted(
            pilots,
            key=lambda p: demand.get(p.uid, 0) * self._mixture_mean(p,
                                                                    fleet),
            reverse=True)

    def steal_eligible(self, task, thief, victim, imbalance):
        """Predicted wait saved vs the affinity penalty, both in seconds.
        ``imbalance`` (victim queued slots per slot of capacity) x the
        victim's mixture mean is the wait the move saves; a LocalityAware
        inner's penalty is its weight x the task's predicted run time x
        the affinity lost by moving."""
        from .futures import model_kind
        fleet = self._fleet_model([thief, victim])
        if not isinstance(self.inner, LocalityAware):
            return self.inner.steal_eligible(task, thief, victim,
                                             imbalance)
        penalty_s = (self.inner.locality_weight
                     * self._run_mean(victim, model_kind(task), fleet)
                     * (affinity_match(task, victim)
                        - affinity_match(task, thief)))
        saved_s = imbalance * self._mixture_mean(victim, fleet)
        return penalty_s <= 0 or saved_s > penalty_s

    # ----------------------------- preemption --------------------------- #
    def pick_preempt(self, thief, candidates, loads):
        """Rank victims by predicted *remaining* work, descending: the
        kind's EWMA mean minus the observed run time so far.  The default
        policy's longest-running-first rule preempts exactly the task
        that is about to finish — maximum migration overhead per second
        of remaining work; pricing the remainder inverts that.  Ties
        break on the checkpoint trail (fewer saved steps = less banked
        progress = preempt first), then the victim's queued backlog.  A
        LocalityAware inner's affinity gate applies first, in seconds."""
        import time as _time
        from .futures import model_kind
        if isinstance(self.inner, LocalityAware):
            candidates = [(t, v) for t, v in candidates
                          if self.steal_eligible(t, thief, v,
                                                 loads.get(v.uid, 0.0))]
        candidates = list(candidates)
        fleet = self._fleet_model([v for _, v in candidates] + [thief])
        now = _time.monotonic()
        best, best_key = None, None
        for t, v in candidates:
            elapsed = max(0.0, now - t.timestamps.get("RUNNING", now))
            remaining = self._run_mean(v, model_kind(t), fleet) - elapsed
            step = v.ckpt.step(t.ckpt_key or t.uid)
            key = (-remaining, step if step is not None else -1,
                   -loads.get(v.uid, 0.0))
            if best is None or key < best_key:
                best, best_key = (t, v), key
        return best

    # ------------------------------ scaling ----------------------------- #
    def pick_template(self, starving_kinds, templates):
        return self.inner.pick_template(starving_kinds, templates)


_POLICIES = {
    "least-loaded": LeastLoaded,
    "least_loaded": LeastLoaded,
    "leastloaded": LeastLoaded,
    "locality": LocalityAware,
    "locality-aware": LocalityAware,
    "locality_aware": LocalityAware,
    "cost": CostModelPolicy,
    "cost-model": CostModelPolicy,
    "cost_model": CostModelPolicy,
    "costmodel": CostModelPolicy,
}


def resolve_policy(policy: Union[None, str, PlacementPolicy]
                   ) -> PlacementPolicy:
    """None -> LeastLoaded(); a name -> its policy with defaults; an
    instance passes through (the RPEXExecutor/PilotPool kwarg surface)."""
    if policy is None:
        return LeastLoaded()
    if isinstance(policy, PlacementPolicy):
        return policy
    cls = _POLICIES.get(str(policy).lower())
    if cls is None:
        raise ValueError(
            f"unknown placement policy {policy!r}; "
            f"known: {sorted(set(_POLICIES))} or a PlacementPolicy instance")
    return cls()
