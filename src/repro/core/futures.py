"""Task state machine + AppFuture (the Parsl-side future abstraction).

State model follows the paper's two systems:

  Parsl/DFK states:   pending -> launched -> running -> done | failed
  RP task states:     NEW -> TRANSLATED -> SCHEDULED -> LAUNCHING ->
                      RUNNING -> DONE | FAILED | CANCELED

The RP states map 1:1 onto the resource-utilization categories of the
paper's Fig. 6 (Scheduled / Launching / Running / Idle): every transition is
timestamped in the TaskRecord so benchmarks/exp2 can integrate per-slot
timelines exactly the way the paper does.
"""
from __future__ import annotations

import itertools
import random
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, List, Optional, Tuple

from .objectstore import ObjectRef


class TaskState(str, Enum):
    NEW = "NEW"
    TRANSLATED = "TRANSLATED"
    SCHEDULED = "SCHEDULED"
    LAUNCHING = "LAUNCHING"
    RUNNING = "RUNNING"
    DONE = "DONE"
    FAILED = "FAILED"
    CANCELED = "CANCELED"


TERMINAL = {TaskState.DONE, TaskState.FAILED, TaskState.CANCELED}

# The declared task-lifecycle state machine: every legal ``transition()``
# edge.  The static checker (repro.analysis.events) verifies each
# ``transition(TaskState.X)`` site targets a reachable state; the runtime
# watchdog (REPRO_LOCK_WATCHDOG=1) validates actual from->to pairs against
# it.  Non-obvious edges, all real:
#   NEW -> NEW              translator stamps the initial state timestamp
#   NEW -> SCHEDULED        tasks submitted straight to an Agent (no
#                           translator in the loop) are NEW when placed
#   NEW -> DONE/FAILED      DFK memoization hit / upstream dep failure
#   TRANSLATED -> RUNNING   direct (non-pilot) executors skip SCHEDULED
#   RUNNING -> TRANSLATED   retry requeue before the FAILED stamp landed
#   FAILED -> TRANSLATED    retry requeue after an in-process body already
#                           stamped FAILED on the shared record
#   RUNNING -> SCHEDULED    preempt-and-migrate requeue
#   DONE -> DONE, FAILED -> FAILED   idempotent re-stamp when the agent
#                           settles a record the executor already stamped
STATE_MACHINE = {
    TaskState.NEW: (TaskState.NEW, TaskState.TRANSLATED,
                    TaskState.SCHEDULED, TaskState.RUNNING,
                    TaskState.DONE, TaskState.FAILED,
                    TaskState.CANCELED),
    TaskState.TRANSLATED: (TaskState.SCHEDULED, TaskState.RUNNING,
                           TaskState.DONE, TaskState.FAILED,
                           TaskState.CANCELED),
    TaskState.SCHEDULED: (TaskState.LAUNCHING, TaskState.SCHEDULED,
                          TaskState.TRANSLATED, TaskState.FAILED,
                          TaskState.CANCELED),
    TaskState.LAUNCHING: (TaskState.RUNNING, TaskState.FAILED,
                          TaskState.CANCELED),
    TaskState.RUNNING: (TaskState.DONE, TaskState.FAILED,
                        TaskState.CANCELED, TaskState.TRANSLATED,
                        TaskState.SCHEDULED),
    TaskState.DONE: (TaskState.DONE,),
    TaskState.FAILED: (TaskState.FAILED, TaskState.TRANSLATED),
    TaskState.CANCELED: (),
}

# Runtime transition validation hook — None (free) unless the lock-order
# watchdog is installed, which points it at its violation recorder.
_validate_transition = None

_uid = itertools.count()


def new_uid(prefix: str = "task") -> str:
    return f"{prefix}.{next(_uid):06d}"


def model_kind(task: "TaskRecord") -> str:
    """The duration-model population a task belongs to: the pre-translation
    app kind when one exists (bash apps *execute* as kind "python" but
    their run times are a bash population), else the execution kind."""
    return task.app_kind or task.kind


@dataclass(frozen=True)
class RetryPolicy:
    """Per-app retry semantics (``@python_app(retry_policy=...)``).

    ``max_retries`` additional attempts are granted after the first
    failure; each retry is requeued after an exponential backoff with
    deterministic jitter (seeded from the task uid and attempt number, so
    runs are reproducible).  The agent's retry classifier also consults:

    fatal_exceptions      — error types that short-circuit retrying: the
                            task fails terminally on the first match.
    retry_different_pilot — infrastructure failures (WorkerDied, a lost
                            pilot, an injected slot failure) send the
                            retry through the pool to a *different*
                            pilot when one is compatible; app-level
                            exceptions always retry in place.
    quarantine_after      — poison quarantine: a task whose attempts have
                            killed this many worker processes is FAILED
                            terminally (with a QUARANTINED journal event)
                            instead of respawn-storming the proc pool.
                            None disables quarantine.

    Tasks declared with the legacy ``retries=N`` (no policy) keep the old
    behavior exactly: immediate in-place requeue, no classification."""
    max_retries: int = 3
    backoff_base_s: float = 0.05    # first-retry delay; 0 = immediate
    backoff_factor: float = 2.0     # exponential growth per attempt
    backoff_max_s: float = 5.0      # delay ceiling
    jitter: float = 0.1             # +/- fraction of the delay randomized
    fatal_exceptions: Tuple[type, ...] = ()
    retry_different_pilot: bool = True
    quarantine_after: Optional[int] = 3

    def backoff_s(self, attempt: int, token: str = "") -> float:
        """Delay before retry ``attempt`` (1-based).  Jitter is seeded
        from ``(token, attempt)`` — same task, same attempt, same delay —
        so chaos runs replay deterministically."""
        if self.backoff_base_s <= 0.0:
            return 0.0
        d = min(self.backoff_max_s,
                self.backoff_base_s * self.backoff_factor ** (attempt - 1))
        if self.jitter > 0.0:
            r = random.Random(f"{token}:{attempt}")
            d *= 1.0 + self.jitter * (2.0 * r.random() - 1.0)
        return d

    def is_fatal(self, err: Optional[BaseException]) -> bool:
        return (bool(self.fatal_exceptions) and err is not None
                and isinstance(err, tuple(self.fatal_exceptions)))


def _attach_root_cause(exc: BaseException, cause: BaseException):
    """Hang ``cause`` off the *root* of ``exc``'s existing cause chain —
    pre-set causes (e.g. WorkerDied raised ``from`` a pipe EOFError) are
    preserved, not clobbered."""
    seen = {id(exc)}
    root = exc
    while root.__cause__ is not None:
        root = root.__cause__
        if id(root) in seen or root is cause:
            return
        seen.add(id(root))
    if root is not cause:
        root.__cause__ = cause


def chain_attempt_errors(task: "TaskRecord"):
    """Link the attempt-error history into the exception that will
    surface: each earlier failure becomes the ``__cause__`` of the next,
    ending at ``task.error``, so the final FAILED exception shows all N
    attempts instead of only the last."""
    prev: Optional[BaseException] = None
    for e in task.attempt_errors:
        if e is None or e is task.error or e is prev:
            continue
        if prev is not None:
            _attach_root_cause(e, prev)
        prev = e
    if prev is not None and task.error is not None:
        _attach_root_cause(task.error, prev)


@dataclass
class ResourceSpec:
    """Per-task resource requirements (the RP task-description fields Parsl
    lacks — the API extension §IV-D of the paper calls out)."""
    slots: int = 1                  # device slots (chips); MPI "ranks"
    mesh_shape: Optional[Tuple[int, int]] = None   # (data, model) sub-mesh
    cpu_only: bool = False          # pre/post-processing helper tasks
    walltime: Optional[float] = None
    priority: int = 0
    res_kind: Optional[str] = None  # resource class for pilot routing
                                    # ("cpu" | "device"); None = inferred
    sticky: bool = False            # pin to the routed pilot: never migrated
                                    # by work stealing (e.g. tasks with
                                    # pilot-local state or data affinity)
    affinity: Tuple[str, ...] = ()  # data-affinity hints: pilot uids/names
                                    # holding this task's input arrays; a
                                    # LocalityAware policy scores placement
                                    # toward them (soft, unlike sticky)
    checkpointable: bool = False    # the body accepts a ``ckpt`` keyword
                                    # (Checkpoint context): it can resume
                                    # from partial progress, making it
                                    # eligible for checkpoint-based
                                    # straggler replicas, cooperative
                                    # preempt-and-migrate, and partial
                                    # restarts

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.mesh_shape is not None:
            d, m = self.mesh_shape
            if d * m != self.slots:
                raise ValueError(
                    f"mesh_shape {self.mesh_shape} inconsistent with "
                    f"slots={self.slots}")


@dataclass
class TaskRecord:
    uid: str
    kind: str                       # python | spmd | bash
    fn: Optional[Callable] = None
    args: tuple = ()
    kwargs: dict = field(default_factory=dict)
    resources: ResourceSpec = field(default_factory=ResourceSpec)
    state: TaskState = TaskState.NEW
    timestamps: Dict[str, float] = field(default_factory=dict)
    depends_on: List[str] = field(default_factory=list)
    result: Any = None
    error: Optional[BaseException] = None
    retries: int = 0
    max_retries: int = 0
    retry_policy: Optional[RetryPolicy] = None  # translator stamp; None =
                                                # legacy immediate in-place
                                                # retries up to max_retries
    attempt_errors: List[BaseException] = field(default_factory=list)
                                    # why each prior attempt failed; the
                                    # final FAILED exception chains these
                                    # as its __cause__ ancestry
    worker_deaths: int = 0          # attempts that killed a worker
                                    # process (poison-quarantine counter)
    quarantined: bool = False       # terminally FAILED by quarantine
    slot_ids: Tuple[int, ...] = ()
    replica_of: Optional[str] = None
    res_kind: Optional[str] = None  # stamped by the translator
    app_kind: Optional[str] = None  # pre-translation kind (bash apps run
                                    # as kind="python" but route as "bash")
    pilot_uid: Optional[str] = None  # late-bound by PilotPool routing;
                                     # re-stamped if the task is stolen
    sticky: bool = False            # steal-eligibility stamp (translator)
    affinity: Tuple[str, ...] = ()  # data-affinity stamp (translator):
                                    # producer pilots + ResourceSpec hints;
                                    # scored by LocalityAware placement
    affinity_bytes: Optional[Dict[str, int]] = None
                                    # byte-weighted affinity (DFK dep
                                    # manager): input bytes per producer
                                    # pilot — placement follows the
                                    # *largest* input, and CostModelPolicy
                                    # prices the non-local remainder as
                                    # transfer seconds (docs/dataplane.md)
    checkpointable: bool = False    # translator stamp of the ResourceSpec
                                    # flag: body takes a ``ckpt`` context
    ckpt_key: Optional[str] = None  # checkpoint identity: the uid by
                                    # default; replicas share the
                                    # leader's; keyed workflows use the
                                    # stable workflow key (restart)
    ckpt_ctx: Optional[Any] = None  # live Checkpoint context while the
                                    # task executes (runtime-only, never
                                    # journaled; the executor injects it
                                    # as the body's ``ckpt`` kwarg)
    inproc_only: bool = False       # translator stamp: the body must run
                                    # in the agent's process regardless of
                                    # transport (spmd — its sub-mesh is
                                    # bound to this process's XLA client)

    def transition(self, state: TaskState, store=None):
        if _validate_transition is not None:
            _validate_transition(self.state.value, state.value, self.uid)
        self.state = state
        self.timestamps[state.value] = time.monotonic()
        if store is not None:
            store.record(self)


class AppFuture(Future):
    """Parsl-style future: returned immediately on app invocation; reading
    the result blocks until the task completes; passing it to another app
    creates a dataflow edge."""

    def __init__(self, task: TaskRecord):
        super().__init__()
        self.task = task
        # lock-free fast read for dependency resolution: a wide fan-in
        # resolves hundreds of already-completed futures, and each
        # Future.result() pays a condition acquisition.  The stash is
        # written before the state flips to FINISHED, so any reader that
        # observed completion (e.g. via a done callback) sees it.
        self._quick: Optional[Tuple[Any]] = None

    def set_result(self, result):
        if isinstance(result, ObjectRef):
            # published result: the handle is the stored value; deref is
            # lazy (first result() call) and the materialized object then
            # takes over the lock-free stash
            super().set_result(result)
            return
        self._quick = (result,)
        super().set_result(result)

    def result(self, timeout=None):
        q = self._quick
        if q is not None:
            return q[0]
        r = super().result(timeout)
        if isinstance(r, ObjectRef):
            val = r.deref()         # client-side read: uncounted bytes
            self._quick = (val,)
            return val
        return r

    def raw_result(self):
        """Ref-or-value of a completed future: the DFK resolves consumer
        args through this so edges ship handles, not payloads — the
        actual deref happens on the *executing* pilot, where cross-pilot
        bytes are attributable.  Blocks like result() if not yet done."""
        q = self._quick
        if q is not None:
            return q[0]
        return super().result()

    def quick_result(self):
        """Result without the condition round-trip — only valid once the
        future is known to be successfully completed; falls back to
        result() (which blocks or raises) otherwise."""
        q = self._quick
        if q is not None:
            return q[0]
        return self.result()

    @property
    def uid(self) -> str:
        return self.task.uid

    def __repr__(self):
        return f"<AppFuture {self.task.uid} {self.task.state.value}>"
