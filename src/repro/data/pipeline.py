"""Data pipeline: deterministic synthetic corpus + sharded batching +
background prefetch.

The corpus is a seeded token stream (a fixed "document" distribution with
Zipfian token frequencies and document boundaries), so training runs are
reproducible and loss curves comparable across configurations.  The loader
is *stateful and checkpointable*: its cursor is part of the training state,
so checkpoint/restart resumes mid-epoch without skipping or repeating data.

``ShardedLoader`` yields global batches laid out for the mesh's batch axis;
a background thread keeps ``prefetch`` batches ready so host-side batch
assembly overlaps device compute.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 1234
    doc_len_mean: int = 512
    zipf_a: float = 1.2
    frontend_tokens: int = 0      # VLM stub: patch positions per sequence
    d_model: int = 0              # patch embedding dim (vlm stub)


class SyntheticCorpus:
    """Deterministic, seekable token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def tokens_at(self, cursor: int, n: int) -> np.ndarray:
        """n tokens starting at absolute position ``cursor`` — O(n), seeded
        per 1k-block so any offset is reproducible without replay."""
        cfg = self.cfg
        out = np.empty(n, dtype=np.int32)
        got = 0
        block = cursor // 1024
        off = cursor % 1024
        while got < n:
            rng = np.random.default_rng((cfg.seed, block))
            toks = rng.zipf(cfg.zipf_a, size=1024).astype(np.int64)
            toks = (toks - 1) % max(2, cfg.vocab_size - 2) + 2
            # document boundaries -> BOS(1)
            bos = rng.random(1024) < (1.0 / max(2, cfg.doc_len_mean))
            toks[bos] = 1
            take = min(1024 - off, n - got)
            out[got:got + take] = toks[off:off + take]
            got += take
            block += 1
            off = 0
        return out


class ShardedLoader:
    def __init__(self, cfg: DataConfig, start_cursor: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg)
        self.cursor = start_cursor
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _make_batch(self, cursor: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        span = cfg.seq_len + 1
        n = cfg.global_batch * span
        flat = self.corpus.tokens_at(cursor, n).reshape(
            cfg.global_batch, span)
        batch = {
            "tokens": flat[:, :-1].astype(np.int32),
            "targets": flat[:, 1:].astype(np.int32),
            "loss_mask": np.ones((cfg.global_batch, cfg.seq_len),
                                 dtype=np.float32),
        }
        if cfg.frontend_tokens:
            rng = np.random.default_rng((cfg.seed, cursor, 7))
            batch["patches"] = rng.standard_normal(
                (cfg.global_batch, cfg.frontend_tokens, cfg.d_model)
            ).astype(np.float32) * 0.02
        return batch

    def _fill(self):
        cursor = self.cursor
        span = self.cfg.global_batch * (self.cfg.seq_len + 1)
        while not self._stop.is_set():
            b = self._make_batch(cursor)
            b["_cursor"] = cursor + span
            while not self._stop.is_set():
                try:
                    self._q.put(b, timeout=0.1)
                    break
                except queue.Full:
                    continue
            cursor += span

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self._q.get()
        self.cursor = b.pop("_cursor")
        return b

    def state(self) -> dict:
        return {"cursor": self.cursor}

    def close(self):
        self._stop.set()
