"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

import importlib

from .base import (LONG_CONTEXT_OK, SHAPES, ModelConfig, ShapeConfig, cells,
                   reduce_config)

ARCHS = [
    "qwen3-moe-235b-a22b",
    "dbrx-132b",
    "gemma2-9b",
    "internlm2-1.8b",
    "granite-3-2b",
    "smollm-360m",
    "jamba-1.5-large-398b",
    "internvl2-76b",
    "musicgen-large",
    "mamba2-1.3b",
]

_MODULE = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "dbrx-132b": "dbrx_132b",
    "gemma2-9b": "gemma2_9b",
    "internlm2-1.8b": "internlm2_1_8b",
    "granite-3-2b": "granite_3_2b",
    "smollm-360m": "smollm_360m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "internvl2-76b": "internvl2_76b",
    "musicgen-large": "musicgen_large",
    "mamba2-1.3b": "mamba2_1_3b",
}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULE:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULE)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE[name]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = [
    "ARCHS", "SHAPES", "LONG_CONTEXT_OK", "ModelConfig", "ShapeConfig",
    "get_config", "get_shape", "cells", "reduce_config",
]
