"""Jamba-1.5-Large-398B — 72L d_model=8192 64H (GQA kv=8) d_ff=24576,
vocab 65536; Mamba2+attn 1:7 interleave, MoE 16e top-2 every other layer.
[arXiv:2403.19887; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    num_experts_per_tok=2,
    moe_every=2,
    attn_every=8,            # 1 attention layer per 8 (1:7 mamba:attn)
    ssm_state=128,
    d_inner=16384,           # 2 * d_model
    ssm_head_dim=128,
    rope_theta=10_000.0,
    source="arXiv:2403.19887",
)
