"""Mamba2-1.3B — 48L d_model=2048, attention-free SSD, ssm_state=128,
vocab 50280.  [arXiv:2405.21060; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                  # pure mamba block, no separate FFN
    vocab_size=50280,
    ssm_state=128,
    d_inner=4096,
    ssm_head_dim=64,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
