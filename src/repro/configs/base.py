"""Model / shape configuration system.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
workflow runtime treats each (config, shape) pair as a *task species* — the
paper's "heterogeneous tasks" — so configs carry everything the task
translator needs to derive resource requirements (parameter bytes, FLOPs per
token) in addition to what the model builder needs.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | vlm | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_every: int = 1               # MoE FFN every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    moe_dispatch: str = "einsum"     # einsum (GShard-style) | gather (zero-FLOP)

    # --- attention variants ---
    sliding_window: int = 0          # window size for local layers (gemma2: 4096)
    local_global_alternate: bool = False
    attn_softcap: float = 0.0        # gemma2: 50.0
    logit_softcap: float = 0.0       # gemma2: 30.0
    rope_theta: float = 10_000.0

    # --- SSM / hybrid ---
    ssm_state: int = 0               # N (mamba2 d_state)
    d_inner: int = 0                 # mamba inner width (default 2*d_model)
    ssm_head_dim: int = 64           # P
    ssm_chunk: int = 256             # SSD chunk length
    conv_width: int = 4
    attn_every: int = 0              # hybrid: 1 attention layer per `attn_every` (jamba: 8)

    # --- frontend stubs ---
    frontend: str = "none"           # none | vision_stub | audio_stub
    frontend_tokens: int = 0         # patch/frame positions occupied by the stub

    # --- misc ---
    tie_embeddings: bool = False
    gated_mlp: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # remat policy: "full" | "dots" | "none"  (hillclimb knob)
    remat: str = "full"
    source: str = ""

    # ------------------------------------------------------------------ #
    @property
    def ssm_heads(self) -> int:
        inner = self.d_inner or 2 * self.d_model
        return inner // self.ssm_head_dim

    @property
    def inner_dim(self) -> int:
        return self.d_inner or 2 * self.d_model

    def layer_kind(self, i: int) -> str:
        """Mixer kind for layer i: 'attn' | 'local_attn' | 'mamba'."""
        if self.family == "ssm":
            return "mamba"
        if self.attn_every:  # hybrid (jamba): one attn per attn_every layers
            return "attn" if (i % self.attn_every) == (self.attn_every - 1) else "mamba"
        if self.local_global_alternate:
            return "local_attn" if i % 2 == 0 else "attn"
        return "attn"

    def ffn_kind(self, i: int) -> str:
        """FFN kind for layer i: 'dense' | 'moe' | 'none'."""
        if self.d_ff == 0 and self.num_experts == 0:
            return "none"
        if self.num_experts and (i % self.moe_every) == (self.moe_every - 1):
            return "moe"
        if self.d_ff:
            return "dense"
        return "none"

    # ----------------------- analytic accounting ---------------------- #
    def param_count(self) -> int:
        """Analytic parameter count (matches init within rounding)."""
        d, hd = self.d_model, self.head_dim
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind in ("attn", "local_attn"):
                n += d * self.num_heads * hd            # wq
                n += 2 * d * self.num_kv_heads * hd     # wk, wv
                n += self.num_heads * hd * d            # wo
            else:  # mamba2
                inner, nh, N = self.inner_dim, self.ssm_heads, self.ssm_state
                n += d * (2 * inner + 2 * N + nh)       # in_proj (x,z,B,C,dt)
                n += inner * d                          # out_proj
                n += self.conv_width * (inner + 2 * N)  # conv
                n += 3 * nh                             # A_log, D, dt_bias
            fk = self.ffn_kind(i)
            mats = 3 if self.gated_mlp else 2
            if fk == "dense":
                n += mats * d * self.d_ff
            elif fk == "moe":
                n += d * self.num_experts               # router
                n += self.num_experts * mats * d * self.d_ff
            n += d + (d if fk != "none" else 0)         # pre-mixer (+pre-ffn) norms
        n += d                                          # final norm
        if self.frontend == "vision_stub":
            n += 2 * d * d                              # connector MLP
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        mats = 3 if self.gated_mlp else 2
        n = self.param_count()
        n_moe_layers = sum(1 for i in range(self.num_layers) if self.ffn_kind(i) == "moe")
        inactive = n_moe_layers * (self.num_experts - self.num_experts_per_tok) * mats * d * self.d_ff
        return n - inactive

    def model_flops_per_token(self, training: bool) -> float:
        """6*N_active per token (bwd = 2x fwd) or 2*N_active for inference."""
        return (6.0 if training else 2.0) * self.active_param_count()


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def tokens(self) -> int:
        # decode processes 1 new token per sequence against a seq_len cache
        return self.global_batch * (1 if self.kind == "decode" else self.seq_len)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Archs with a sub-quadratic mechanism run long_500k; pure full-attention
# archs skip it (recorded in DESIGN.md §Arch-applicability).
LONG_CONTEXT_OK = {"gemma2-9b", "jamba-1.5-large-398b", "mamba2-1.3b"}


def cells(arch: str) -> list:
    """The dry-run cells for one architecture."""
    out = []
    for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        if s == "long_500k" and arch not in LONG_CONTEXT_OK:
            out.append((s, "SKIP(full-attn)"))
        else:
            out.append((s, "RUN"))
    return out


def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        num_layers=max(2, cfg.attn_every or 0, 2 * (cfg.moe_every or 1)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 2,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=257,
        num_experts=4 if cfg.num_experts else 0,
        num_experts_per_tok=2 if cfg.num_experts else 0,
        d_inner=128 if (cfg.family in ("ssm", "hybrid")) else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=32,
        ssm_chunk=8,
        sliding_window=8 if cfg.sliding_window else 0,
        frontend_tokens=4 if cfg.frontend != "none" else 0,
        name=cfg.name + "-smoke",
    )
    if cfg.attn_every:
        small["num_layers"] = 2 * cfg.attn_every  # cover both mixer kinds
    small.update(overrides)
    return replace(cfg, **small)
