"""Gemma2-9B — 42L d_model=3584 16H (GQA kv=8) d_ff=14336, vocab 256000;
local(4096-window)+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,           # gemma2 uses head_dim 256 (16*256 = 4096 != d_model)
    d_ff=14336,
    vocab_size=256000,
    sliding_window=4096,
    local_global_alternate=True,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    rope_theta=10_000.0,
    source="arXiv:2408.00118",
)
