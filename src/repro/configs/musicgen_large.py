"""MusicGen-Large — 48L d_model=2048 32H (kv=32, i.e. MHA) d_ff=8192,
vocab 2048 (EnCodec codebook); decoder-only over EnCodec tokens; the EnCodec
frontend is a STUB (tokens arrive precomputed). [arXiv:2306.05284; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    gated_mlp=False,         # musicgen uses a plain 2-matrix FFN
    frontend="audio_stub",
    frontend_tokens=0,       # EnCodec tokens ARE the input stream
    rope_theta=10_000.0,
    source="arXiv:2306.05284",
)
