"""InternVL2-76B (LM backbone) — 80L d_model=8192 64H (GQA kv=8) d_ff=28672,
vocab 128256; InternViT frontend is a STUB (input_specs feeds patch
embeddings).  [arXiv:2404.16821; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    frontend="vision_stub",
    frontend_tokens=1024,    # patch-embedding positions inside each sequence
    rope_theta=5e5,
    source="arXiv:2404.16821",
)
