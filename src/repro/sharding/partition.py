"""Logical-axis -> physical-mesh partitioning.

Weights and activations are annotated with *logical* axis names; this module
resolves them against a concrete mesh, with divisibility fallback (e.g.
smollm's 15 query heads cannot shard 16-way -> replicated; granite's 49155
vocab rows cannot shard 16-way -> embedding falls back to FSDP-only).

Resolution is the single place where DP/FSDP/TP/EP decisions live, so the
perf pass can hillclimb by editing one rule table.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# logical axis -> ordered candidate mesh axes (first divisible wins; the
# batch/fsdp axis composes pod+data when a pod axis exists).
DEFAULT_RULES: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    "batch":    (("pod", "data"), ("data",)),
    "embed_w":  (("pod", "data"), ("data",)),   # weight FSDP axis (ZeRO-3)
    "vocab":    (("model",),),
    "heads":    (("model",),),
    "kv_heads": (("model",),),
    "mlp":      (("model",),),
    "expert":   (("model",),),
    "ssm_heads": (("model",),),
    "ssm_inner": (("model",),),
    # expert weights: d-dim FSDP by default (same as embed_w); the serving
    # rule-set flips to {expert_embed: replicated, expert_ff: data} so the
    # (dominant) expert weights are never all-gathered per decode step.
    "expert_embed": (("pod", "data"), ("data",)),
    "expert_ff": ((),),
    "seq_kv":   (("data",),),                    # long-context decode KV shard
    "seq":      ((),),                           # train seq: unsharded
    "embed":    ((),),                           # activation d_model: unsharded
    "head_dim": (("model",),),                   # fallback TP when heads can't

    "layers":   ((),),                           # scan/group dim (PP would go here)
    "state":    ((),),
    None:       ((),),
}


class PartitionRules:
    def __init__(self, rules: Optional[Dict] = None):
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def _axis_size(self, mesh: Mesh, axes: Tuple[str, ...]) -> int:
        return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    def spec_for(self, logical: Sequence[Optional[str]], shape: Sequence[int],
                 mesh: Mesh) -> P:
        used = set()
        out = []
        for name, dim in zip(logical, shape):
            resolved = None
            for cand in self.rules.get(name, ((),)):
                cand = tuple(a for a in cand if a in mesh.shape)
                if not cand:
                    continue
                if any(a in used for a in cand):
                    continue
                sz = self._axis_size(mesh, cand)
                if sz > 1 and dim % sz == 0:
                    resolved = cand if len(cand) > 1 else cand[0]
                    used.update(cand)
                    break
            out.append(resolved)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding_for(self, logical, shape, mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, self.spec_for(logical, shape, mesh))

    def tree_specs(self, axes_tree, shape_tree, mesh: Mesh):
        """Map a pytree of logical-axes tuples + matching shapes to specs."""
        return jax.tree.map(
            lambda ax, shp: self.spec_for(ax, shp.shape, mesh),
            axes_tree, shape_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x),
        )


class ShardCtx:
    """Carries (mesh, rules) into model code; ``act`` constrains activations.

    A ``None`` ShardCtx (CPU smoke tests, single device) makes every
    constraint a no-op, so model code is written once.
    """

    def __init__(self, mesh: Optional[Mesh], rules: Optional[PartitionRules] = None):
        self.mesh = mesh
        self.rules = rules or PartitionRules()

    def act(self, x, logical: Sequence[Optional[str]]):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, self.rules.sharding_for(logical, x.shape, self.mesh))

    def spec(self, logical, shape) -> P:
        if self.mesh is None:
            return P()
        return self.rules.spec_for(logical, shape, self.mesh)


NULL_CTX = ShardCtx(None)
