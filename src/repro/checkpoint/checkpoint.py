"""Sharded checkpointing: per-leaf .npy shards + JSON manifest.

Production properties:
  * atomic commit — writes go to ``step_N.tmp/`` and are renamed into place,
    so a crash mid-save never corrupts the latest checkpoint;
  * async — ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread, overlapping I/O with the next step;
  * sharding-aware restore — arrays are re-placed with the target sharding
    via ``jax.device_put``, so a checkpoint written on one mesh restores
    onto another (elastic restart across different pilot sizes);
  * self-describing — the manifest records the flattened treedef, shapes,
    dtypes, and the training step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# dtypes numpy can't serialize natively: store as same-width integer views
_EXOTIC = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    # ------------------------------ save -------------------------------- #
    def save(self, step: int, tree: Any):
        self.wait()
        host = [np.asarray(l) for l in jax.tree.leaves(tree)]
        self._write(step, host, jax.tree.structure(tree))

    def save_async(self, step: int, tree: Any):
        self.wait()
        # snapshot to host now (device buffers may be donated next step)
        host = [np.asarray(l) for l in jax.tree.leaves(tree)]
        treedef = jax.tree.structure(tree)
        self._thread = threading.Thread(
            target=self._write_guarded, args=(step, host, treedef),
            daemon=True)
        self._thread.start()

    def _write_guarded(self, step, host, treedef):
        try:
            self._write(step, host, treedef)
        except BaseException as e:  # noqa: BLE001 — surfaced via wait()
            self._err = e

    def _write(self, step, host, treedef):
        tmp = self.dir / f"step_{step:09d}.tmp"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "n_leaves": len(host),
                    "treedef": str(treedef),
                    "leaves": [], "t": time.time()}
        for i, arr in enumerate(host):
            name = str(arr.dtype)
            if name in _EXOTIC:
                np.save(tmp / f"leaf_{i:05d}.npy",
                        arr.view(_EXOTIC[name][1]))
            else:
                np.save(tmp / f"leaf_{i:05d}.npy", arr)
            manifest["leaves"].append(
                {"shape": list(arr.shape), "dtype": name})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)      # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # ----------------------------- restore ------------------------------ #
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.name.endswith(".tmp"):
                continue
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any]:
        """Restore into the structure of ``tree_like``; optional shardings
        tree re-places leaves onto the current mesh."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = jax.tree.flatten(tree_like)
        if manifest["n_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"target structure has {len(leaves)}")
        out = []
        sh_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
                     if shardings is not None else [None] * len(leaves))
        for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
            arr = np.load(d / f"leaf_{i:05d}.npy")
            name = manifest["leaves"][i]["dtype"]
            if name in _EXOTIC:
                arr = arr.view(_EXOTIC[name][0])
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            elif isinstance(ref, jax.Array):
                # device leaf: ref.dtype is backend-supported by
                # construction, so asarray never truncates
                out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
            else:
                # host-side leaf (np.ndarray or np scalar): stay in numpy —
                # routing through jnp would silently truncate dtypes the
                # backend lacks (float64 under default x32)
                out.append(arr.astype(ref.dtype))
        return step, jax.tree.unflatten(treedef, out)
