from . import ref  # noqa
