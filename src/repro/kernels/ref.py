"""Pure-jnp oracles for the Pallas kernels.

``attention_reference`` — naive full-softmax attention (quadratic memory);
``ssd_reference``       — chunked SSD scan (the model's default impl);
``ssd_sequential``      — step-by-step SSM recurrence (oracle for chunking).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(q, k, v, *, causal: bool = True, window: int = 0,
                        attn_softcap: float = 0.0, q_offset: int = 0):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bshgd,bkhd->bshgk", qg, k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    if attn_softcap:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= kv_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bshgk,bkhd->bshgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


# ------------------------------- SSD ----------------------------------- #

def ssd_sequential(x, dt, A, B_, C_, h0: Optional[jnp.ndarray] = None):
    """Step-by-step SSM recurrence (slow oracle).

    x: (B,S,H,P); dt: (B,S,H); A: (H,); B_/C_: (B,S,N).
    h_t = exp(dt_t A) h_{t-1} + dt_t * x_t (outer) B_t ;  y_t = C_t . h_t
    """
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    xf = x.astype(jnp.float32)
    Bf, Cf = B_.astype(jnp.float32), C_.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * A[None, :])           # (B,H)
        h = h * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, bt)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None else h0
    hT, ys = jax.lax.scan(
        step, h0,
        (xf.swapaxes(0, 1), dtf.swapaxes(0, 1), Bf.swapaxes(0, 1),
         Cf.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), hT


def ssd_chunk_terms(xc, dtc, A, Bc, Cc):
    """Intra-chunk SSD terms for one chunk batch.

    xc: (B,Q,H,P); dtc: (B,Q,H); A: (H,); Bc/Cc: (B,Q,N).
    Returns (y_intra (B,Q,H,P), state (B,H,P,N), decay_all (B,H,Q),
    decay_chunk (B,H)).  All f32.
    """
    Q = xc.shape[1]
    la = dtc * A[None, None, :]                       # (B,Q,H) log-decay
    cum = jnp.cumsum(la, axis=1)                      # L_i (inclusive)
    # pairwise decay exp(L_i - L_j) for j <= i
    Li = cum.transpose(0, 2, 1)                       # (B,H,Q)
    diff = Li[:, :, :, None] - Li[:, :, None, :]      # (B,H,Qi,Qj)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bin,bjn->bij", Cc, Bc)           # (B,Qi,Qj)
    M = cb[:, None] * L * dtc.transpose(0, 2, 1)[:, :, None, :]   # (B,H,Qi,Qj)
    y_intra = jnp.einsum("bhij,bjhp->bihp", M, xc)
    # chunk state: sum_j exp(L_Q - L_j) dt_j B_j (outer) x_j
    decay_to_end = jnp.exp(Li[:, :, -1:] - Li)        # (B,H,Q)
    state = jnp.einsum("bhq,bqh,bqn,bqhp->bhpn", decay_to_end, dtc, Bc, xc)
    decay_all = jnp.exp(Li)                           # exp(L_i) (B,H,Q)
    decay_chunk = jnp.exp(Li[:, :, -1])               # (B,H)
    return y_intra, state, decay_all, decay_chunk


def ssd_reference(x, dt, A, B_, C_, *, chunk: int, h0=None):
    """Chunked SSD: scan over chunks of length ``chunk``.

    Same contract as :func:`ssd_sequential` but O(S*Q) memory / step.
    """
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    if S % Q:
        raise ValueError(f"seq {S} not divisible by chunk {Q}")
    nc = S // Q
    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bf = B_.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Cf = C_.astype(jnp.float32).reshape(Bsz, nc, Q, N)

    def step(h, inp):
        xc, dtc, bc, cc = inp
        y_intra, state, decay_all, decay_chunk = ssd_chunk_terms(
            xc, dtc, A, bc, cc)
        # inter-chunk: y_i += C_i . (exp(L_i) * h_prev)
        y_inter = jnp.einsum("bqn,bhq,bhpn->bqhp", cc, decay_all, h)
        h_new = h * decay_chunk[..., None, None] + state
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None else h0
    hT, ys = jax.lax.scan(
        step, h0, (xf.swapaxes(0, 1), dtf.swapaxes(0, 1), Bf.swapaxes(0, 1),
                   Cf.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), hT
