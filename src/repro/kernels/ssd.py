"""Pallas TPU kernel for the Mamba2 SSD intra-chunk computation.

Grid: (batch, heads, num_chunks) — each program owns one (chunk x head)
tile and produces, entirely in VMEM:
    y_intra  (Q, P)  — the chunk-local quadratic ("attention-like") term
    state    (P, N)  — the chunk's contribution to the running SSM state
    decay_all (Q,)   — exp(cumsum(dt*A)) for the inter-chunk correction
    decay_chunk ()   — exp(full-chunk log-decay)
The O(S) inter-chunk recurrence (a tiny tensor contraction per chunk) stays
a lax.scan on the host side (ops.ssd) — it is bandwidth-trivial compared to
the intra-chunk quadratic term this kernel owns.

Tiling: Q (chunk length, default 128-256) x P (head dim 64/128) and (Q, N)
B/C tiles; all matmuls are (Q,N)x(N,Q), (Q,Q)x(Q,P), (N,Q)x(Q,P) — MXU
shapes.  Validated against ref.ssd_chunk_terms / ssd_reference in interpret
mode (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
            y_ref, st_ref, dall_ref, dchunk_ref, *, Q):
    x = x_ref[0, 0, 0].astype(jnp.float32)     # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)   # (Q,)
    A = a_ref[0].astype(jnp.float32)           # ()
    Bc = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    Cc = c_ref[0, 0].astype(jnp.float32)       # (Q, N)

    la = dt * A                                 # (Q,) log-decay
    cum = jnp.cumsum(la)                        # L_i inclusive
    diff = cum[:, None] - cum[None, :]          # (Qi, Qj)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(iota_j <= iota_i, jnp.exp(diff), 0.0)
    cb = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Qi,Qj)
    M = cb * L * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q,P)
    decay_to_end = jnp.exp(cum[-1] - cum)       # (Q,)
    wB = Bc * (decay_to_end * dt)[:, None]      # (Q,N)
    state = jax.lax.dot_general(x, wB, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (P,N)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    st_ref[0, 0, 0] = state.astype(st_ref.dtype)
    dall_ref[0, 0, 0] = jnp.exp(cum).astype(dall_ref.dtype)
    dchunk_ref[0, 0, 0] = jnp.exp(cum[-1]).astype(dchunk_ref.dtype)


def ssd_chunk_kernel(x, dt, A, B_, C_, *, chunk: int, interpret: bool = True):
    """Intra-chunk terms for all chunks.

    x: (B,S,H,P); dt: (B,S,H) f32; A: (H,); B_/C_: (B,S,N).
    Returns y_intra (B,S,H,P) f32, states (B,H,nc,P,N) f32,
    decay_all (B,H,nc,Q) f32, decay_chunk (B,H,nc) f32.
    """
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    # layouts: (B,H,nc,Q,*) for per-(head,chunk) tiles
    xr = x.reshape(Bsz, nc, Q, H, P).transpose(0, 3, 1, 2, 4)
    dtr = dt.reshape(Bsz, nc, Q, H).transpose(0, 3, 1, 2)
    Br = B_.reshape(Bsz, nc, Q, N)
    Cr = C_.reshape(Bsz, nc, Q, N)

    y, st, dall, dchunk = pl.pallas_call(
        functools.partial(_kernel, Q=Q),
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, c: (b, h, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, nc, P, N), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, nc, Q), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, nc), jnp.float32),
        ],
        interpret=interpret,
    )(xr, dtr, A, Br, Cr)
    # y back to (B,S,H,P)
    y = y.transpose(0, 2, 3, 1, 4).reshape(Bsz, S, H, P)
    return y, st, dall, dchunk


def _kernel_ref_note():
    """The (1,1,...) leading block dims exist because pallas interpret mode
    requires block shapes to cover every array dim; squeezed in-kernel."""
