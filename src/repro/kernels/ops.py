"""jit'd public wrappers for the Pallas kernels.

``interpret=None`` auto-selects: compiled on TPU, interpret elsewhere — so
the same model code (use_pallas=True) runs the real kernel on hardware and
the Python-executed kernel body on the CPU container.

The SSD wrapper composes the Pallas intra-chunk kernel with the host-side
inter-chunk recurrence (a lax.scan over per-chunk states) and defines a
custom VJP that recomputes kernel terms in the backward pass via the jnp
reference (training path memory: O(S) states, no stored (Q,Q) matrices).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import ssd as _ssd
from . import ref as _ref


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    attn_softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    """Forward-only Pallas flash attention (inference/prefill hot path).

    The training path uses the custom-VJP jnp formulation in
    repro.models.attention (same algorithm; this kernel is its TPU twin and
    is differentiated via the same reference backward when needed).
    """
    return _fa.flash_attention_fwd(
        q, k, v, causal=causal, window=window, attn_softcap=attn_softcap,
        block_q=block_q, block_k=block_k,
        interpret=_auto_interpret(interpret))


@partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def ssd(x, dt, A, B_, C_, chunk: int = 128,
        interpret: Optional[bool] = None):
    """SSD scan: Pallas intra-chunk kernel + host inter-chunk recurrence.

    Same contract as repro.kernels.ref.ssd_reference.
    Returns (y (B,S,H,P), final_state (B,H,P,N) f32).
    """
    y, hT = _ssd_fwd_impl(x, dt, A, B_, C_, chunk, interpret)
    return y, hT


def _ssd_fwd_impl(x, dt, A, B_, C_, chunk, interpret):
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    y_intra, states, dall, dchunk = _ssd.ssd_chunk_kernel(
        x, dt, A, B_, C_, chunk=Q, interpret=_auto_interpret(interpret))
    Cr = C_.astype(jnp.float32).reshape(Bsz, nc, Q, N)

    def step(h, inp):
        st_c, dall_c, dch_c, c_c = inp
        # y_inter_i = C_i . (exp(L_i) * h_prev)
        y_int = jnp.einsum("bqn,bhq,bhpn->bqhp", c_c, dall_c, h)
        h_new = h * dch_c[..., None, None] + st_c
        return h_new, y_int

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    hT, y_inter = jax.lax.scan(
        step, h0,
        (states.transpose(2, 0, 1, 3, 4), dall.transpose(2, 0, 1, 3),
         dchunk.transpose(2, 0, 1), Cr.transpose(1, 0, 2, 3)))
    y_inter = y_inter.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    y = (y_intra.reshape(Bsz, S, H, P) + y_inter).astype(x.dtype)
    return y, hT


def _ssd_fwd(x, dt, A, B_, C_, chunk, interpret):
    out = _ssd_fwd_impl(x, dt, A, B_, C_, chunk, interpret)
    return out, (x, dt, A, B_, C_)


def _ssd_bwd(chunk, interpret, res, cts):
    # backward through the jnp reference (identical math; recomputes chunk
    # terms instead of storing (Q,Q) matrices)
    x, dt, A, B_, C_ = res
    def f(x, dt, A, B_, C_):
        return _ref.ssd_reference(x, dt, A, B_, C_, chunk=chunk)
    _, vjp = jax.vjp(f, x, dt, A, B_, C_)
    return vjp(cts)


ssd.defvjp(_ssd_fwd, _ssd_bwd)
