"""Pallas TPU flash attention kernel.

Grid: (batch*heads, num_q_blocks, num_kv_blocks) — the last grid dimension
iterates sequentially on TPU, so the online-softmax running state (m, l,
acc) lives in VMEM scratch that persists across kv steps.  BlockSpecs tile
(block_q x head_dim) of q and (block_k x head_dim) of k/v into VMEM;
blocks are MXU-aligned (128-lane).  GQA is resolved in the k/v index_map
(q head -> kv head), so grouped queries reuse K/V tiles without host-side
broadcast.

Causal skipping: kv blocks strictly above the diagonal are predicated off
with pl.when — their MXU work is never issued (the jnp reference pays full
S^2; the kernel pays the ~S^2/2 the algorithm needs).

Validated against repro.kernels.ref.attention_reference in interpret mode
across a shape/dtype sweep (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, cap, block_q, block_k, nk, sq, skv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    needed = jnp.bool_(True)
    if causal:  # block fully above the diagonal contributes nothing
        needed &= k_start <= q_start + block_q - 1
    if window:  # block fully outside the attention window contributes nothing
        needed &= k_start + block_k - 1 > q_start - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)           # (bq, D)
        k = k_ref[0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if cap:
            s = cap * jnp.tanh(s / cap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        kv_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
        mask = (kv_pos < skv) & (q_pos < sq)
        if causal:
            mask &= kv_pos <= q_pos
        if window:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        attn_softcap: float = 0.0, block_q: int = 128,
                        block_k: int = 128, interpret: bool = True):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Skv, block_k)
    sq_pad, skv_pad = nq * block_q, nk * block_k
    scale = D ** -0.5

    # (B*H, S, D) layout: folded batch*head leading grid dim
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    if sq_pad != Sq:
        qf = jnp.pad(qf, ((0, 0), (0, sq_pad - Sq), (0, 0)))
    if skv_pad != Skv:
        kf = jnp.pad(kf, ((0, 0), (0, skv_pad - Skv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, skv_pad - Skv), (0, 0)))

    def kv_index(bh, qi, ki):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // G, ki, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          cap=attn_softcap, block_q=block_q, block_k=block_k,
                          nk=nk, sq=Sq, skv=Skv),
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D), kv_index),
            pl.BlockSpec((1, block_k, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, D),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, sq_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :Sq].reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
    return out
