"""Experiment 8: process-based worker pools vs the in-process thread pool.

ROADMAP open item 3 called the GIL-bound thread pool "the ceiling for
every other direction": exp3's bulk throughput tops out at one core no
matter how many slots a pilot has, because every python task body shares
the interpreter lock.  This experiment measures what the pluggable
WorkerTransport buys:

  * bulk **no-op** throughput, inproc vs proc — the proc transport pays
    pickle + pipe per task, so no-ops are its *worst* case (reported
    honestly; overhead-bound workloads should stay inproc);
  * bulk **CPU-burn** throughput, inproc vs proc — fixed-work bodies on
    ``--slots`` concurrent slots.  Inproc serializes behind the GIL
    (gil_bound ~ 1.0); proc workers burn on separate cores
    (gil_bound -> 1/cores), and the headline ``proc_speedup_cpu`` is the
    wall-time ratio.  CI gates on ``--min-proc-speedup`` (1.3x; ideal is
    ~2x minus transport overhead on 2 cores).  The gate self-skips when
    fewer than 2 cores are visible — two processes time-sharing one core
    cannot beat two threads on it — and the JSON records ``cores`` so
    each artifact says which environment produced it.

Emits ``BENCH_procpool.json``.  See docs/processes.md for the transport
design and its guarantees.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

from repro.core import (PilotDescription, ResourceSpec, RPEXExecutor,
                        translate)


def _noop(x):
    return x


def _burn(iters):
    """Fixed-work CPU burn — NOT wall-clock bounded (a time-based burn
    hides GIL contention: contended threads do less work in the same
    wall time, so the bulk looks falsely parallel)."""
    x = 0
    for i in range(iters):
        x += i * i
    return x


def _calibrate_burn(target_s: float) -> int:
    iters = 50_000
    while True:
        t0 = time.perf_counter()
        _burn(iters)
        dt = time.perf_counter() - t0
        if dt >= target_s / 4:
            return max(1, int(iters * target_s / dt))
        iters *= 2


def _rpex(transport: str, n_slots: int) -> RPEXExecutor:
    return RPEXExecutor(PilotDescription(
        n_slots=n_slots, max_workers=n_slots, transport=transport,
        name=f"exp8-{transport}"))


def bench_bulk(transport: str, fn, arg, n_tasks: int, n_slots: int,
               work_s: float = 0.0, warmup: int = 4) -> dict:
    """Bulk-submit n_tasks of fn(arg); wall time (+ gil_bound when the
    per-task single-threaded work is known).  gil_bound is wall over
    total *calibrated* work — NOT over summed task spans, which stretch
    under GIL contention by exactly the factor they are meant to expose
    (contended and parallel runs produce the same span ratio)."""
    rpex = _rpex(transport, n_slots)
    try:
        # warmup: first proc dispatches pay worker fork; first inproc
        # dispatches pay thread spawn — neither is steady-state
        wu = [translate(fn, (arg,), {}, ResourceSpec(slots=1))
              for _ in range(warmup)]
        rpex.tmgr.submit_bulk(wu)
        assert rpex.tmgr.wait(timeout=60), "warmup timed out"
        tasks = [translate(fn, (arg,), {}, ResourceSpec(slots=1))
                 for _ in range(n_tasks)]
        t0 = time.monotonic()
        rpex.tmgr.submit_bulk(tasks)
        ok = rpex.tmgr.wait(timeout=300)
        assert ok, f"{transport} bulk timed out"
        wall = time.monotonic() - t0
        out = {"wall_s": wall, "tasks_per_s": n_tasks / wall}
        if work_s > 0:
            out["gil_bound"] = wall / (n_tasks * work_s)
        return out
    finally:
        rpex.shutdown()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--noop-tasks", type=int, default=400)
    ap.add_argument("--burn-tasks", type=int, default=48)
    ap.add_argument("--burn-s", type=float, default=0.02,
                    help="single-threaded CPU work per burn task")
    ap.add_argument("--slots", type=int, default=2,
                    help="concurrent slots (= workers); 2 on a 2-core "
                         "container isolates the GIL effect")
    ap.add_argument("--repeats", type=int, default=3,
                    help="repeat each measurement, keep the best wall "
                         "time (min-of-N estimates the floor under "
                         "container scheduling noise)")
    ap.add_argument("--min-proc-speedup", type=float, default=0.0,
                    help="exit nonzero if proc/inproc CPU-burn wall-time "
                         "speedup falls below this (CI gates at 1.3 on "
                         "the 2-core container; 0 = report only)")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent.parent
                                         / "BENCH_procpool.json"))
    args = ap.parse_args(argv)

    iters = _calibrate_burn(args.burn_s)
    cores = len(os.sched_getaffinity(0))
    results = {"config": {"noop_tasks": args.noop_tasks,
                          "burn_tasks": args.burn_tasks,
                          "burn_s": args.burn_s, "burn_iters": iters,
                          "slots": args.slots, "repeats": args.repeats,
                          "cores": cores}}

    def best(transport, fn, arg, n, work_s=0.0):
        runs = [bench_bulk(transport, fn, arg, n, args.slots, work_s)
                for _ in range(max(1, args.repeats))]
        return min(runs, key=lambda r: r["wall_s"])

    print(f"# bulk no-op ({args.noop_tasks} tasks, {args.slots} slots)")
    noop = {}
    for tr in ("inproc", "proc"):
        noop[tr] = best(tr, _noop, 0, args.noop_tasks)
        print(f"  {tr:7s}: {noop[tr]['tasks_per_s']:9,.0f} tasks/s")
    results["noop"] = noop

    print(f"# bulk CPU-burn ({args.burn_tasks} x ~{args.burn_s * 1e3:.0f}ms, "
          f"{args.slots} slots, {cores} core(s))")
    burn = {}
    for tr in ("inproc", "proc"):
        burn[tr] = best(tr, _burn, iters, args.burn_tasks, args.burn_s)
        print(f"  {tr:7s}: wall {burn[tr]['wall_s']:6.2f}s"
              f"   gil_bound {burn[tr]['gil_bound']:.2f}")
    results["cpu_burn"] = burn

    speedup = burn["inproc"]["wall_s"] / burn["proc"]["wall_s"]
    results["proc_speedup_cpu"] = speedup
    print(f"# proc-transport CPU-bound speedup: {speedup:.2f}x "
          f"(ideal ~{min(args.slots, cores)}.0x minus pipe+pickle overhead)")

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=2))
    print(f"wrote {out}")
    if args.min_proc_speedup:
        if cores < 2:
            # two processes time-share one core: no parallel speedup is
            # physically possible, so the gate would only test noise.
            # The JSON records cores so the cross-PR trajectory shows
            # which environment produced each artifact.
            print(f"GATE SKIPPED: only {cores} core(s) available — the "
                  f"{args.min_proc_speedup:.1f}x proc-speedup gate needs "
                  f">= 2 cores (it is active on multi-core CI runners)")
        elif speedup < args.min_proc_speedup:
            raise SystemExit(
                f"REGRESSION: proc CPU-bound speedup {speedup:.2f}x < "
                f"required {args.min_proc_speedup:.2f}x on {cores} cores")
    return results


if __name__ == "__main__":
    main()
