"""Experiment 10: resilience under a chaos storm — makespan degradation
and exactly-once completion with pilot failure domains active.

Two runs of the same workload over a 3-pilot pool (two inproc, one proc
so worker kills have a target), heartbeat supervision on, PoolScaler
replace-on-loss armed:

  * baseline — fault free;
  * chaos    — a pilot crash pinned to the pilot holding a RUNNING
    checkpointable task (guaranteeing a checkpoint re-adoption), plus a
    seeded storm of worker kills and slot failures.

The workload mixes long checkpointable step tasks with a burst of short
python tasks, all carrying a RetryPolicy (backoff + retry-on-a-
different-pilot for infra failures).  Hard gates on the chaos run:

  * every task completes DONE, exactly once;
  * a PILOT_LOST event is journaled and its work re-routes
    (STOLEN reason="pilot-lost");
  * at least one checkpointable task resumes at step > 0 on a survivor;
  * the scaler replaces the lost pilot (a ``replace_lost`` decision).

The soft gate is graceful degradation: chaos makespan / baseline
makespan must stay <= --max-degradation-ratio (0 = report only; CI
passes a finite bound).  Emits ``BENCH_resilience.json`` at the repo
root.
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from pathlib import Path

from repro.core import (EVENTS, FaultInjector, PilotDescription,
                        PilotPool, PoolScaler, ResourceSpec, RetryPolicy,
                        ScalerConfig, TaskManager, TaskState, translate)


def _ckpt_body(n, step_s, ckpt=None):
    start = 0
    got = ckpt.restore()
    if got is not None:
        start = got[0] + 1
    for step in range(start, n):
        time.sleep(step_s)
        ckpt.save(step, step)
    return {"start": start}


def run_workload(chaos: bool, n_tasks: int, task_ms: float, ckpt_tasks: int,
                 ckpt_steps: int, step_ms: float, seed: int,
                 storm_s: float, worker_kills: int,
                 slot_failures: int) -> dict:
    pool = PilotPool(
        [PilotDescription(n_slots=4, name="r0", straggler_factor=1e9),
         PilotDescription(n_slots=4, name="r1", straggler_factor=1e9),
         PilotDescription(n_slots=4, name="r2", straggler_factor=1e9,
                          transport="proc")],
        heartbeat_timeout_s=0.8)
    scaler = PoolScaler(pool, ScalerConfig(
        template=PilotDescription(n_slots=4, name="spare",
                                  straggler_factor=1e9),
        min_pilots=3, max_pilots=4, interval_s=0.05,
        scale_up_wait_s=1e9, scale_down_idle_s=1e9)).start()
    tmgr = TaskManager(pool)
    inj = FaultInjector(pool, seed=seed)
    try:
        pol = RetryPolicy(max_retries=8, backoff_base_s=0.02,
                          backoff_max_s=0.2, quarantine_after=None)
        done_lock = threading.Lock()
        completions = []   # (uid, state, record) — a task recovered from a
                           # LOST pilot completes as a same-uid clone, so
                           # results must be read here, not off the object
                           # originally submitted
        def cb(rec):
            with done_lock:
                completions.append((rec.uid, rec.state, rec))

        t0 = time.monotonic()
        ckpts = [translate(_ckpt_body, (ckpt_steps, step_ms / 1000.0), {},
                           ResourceSpec(checkpointable=True),
                           retry_policy=pol)
                 for _ in range(ckpt_tasks)]
        tmgr.submit_bulk(ckpts, done_cb=cb)

        if chaos:
            # pin the crash to a pilot that provably holds a RUNNING
            # checkpointable task with a durable step — the re-adoption
            # path is then exercised every run, not only on lucky seeds
            victim = None
            deadline = time.monotonic() + 15
            while victim is None and time.monotonic() < deadline:
                for t in ckpts:
                    p = pool.by_uid(t.pilot_uid)
                    if (p is not None and p in pool.active()
                            and p.ckpt.step(t.ckpt_key) is not None):
                        victim = p
                        break
                time.sleep(0.01)
            assert victim is not None, "no checkpoint ever saved"
            inj.add_pilot_crash(0.05, pilot=victim)
            inj.storm(duration_s=storm_s, pilot_crashes=0,
                      worker_kills=worker_kills,
                      slot_failures=slot_failures, warmup_s=0.2)
            inj.start()

        burst = [translate(lambda i=i: time.sleep(task_ms / 1000.0) or i,
                           (), {}, retry_policy=pol)
                 for i in range(n_tasks)]
        tmgr.submit_bulk(burst, done_cb=cb)
        drained = tmgr.wait(timeout=240)
        makespan = time.monotonic() - t0
        inj.stop()
        assert drained, "workload never drained"

        total = n_tasks + ckpt_tasks
        uids = [u for u, _, _ in completions]
        states = [s for _, s, _ in completions]
        by_uid = {u: r for u, _, r in completions}
        ckpt_uids = {t.uid for t in ckpts}
        evs = pool.events()
        out = {
            "makespan_s": makespan,
            "tasks": total,
            "completed": len(completions),
            "unique": len(set(uids)),
            "done": sum(1 for s in states if s == TaskState.DONE),
            "pilot_lost": sum(1 for e in evs
                              if e["event"] == EVENTS.PILOT_LOST),
            "stolen_pilot_lost": sum(1 for e in evs
                                     if e["event"] == EVENTS.STOLEN
                                     and e.get("reason") == "pilot-lost"),
            "stolen_retry": sum(1 for e in evs if e["event"] == EVENTS.STOLEN
                                and e.get("reason") == "retry"),
            "replaced": sum(1 for d in scaler.decisions
                            if d["action"] == "replace_lost"),
            "ckpt_resumed": sum(
                1 for u in ckpt_uids
                if (r := by_uid.get(u)) is not None
                and r.state == TaskState.DONE and r.result["start"] > 0),
            "injected": list(inj.events),
        }
        return out
    finally:
        inj.stop()
        scaler.stop()
        tmgr = None
        pool.close()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=200,
                    help="short-task burst size")
    ap.add_argument("--task-ms", type=float, default=50.0)
    ap.add_argument("--ckpt-tasks", type=int, default=3,
                    help="long checkpointable step tasks")
    ap.add_argument("--ckpt-steps", type=int, default=12)
    ap.add_argument("--step-ms", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=7,
                    help="chaos schedule seed (deterministic storm)")
    ap.add_argument("--storm-s", type=float, default=1.5)
    ap.add_argument("--worker-kills", type=int, default=3)
    ap.add_argument("--slot-failures", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=3,
                    help="repeat each run, keep the best makespan "
                         "(container scheduling noise)")
    ap.add_argument("--max-degradation-ratio", type=float, default=0.0,
                    help="gate: chaos makespan / baseline makespan must "
                         "stay under this (0 = report only)")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent
                                         .parent / "BENCH_resilience.json"))
    args = ap.parse_args(argv)
    reps = max(1, args.repeats)

    def once(chaos):
        return run_workload(chaos, args.tasks, args.task_ms,
                            args.ckpt_tasks, args.ckpt_steps, args.step_ms,
                            args.seed, args.storm_s, args.worker_kills,
                            args.slot_failures)

    print("# baseline: fault-free")
    base = min((once(False) for _ in range(reps)),
               key=lambda r: r["makespan_s"])
    print(f"  makespan {base['makespan_s']:.3f}s, "
          f"{base['done']}/{base['tasks']} done")

    print("# chaos: pilot crash + worker kills + slot failures "
          f"(seed={args.seed})")
    storm = min((once(True) for _ in range(reps)),
                key=lambda r: r["makespan_s"])
    ratio = storm["makespan_s"] / base["makespan_s"]
    print(f"  makespan {storm['makespan_s']:.3f}s "
          f"({ratio:.2f}x baseline), {storm['done']}/{storm['tasks']} done")
    print(f"  pilot_lost={storm['pilot_lost']} "
          f"rerouted={storm['stolen_pilot_lost']} "
          f"retry_reroutes={storm['stolen_retry']} "
          f"replaced={storm['replaced']} "
          f"ckpt_resumed={storm['ckpt_resumed']}")

    results = {
        "config": dict(vars(args)),
        "baseline": base,
        "chaos": storm,
        "degradation_ratio": ratio,
    }
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=2))
    print(f"wrote {out}")

    for run, label in ((base, "baseline"), (storm, "chaos")):
        if (run["done"] != run["tasks"] or run["completed"] != run["tasks"]
                or run["unique"] != run["tasks"]):
            raise SystemExit(
                f"REGRESSION: {label} run lost or duplicated tasks "
                f"(done={run['done']}, completed={run['completed']}, "
                f"unique={run['unique']}, expected={run['tasks']})")
    if storm["pilot_lost"] < 1 or storm["stolen_pilot_lost"] < 1:
        raise SystemExit(
            "REGRESSION: the injected crash produced no PILOT_LOST "
            f"recovery (pilot_lost={storm['pilot_lost']}, "
            f"rerouted={storm['stolen_pilot_lost']})")
    if storm["ckpt_resumed"] < 1:
        raise SystemExit(
            "REGRESSION: no checkpointable task resumed from its snapshot "
            "after the pilot loss (ckpt_resumed=0)")
    if storm["replaced"] < 1:
        raise SystemExit(
            "REGRESSION: the scaler never replaced the lost pilot")
    if (args.max_degradation_ratio
            and ratio > args.max_degradation_ratio):
        raise SystemExit(
            f"REGRESSION: chaos makespan degraded {ratio:.2f}x over "
            f"baseline (> {args.max_degradation_ratio:.2f}x)")
    return results


if __name__ == "__main__":
    main()
