"""Experiment 1 (paper Table II / Fig. 4): SPMD-function-executor scaling.

Weak and strong scaling of the MPI-function-executor analog: a homogeneous
workload of no-op SPMD functions, each spanning ``ranks_per_task`` slots
(the paper uses 2-node tasks = 256/112 ranks; we use multi-slot sub-mesh
tasks).  Metrics exactly as the paper defines them:

  TPT — total processing time: last task end - first task start (the time
        the executor kept resources busy);
  TS  — throughput = tasks / TPT.

Two platform profiles mirror Expanse (2..32 "nodes") and Frontera
(8..512 "nodes"), with nodes -> slot blocks.  ``--no-cache`` reproduces the
paper's cold-communicator cost (every task pays trace+compile, the ibrun /
MPI_Comm_split analog); the default cached mode is the paper's own proposed
fix, measured.
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax
import jax.numpy as jnp

from repro.core import (PilotDescription, RPEXExecutor, ResourceSpec,
                        TaskState, translate)
from repro.compat import shard_map


def _noop_spmd(mesh, x):
    # "no-op" MPI function: one tiny collective to force real dispatch
    from jax.sharding import PartitionSpec as P
    return shard_map(lambda a: jax.lax.psum(a, "data"),
                         mesh=mesh, in_specs=P(), out_specs=P())(x)


_noop_spmd.__app_kind__ = "spmd"      # translated as an SPMD task body


def run_scale(n_slots: int, n_tasks: int, ranks_per_task: int,
              cache: bool, repeats: int = 3):
    tpts, tss = [], []
    for _ in range(repeats):
        rpex = RPEXExecutor(PilotDescription(
            n_slots=n_slots, cache_executables=cache,
            max_workers=max(32, n_slots)))
        tm = rpex.tmgr
        tasks = [translate(_noop_spmd, (jnp.float32(i),), {},
                           ResourceSpec(slots=ranks_per_task))
                 for i in range(n_tasks)]
        t0 = time.monotonic()
        tm.submit_bulk(tasks)
        ok = tm.wait(timeout=600)
        assert ok, "timeout"
        starts = [t.timestamps.get("SCHEDULED", t.timestamps["TRANSLATED"])
                  for t in tasks]
        ends = [t.timestamps[t.state.value] for t in tasks]
        assert all(t.state == TaskState.DONE for t in tasks), \
            [t.state for t in tasks if t.state != TaskState.DONE][:3]
        tpt = max(ends) - min(starts)
        tpts.append(tpt)
        tss.append(n_tasks / tpt if tpt > 0 else float("inf"))
        rpex.shutdown()
    return (statistics.mean(tpts), statistics.stdev(tpts) if repeats > 1 else 0.0,
            statistics.mean(tss), statistics.stdev(tss) if repeats > 1 else 0.0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", choices=["expanse", "frontera", "quick"],
                    default="quick")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--tasks-per-slot", type=int, default=4)
    ap.add_argument("--strong-tasks", type=int, default=256)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)

    profiles = {
        # nodes -> slots (node = 1 slot block here); ranks/task like the
        # paper's 2-node tasks
        "expanse": dict(nodes=[2, 4, 8, 16, 32], ranks=2),
        "frontera": dict(nodes=[8, 16, 32, 64, 128, 256, 512], ranks=2),
        "quick": dict(nodes=[2, 4, 8, 16], ranks=2),
    }
    prof = profiles[args.profile]
    cache = not args.no_cache
    rows = []
    print("system,scaling,nodes,tasks,tpt_s,tpt_sd,ts_tasks_per_s,ts_sd")
    for scaling in ("strong", "weak"):
        for n in prof["nodes"]:
            n_tasks = (args.strong_tasks if scaling == "strong"
                       else n * args.tasks_per_slot)
            tpt, tpt_sd, ts, ts_sd = run_scale(
                n, n_tasks, prof["ranks"], cache, args.repeats)
            row = (args.profile, scaling, n, n_tasks, round(tpt, 4),
                   round(tpt_sd, 4), round(ts, 2), round(ts_sd, 2))
            rows.append(row)
            print(",".join(str(x) for x in row), flush=True)
    return rows


if __name__ == "__main__":
    main()
