"""Experiment 3: runtime overhead of the event-driven agent loop.

Measures per-task runtime overhead on 1k no-op tasks in four settings —
stream vs bulk submission, 1 vs 2 pilots — and compares the event-driven
runtime against a faithful reimplementation of the pre-refactor polling
agent (sleep-poll scheduling loop with ``poll_interval``, thread-per-task
execution).  The paper's throughput metrics (TPT/TS) are reported alongside
stream latency, which is where polling hurts: each stream submission used
to wait out a poll tick before it could even be scheduled.

Emits ``BENCH_throughput.json`` with every measurement plus the headline
``stream_speedup_vs_polling`` factor (acceptance gate: >= 5x) and a
``gil_bound`` diagnostic — bulk wall-time over total calibrated
single-threaded CPU-burn work (~1.0 = fully GIL-serialized, ~1/cores =
parallel) — so the thread/process crossover exp8 measures is visible in
the cross-PR trajectory.
"""
from __future__ import annotations

import argparse
import json
import queue
import threading
import time
from pathlib import Path

from repro.core import (PilotDescription, ResourceSpec, RPEXExecutor,
                        SlotScheduler, translate)


def _noop(x):
    return x


def _burn(iters):
    """Fixed-work CPU burn (NOT wall-clock bounded: a time-based burn
    under GIL contention does less work per task but the same wall time,
    which would hide the contention this diagnostic exists to show)."""
    x = 0
    for i in range(iters):
        x += i * i
    return x


def _calibrate_burn(target_s: float = 0.01) -> int:
    """Iterations of _burn worth ~target_s of single-threaded work."""
    iters = 50_000
    while True:
        t0 = time.perf_counter()
        _burn(iters)
        dt = time.perf_counter() - t0
        if dt >= target_s / 4:
            return max(1, int(iters * target_s / dt))
        iters *= 2


# ---------------------- pre-refactor polling baseline ---------------------- #

class PollingBaseline:
    """The old runtime's control flow, kept for comparison: a scheduling
    loop that sleeps ``poll_interval`` whenever a pass makes no progress,
    and a fresh OS thread per task."""

    def __init__(self, n_slots: int, poll_interval: float = 0.002):
        self.scheduler = SlotScheduler(n_slots)
        self.poll = poll_interval
        self.inbox: "queue.Queue" = queue.Queue()
        self._wait = []
        self._done = {}
        self._cv = threading.Condition()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, uid, fn, args):
        self.inbox.put((uid, fn, args))

    def _loop(self):
        while not self._stop.is_set():
            moved = False
            try:
                while True:
                    self._wait.append(self.inbox.get_nowait())
                    moved = True
            except queue.Empty:
                pass
            launched = False
            still = []
            for uid, fn, args in self._wait:
                slots = self.scheduler.allocate(uid, 1)
                if slots is None:
                    still.append((uid, fn, args))
                    continue
                threading.Thread(target=self._run, args=(uid, fn, args),
                                 daemon=True).start()
                launched = True
            self._wait = still
            if not moved and not launched:
                time.sleep(self.poll)

    def _run(self, uid, fn, args):
        result = fn(*args)
        self.scheduler.release(uid)
        with self._cv:
            self._done[uid] = result
            self._cv.notify_all()

    def wait(self, uid, timeout=30.0):
        with self._cv:
            self._cv.wait_for(lambda: uid in self._done, timeout)
            return self._done.pop(uid)

    def wait_all(self, uids, timeout=60.0):
        with self._cv:
            self._cv.wait_for(lambda: all(u in self._done for u in uids),
                              timeout)

    def close(self):
        self._stop.set()


# ------------------------------ measurements ------------------------------ #

def bench_polling_stream(n_tasks: int, n_slots: int, poll: float) -> float:
    """Mean submit->complete latency per task, sequential stream."""
    base = PollingBaseline(n_slots, poll)
    try:
        t0 = time.monotonic()
        for i in range(n_tasks):
            base.submit(f"t{i}", _noop, (i,))
            base.wait(f"t{i}")
        return (time.monotonic() - t0) / n_tasks
    finally:
        base.close()


def bench_polling_bulk(n_tasks: int, n_slots: int, poll: float) -> float:
    base = PollingBaseline(n_slots, poll)
    try:
        t0 = time.monotonic()
        for i in range(n_tasks):
            base.submit(f"t{i}", _noop, (i,))
        base.wait_all([f"t{i}" for i in range(n_tasks)])
        return (time.monotonic() - t0) / n_tasks
    finally:
        base.close()


def _mk_rpex(n_pilots: int, n_slots: int) -> RPEXExecutor:
    per = max(1, n_slots // n_pilots)
    return RPEXExecutor([PilotDescription(n_slots=per, name=f"p{i}")
                         for i in range(n_pilots)])


def bench_event_stream(n_tasks: int, n_slots: int, n_pilots: int) -> float:
    rpex = _mk_rpex(n_pilots, n_slots)
    try:
        t0 = time.monotonic()
        for i in range(n_tasks):
            t = translate(_noop, (i,), {}, ResourceSpec(slots=1))
            rpex.tmgr.submit(t)
            rpex.tmgr.wait(uids=[t.uid], timeout=30)
        return (time.monotonic() - t0) / n_tasks
    finally:
        rpex.shutdown()


def bench_event_bulk(n_tasks: int, n_slots: int, n_pilots: int) -> float:
    rpex = _mk_rpex(n_pilots, n_slots)
    try:
        tasks = [translate(_noop, (i,), {}, ResourceSpec(slots=1))
                 for i in range(n_tasks)]
        t0 = time.monotonic()
        rpex.tmgr.submit_bulk(tasks)
        ok = rpex.tmgr.wait(timeout=120)
        assert ok, "bulk run timed out"
        return (time.monotonic() - t0) / n_tasks
    finally:
        rpex.shutdown()


def bench_gil_bound(n_tasks: int, n_slots: int, burn_s: float):
    """The thread/process crossover diagnostic: bulk wall-time over the
    total *calibrated* single-threaded work (n_tasks x burn_s).  With k
    truly parallel executors the ratio approaches 1/k; GIL-bound thread
    workers hold it at ~1.0 regardless of slot count (the exp3 ceiling
    ROADMAP's open item 3 and docs/processes.md discuss).  Calibrated
    work is the right denominator: per-task RUNNING->DONE spans stretch
    under GIL contention by exactly the factor being measured, so a
    span-based ratio reads ~1/slots whether or not the GIL serialized
    anything."""
    iters = _calibrate_burn(burn_s)
    rpex = _mk_rpex(1, n_slots)
    try:
        tasks = [translate(_burn, (iters,), {}, ResourceSpec(slots=1))
                 for _ in range(n_tasks)]
        t0 = time.monotonic()
        rpex.tmgr.submit_bulk(tasks)
        ok = rpex.tmgr.wait(timeout=120)
        assert ok, "gil-bound probe timed out"
        wall = time.monotonic() - t0
        return {"wall_s": wall, "work_s": n_tasks * burn_s,
                "gil_bound": wall / (n_tasks * burn_s)}
    finally:
        rpex.shutdown()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=1000)
    ap.add_argument("--stream-tasks", type=int, default=150,
                    help="stream latency sample size (polling pays ~1 poll "
                         "tick per task, so full 1k would just take longer)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--poll-interval", type=float, default=0.002)
    ap.add_argument("--repeats", type=int, default=5,
                    help="repeat each stream measurement, keep the best: "
                         "stream latency is ~3 thread handoffs, so single "
                         "runs swing 2x with container scheduling noise; "
                         "min-of-N estimates the floor for both runtimes")
    ap.add_argument("--gil-tasks", type=int, default=32,
                    help="CPU-burn tasks for the gil_bound diagnostic")
    ap.add_argument("--gil-burn-s", type=float, default=0.01,
                    help="single-threaded CPU work per gil_bound task")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit nonzero if stream speedup vs the polling "
                         "baseline falls below this (0 = report only); CI "
                         "uses a conservative value to catch regressions "
                         "without flaking on scheduler noise")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent.parent
                                         / "BENCH_throughput.json"))
    args = ap.parse_args(argv)

    results = {"config": {"tasks": args.tasks,
                          "stream_tasks": args.stream_tasks,
                          "slots": args.slots,
                          "poll_interval": args.poll_interval,
                          "repeats": args.repeats}}

    def best(fn, *a):
        return min(fn(*a) for _ in range(max(1, args.repeats)))

    print("# event-driven runtime")
    for n_pilots in (1, 2):
        ev_stream = best(bench_event_stream, args.stream_tasks, args.slots,
                         n_pilots)
        ev_bulk = best(bench_event_bulk, args.tasks, args.slots, n_pilots)
        results[f"event_{n_pilots}p"] = {
            "stream_us_per_task": ev_stream * 1e6,
            "bulk_us_per_task": ev_bulk * 1e6,
            "bulk_tasks_per_s": 1.0 / ev_bulk,
        }
        print(f"  {n_pilots} pilot(s): stream {ev_stream * 1e6:9.1f} us/task"
              f"   bulk {ev_bulk * 1e6:9.1f} us/task"
              f"   ({1.0 / ev_bulk:,.0f} tasks/s)")

    print("# polling baseline (pre-refactor control flow)")
    poll_stream = best(bench_polling_stream, args.stream_tasks, args.slots,
                       args.poll_interval)
    poll_bulk = best(bench_polling_bulk, args.tasks, args.slots,
                     args.poll_interval)
    results["polling"] = {"stream_us_per_task": poll_stream * 1e6,
                          "bulk_us_per_task": poll_bulk * 1e6}
    print(f"  stream: {poll_stream * 1e6:9.1f} us/task")
    print(f"  bulk:   {poll_bulk * 1e6:9.1f} us/task")

    speedup = (results["polling"]["stream_us_per_task"]
               / results["event_1p"]["stream_us_per_task"])
    results["stream_speedup_vs_polling"] = speedup
    print(f"# stream per-task overhead: event-driven is {speedup:.1f}x "
          f"lower than poll_interval={args.poll_interval}")

    gb = bench_gil_bound(args.gil_tasks, args.slots, args.gil_burn_s)
    results["gil_bound"] = gb
    print(f"# gil_bound diagnostic (inproc, CPU-burn bulk): "
          f"{gb['gil_bound']:.2f} "
          f"(1.0 = fully serialized; ~1/cores = parallel — see exp8 for "
          f"the proc-transport crossover)")

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=2))
    print(f"wrote {out}")
    if args.min_speedup and speedup < args.min_speedup:
        raise SystemExit(
            f"REGRESSION: stream speedup {speedup:.1f}x < required "
            f"{args.min_speedup:.1f}x")
    return results


if __name__ == "__main__":
    main()
