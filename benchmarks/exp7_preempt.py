"""Experiment 7: checkpoint-based straggler recovery + preempt-and-migrate.

Phase 1 (straggler recovery — replicas resume from checkpoints): a long
stepwise task on a seeded pilot turns straggler mid-run (its per-step time
jumps ~50x, the slow-node model).  The agent's p95 deadline fires a
replica either way; the measurement is what the replica *does*:

  * recompute-from-scratch (checkpointable=False, the pre-PR behavior):
    the replica reruns every step from 0;
  * checkpoint-resume: the replica restores the leader's latest saved
    step and only runs the remainder.

The gate is the ratio of straggler-task makespans (submit -> first
finisher): resume must be >= --min-recovery-ratio (CI: 1.5) faster.

Phase 2 (preempt-and-migrate vs queued-only stealing): a two-pilot pool
with a long-task skew that queued-only stealing cannot fix — a long
RUNNING checkpointable SPMD-kind task occupies the generalist pilot while
*sticky* (hence unstealable) short tasks queue behind it; the device
pilot idles.  With preemption enabled the idle pilot preempts the long
task at its next checkpoint boundary and resumes it from the saved step
(STOLEN reason="preempt"), freeing the generalist for its pinned backlog.
Gate: makespan improvement >= --min-preempt-ratio, plus the migration
evidence itself (a STOLEN-after-preempt event and a resumed step > 0).

Emits ``BENCH_preempt.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import itertools
import json
import threading
import time
from pathlib import Path

from repro.core import (EVENTS, PilotDescription, PilotPool, PoolScaler,
                        ResourceSpec, ScalerConfig, TaskState, translate)


# --------------------------- phase 1: recovery --------------------------- #

def _straggler_body(counter, lock, n, fast_s, slow_s, slow_after,
                    ckpt=None):
    """First invocation (the leader) turns slow at ``slow_after``;
    replicas run at the healthy rate.  With a ckpt context each step is
    checkpointed, so a replica resumes instead of recomputing."""
    with lock:
        me = next(counter)
    start = 0
    if ckpt is not None:
        got = ckpt.restore()
        if got is not None:
            start = got[0] + 1
    for step in range(start, n):
        time.sleep(slow_s if (me == 0 and step >= slow_after) else fast_s)
        if ckpt is not None:
            ckpt.save(step, step)
    return {"who": me, "start": start}


def run_recovery(checkpointed: bool, n_steps: int, fast_ms: float,
                 slow_s: float, slow_after: int, seed_ms: float) -> dict:
    from repro.core import Pilot
    pilot = Pilot(PilotDescription(n_slots=2, straggler_factor=3.0,
                                   name="rec"))
    try:
        # seed the duration window: the siblings' durations set the p95
        # deadline, sized so it fires about when the leader's healthy
        # phase ends — i.e. with most of its steps already checkpointed
        seeds = [translate(lambda: time.sleep(seed_ms / 1000.0), (), {})
                 for _ in range(5)]
        for s in seeds:
            pilot.agent.submit(s)
        assert pilot.agent.wait_idle(timeout=10)

        lock = threading.Lock()
        t = translate(
            _straggler_body,
            (itertools.count(), lock, n_steps, fast_ms / 1000.0, slow_s,
             slow_after), {},
            ResourceSpec(checkpointable=checkpointed))
        res = []
        t0 = time.monotonic()
        pilot.agent.submit(t, done_cb=res.append)
        deadline = time.monotonic() + 120
        while not res and time.monotonic() < deadline:
            time.sleep(0.005)
        makespan = time.monotonic() - t0
        assert res, "straggler task never completed"
        done = res[0]
        assert done.state == TaskState.DONE
        return {"makespan_s": makespan,
                "winner": "replica" if done.result["who"] > 0 else "leader",
                "resumed_at": done.result["start"]}
    finally:
        pilot.close()


# ----------------------- phase 2: preempt vs queued ----------------------- #

def _resumable_body(n, step_s, ckpt=None):
    start = 0
    if ckpt is not None:
        got = ckpt.restore()
        if got is not None:
            start = got[0] + 1
    for step in range(start, n):
        time.sleep(step_s)
        if ckpt is not None:
            ckpt.save(step, step)
    return {"start": start}


def run_skew(preempt: bool, long_steps: int, step_ms: float,
             n_short: int, short_ms: float) -> dict:
    """Generalist pilot p0 runs the long SPMD-kind task on both slots
    with sticky python shorts queued behind it; device pilot p1 accepts
    only the long task's kind.  Queued-only stealing moves nothing (the
    backlog is sticky, the long task is RUNNING); preemption re-binds
    the long task mid-flight."""
    pool = PilotPool([PilotDescription(n_slots=2, name="gen",
                                       straggler_factor=1e9),
                      PilotDescription(n_slots=2, kinds=("spmd", "device"),
                                       name="dev", straggler_factor=1e9)],
                     preempt=preempt)
    scaler = PoolScaler(pool, ScalerConfig(
        min_pilots=2, max_pilots=2, interval_s=0.02,
        scale_up_wait_s=1e9, scale_down_idle_s=1e9)).start()
    try:
        gen, dev = pool.pilots
        lt = translate(_resumable_body, (long_steps, step_ms / 1000.0), {},
                       ResourceSpec(slots=2, checkpointable=True,
                                    res_kind="device"))
        lt.pilot_uid = gen.uid
        lres, sres = [], []
        t0 = time.monotonic()
        gen.agent.submit(lt, done_cb=lres.append)
        for _ in range(n_short):
            s = translate(lambda: time.sleep(short_ms / 1000.0), (), {},
                          ResourceSpec(sticky=True))
            s.pilot_uid = gen.uid
            gen.agent.submit(s, done_cb=sres.append)
        deadline = time.monotonic() + 120
        while ((not lres or len(sres) < n_short)
               and time.monotonic() < deadline):
            time.sleep(0.005)
        makespan = time.monotonic() - t0
        assert lres and len(sres) == n_short, "skew workload timed out"
        stolen = [e for e in pool.events() if e["event"] == EVENTS.STOLEN]
        return {"makespan_s": makespan,
                "long_final_pilot": ("dev" if lt.pilot_uid == dev.uid
                                     else "gen"),
                "resumed_at": lres[0].result["start"],
                "stolen_preempt": sum(1 for e in stolen
                                      if e.get("reason") == "preempt")}
    finally:
        scaler.stop()
        pool.close()


# --------------------------------- main ----------------------------------- #

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12,
                    help="straggler task steps (phase 1)")
    ap.add_argument("--fast-ms", type=float, default=40.0)
    ap.add_argument("--slow-s", type=float, default=2.0,
                    help="leader per-step time once it straggles")
    ap.add_argument("--slow-after", type=int, default=9,
                    help="step index at which the leader turns slow")
    ap.add_argument("--seed-ms", type=float, default=120.0,
                    help="sibling-task duration seeding the p95 deadline "
                         "(deadline = 3x p95; default fires as the "
                         "leader's healthy phase ends)")
    ap.add_argument("--long-steps", type=int, default=16,
                    help="preempt-phase long-task steps")
    ap.add_argument("--step-ms", type=float, default=60.0)
    ap.add_argument("--shorts", type=int, default=8)
    ap.add_argument("--short-ms", type=float, default=100.0)
    ap.add_argument("--repeats", type=int, default=3,
                    help="repeat each measurement, keep the best per mode "
                         "(container scheduling noise)")
    ap.add_argument("--min-recovery-ratio", type=float, default=0.0,
                    help="gate: checkpoint-resume speedup over "
                         "recompute-from-scratch (0 = report only)")
    ap.add_argument("--min-preempt-ratio", type=float, default=0.0,
                    help="gate: preempt-and-migrate speedup over "
                         "queued-only stealing (0 = report only)")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent
                                         .parent / "BENCH_preempt.json"))
    args = ap.parse_args(argv)
    reps = max(1, args.repeats)

    print("# phase 1: straggler recovery — replica from checkpoint vs "
          "recompute")
    scratch = min((run_recovery(False, args.steps, args.fast_ms,
                                args.slow_s, args.slow_after, args.seed_ms)
                   for _ in range(reps)), key=lambda r: r["makespan_s"])
    resume = min((run_recovery(True, args.steps, args.fast_ms,
                               args.slow_s, args.slow_after, args.seed_ms)
                  for _ in range(reps)), key=lambda r: r["makespan_s"])
    recovery_ratio = scratch["makespan_s"] / resume["makespan_s"]
    print(f"  recompute-from-scratch: {scratch['makespan_s']:.3f}s "
          f"(winner={scratch['winner']}, start={scratch['resumed_at']})")
    print(f"  checkpoint-resume     : {resume['makespan_s']:.3f}s "
          f"(winner={resume['winner']}, start={resume['resumed_at']})")
    print(f"  recovery speedup: {recovery_ratio:.2f}x")

    print("# phase 2: long-task skew — preempt-and-migrate vs queued-only "
          "stealing")
    queued = min((run_skew(False, args.long_steps, args.step_ms,
                           args.shorts, args.short_ms)
                  for _ in range(reps)), key=lambda r: r["makespan_s"])
    pre = min((run_skew(True, args.long_steps, args.step_ms,
                        args.shorts, args.short_ms)
               for _ in range(reps)), key=lambda r: r["makespan_s"])
    preempt_ratio = queued["makespan_s"] / pre["makespan_s"]
    print(f"  queued-only stealing : {queued['makespan_s']:.3f}s "
          f"(long ran on {queued['long_final_pilot']})")
    print(f"  preempt-and-migrate  : {pre['makespan_s']:.3f}s "
          f"(long migrated to {pre['long_final_pilot']}, resumed at "
          f"step {pre['resumed_at']}, "
          f"preempt-steals={pre['stolen_preempt']})")
    print(f"  makespan speedup: {preempt_ratio:.2f}x")

    results = {
        "config": dict(vars(args)),
        "recovery": {"scratch": scratch, "resume": resume,
                     "ratio": recovery_ratio},
        "preempt": {"queued_only": queued, "preempt": pre,
                    "ratio": preempt_ratio},
    }
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=2))
    print(f"wrote {out}")

    if resume["resumed_at"] <= 0:
        raise SystemExit("REGRESSION: the replica did not resume from a "
                         "checkpoint (resumed_at == 0)")
    if pre["stolen_preempt"] < 1 or pre["long_final_pilot"] != "dev" \
            or pre["resumed_at"] <= 0:
        raise SystemExit(
            "REGRESSION: no RUNNING task migrated pilots via preemption "
            f"(stolen_preempt={pre['stolen_preempt']}, "
            f"final={pre['long_final_pilot']}, "
            f"resumed_at={pre['resumed_at']})")
    if args.min_recovery_ratio and recovery_ratio < args.min_recovery_ratio:
        raise SystemExit(
            f"REGRESSION: checkpoint-recovery speedup {recovery_ratio:.2f}x "
            f"< required {args.min_recovery_ratio:.2f}x")
    if args.min_preempt_ratio and preempt_ratio < args.min_preempt_ratio:
        raise SystemExit(
            f"REGRESSION: preempt-and-migrate speedup {preempt_ratio:.2f}x "
            f"< required {args.min_preempt_ratio:.2f}x")
    return results


if __name__ == "__main__":
    main()
