"""Experiment 4: inter-pilot load balance — work stealing + elastic pool.

Phase 1 (stealing vs. PR-1 static routing): a skewed bulk workload on two
identical pilots.  Bulk routing is least-loaded by *demand*, so a batch of
interleaved long/short tasks splits evenly by count — but all the long
tasks land on one pilot and all the short ones on the other.  Under PR-1
static routing the short-task pilot finishes early and idles while the
long-task pilot grinds through its queue; with stealing enabled the idle
pilot migrates queued long tasks over and the makespan drops toward the
balanced optimum.

Phase 2 (elastic autoscale cycle): one seed pilot plus a PoolScaler; a
burst overloads the seed, the scaler spawns a pilot from the template
(PILOT_START), stealing moves the backlog (STOLEN), and after the burst
the spawned pilot drains and retires (PILOT_RETIRE) — the full steal/scale
cycle is asserted from PilotPool.events().

Emits ``BENCH_balance.json``; ``--min-speedup`` turns the phase-1 makespan
ratio into a regression gate.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.core import (EVENTS, PilotDescription, RPEXExecutor,
                        ScalerConfig, translate)


def _sleeper(dur):
    time.sleep(dur)
    return dur


def skewed_durations(n_tasks: int, long_s: float, short_s: float):
    """Alternating long/short: bulk routing alternates pilots on equal
    load, so evens (long) pile onto pilot 0 and odds (short) onto 1."""
    return [long_s if i % 2 == 0 else short_s for i in range(n_tasks)]


def run_balance(n_tasks: int, long_s: float, short_s: float,
                steal: bool) -> dict:
    rpex = RPEXExecutor([PilotDescription(n_slots=2, name="p0"),
                         PilotDescription(n_slots=2, name="p1")],
                        steal=steal)
    try:
        tasks = [translate(_sleeper, (d,), {})
                 for d in skewed_durations(n_tasks, long_s, short_s)]
        t0 = time.monotonic()
        rpex.tmgr.submit_bulk(tasks)
        ok = rpex.tmgr.wait(timeout=120)
        makespan = time.monotonic() - t0
        assert ok, "workload timed out"
        events = rpex.pool.events()
        stolen = sum(1 for e in events if e["event"] == EVENTS.STOLEN)
        per_pilot = {}
        for t in tasks:
            per_pilot[t.pilot_uid] = per_pilot.get(t.pilot_uid, 0) + 1
        return {"makespan_s": makespan, "stolen": stolen,
                "tasks_per_pilot": per_pilot}
    finally:
        rpex.shutdown()


def run_autoscale(n_tasks: int, task_s: float) -> dict:
    cfg = ScalerConfig(template=PilotDescription(n_slots=2, name="elastic"),
                       min_pilots=1, max_pilots=3,
                       scale_up_wait_s=0.1, scale_down_idle_s=0.4,
                       spawn_cooldown_s=0.2, interval_s=0.05)
    rpex = RPEXExecutor(PilotDescription(n_slots=2, name="seed"), scaler=cfg)
    try:
        tasks = [translate(_sleeper, (task_s,), {}) for _ in range(n_tasks)]
        t0 = time.monotonic()
        rpex.tmgr.submit_bulk(tasks)
        ok = rpex.tmgr.wait(timeout=120)
        makespan = time.monotonic() - t0
        assert ok, "autoscale workload timed out"
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:      # wait out the idle retire
            kinds = {e["event"] for e in rpex.pool.events()}
            if EVENTS.PILOT_RETIRE in kinds:
                break
            time.sleep(0.05)
        events = rpex.pool.events()
        kinds = {e["event"] for e in events}
        cycle_ok = {EVENTS.PILOT_START, EVENTS.STOLEN,
                    EVENTS.PILOT_RETIRE} <= kinds
        return {"makespan_s": makespan, "cycle_ok": cycle_ok,
                "n_spawned": sum(1 for d in rpex.scaler.decisions
                                 if d["action"] == "scale_up"),
                "n_retired": sum(1 for d in rpex.scaler.decisions
                                 if d["action"] == "retire"),
                "stolen": sum(1 for e in events if e["event"] == EVENTS.STOLEN),
                "utilization_keys": len(rpex.utilization())}
    finally:
        rpex.shutdown()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=40)
    ap.add_argument("--long-ms", type=float, default=80.0)
    ap.add_argument("--short-ms", type=float, default=4.0)
    ap.add_argument("--repeats", type=int, default=3,
                    help="repeat each phase-1 measurement, keep the best "
                         "makespan per mode (container scheduling noise)")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit nonzero if the stealing makespan speedup "
                         "over static routing falls below this "
                         "(0 = report only)")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent.parent
                                         / "BENCH_balance.json"))
    args = ap.parse_args(argv)

    long_s, short_s = args.long_ms / 1000.0, args.short_ms / 1000.0
    results = {"config": {"tasks": args.tasks, "long_ms": args.long_ms,
                          "short_ms": args.short_ms,
                          "repeats": args.repeats}}

    print("# phase 1: skewed bulk workload, 2 pilots x 2 slots")
    static = min((run_balance(args.tasks, long_s, short_s, steal=False)
                  for _ in range(max(1, args.repeats))),
                 key=lambda r: r["makespan_s"])
    steal = min((run_balance(args.tasks, long_s, short_s, steal=True)
                 for _ in range(max(1, args.repeats))),
                key=lambda r: r["makespan_s"])
    speedup = static["makespan_s"] / steal["makespan_s"]
    results["static"] = static
    results["steal"] = steal
    results["makespan_speedup"] = speedup
    print(f"  static routing : {static['makespan_s']:.3f}s "
          f"(stolen={static['stolen']})")
    print(f"  work stealing  : {steal['makespan_s']:.3f}s "
          f"(stolen={steal['stolen']})")
    print(f"  makespan speedup: {speedup:.2f}x")

    print("# phase 2: elastic autoscale cycle (1 seed pilot + PoolScaler)")
    scale = run_autoscale(12, 0.15)
    results["autoscale"] = scale
    print(f"  makespan {scale['makespan_s']:.3f}s, spawned="
          f"{scale['n_spawned']}, retired={scale['n_retired']}, "
          f"stolen={scale['stolen']}, cycle_ok={scale['cycle_ok']}")

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=2))
    print(f"wrote {out}")

    if not scale["cycle_ok"]:
        raise SystemExit("REGRESSION: no full steal/scale cycle "
                         "(PILOT_START/STOLEN/PILOT_RETIRE) in events")
    if args.min_speedup and speedup < args.min_speedup:
        raise SystemExit(
            f"REGRESSION: stealing makespan speedup {speedup:.2f}x < "
            f"required {args.min_speedup:.2f}x")
    return results


if __name__ == "__main__":
    main()
