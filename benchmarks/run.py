"""Benchmark entry point — one harness per paper table/figure.

  exp1   -> Table II / Fig. 4  (SPMD executor weak/strong scaling)
  exp1nc -> §V-A cold-communicator ablation (--no-cache)
  exp2   -> Table III / Fig. 5 (Colmena + IWP TTX and overheads)
  bulk   -> paper's future-work bulk-submission mode, measured
  roofline -> §Roofline table from the dry-run artifacts (assignment)

The gated runtime benchmarks (exp3 throughput, exp4 balance, exp5 state
path, exp6 locality, exp7 preemption, exp8 proc pool) each emit a
canonical ``BENCH_*.json`` at the repo root so the perf trajectory is
tracked across PRs; ``--bench-summary`` aggregates whatever artifacts
are present into one table without re-running anything.

Prints ``name,us_per_call,derived`` CSV summary lines at the end.
"""
from __future__ import annotations

import contextlib
import io
import json
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# headline metric per canonical artifact: (json path, label, format)
_BENCH_HEADLINES = {
    "BENCH_throughput.json": [
        (("event_1p", "stream_us_per_task"), "stream us/task", "{:.1f}"),
        (("event_1p", "bulk_tasks_per_s"), "bulk tasks/s", "{:,.0f}"),
        (("stream_speedup_vs_polling",), "vs polling", "{:.1f}x"),
    ],
    "BENCH_balance.json": [
        (("steal", "makespan_s"), "steal makespan s", "{:.2f}"),
        (("makespan_speedup",), "vs static", "{:.2f}x"),
    ],
    "BENCH_statepath.json": [
        (("record", "write_behind_us_per_task"), "journal us/task", "{:.1f}"),
        (("record", "speedup"), "vs sync", "{:.1f}x"),
        (("lookup", "speedup"), "lookup vs scan", "{:.0f}x"),
        (("fanin", "speedup"), "fan-in vs PR-2", "{:.1f}x"),
    ],
    "BENCH_locality.json": [
        (("locality", "hops_total"), "locality hops", "{:d}"),
        (("least_loaded", "hops_total"), "least-loaded hops", "{:d}"),
        (("hop_ratio",), "hop reduction", "{:.1f}x"),
        (("bytes_moved_ratio",), "bytes moved", "{:.1f}x"),
        (("makespan_ratio",), "makespan ratio", "{:.2f}"),
    ],
    "BENCH_dataplane.json": [
        (("delivery", "ratio"), "delivery overhead", "{:.1f}x"),
        (("delivery", "on", "overhead_ms_per_edge"), "dataplane ms/edge",
         "{:.2f}"),
        (("delivery", "off", "overhead_ms_per_edge"), "pickled ms/edge",
         "{:.2f}"),
        (("placement", "byte_follows_largest"), "byte-affinity routes",
         "{}"),
        (("placement", "uid_misroutes"), "uid misroutes", "{}"),
    ],
    "BENCH_preempt.json": [
        (("recovery", "ratio"), "ckpt recovery", "{:.2f}x"),
        (("recovery", "resume", "resumed_at"), "replica resumed@", "{:d}"),
        (("preempt", "ratio"), "preempt vs queued", "{:.2f}x"),
        (("preempt", "preempt", "stolen_preempt"), "preempt steals", "{:d}"),
    ],
    "BENCH_procpool.json": [
        (("proc_speedup_cpu",), "proc CPU speedup", "{:.2f}x"),
        (("cpu_burn", "inproc", "gil_bound"), "inproc gil_bound", "{:.2f}"),
        (("cpu_burn", "proc", "gil_bound"), "proc gil_bound", "{:.2f}"),
        (("config", "cores"), "cores", "{:d}"),
    ],
    "BENCH_lockorder.json": [
        (("edge_count",), "lock-order edges", "{:d}"),
        (("locks",), "locks seen", "{:d}"),
        (("max_hold_ms_overall",), "max hold ms", "{:.1f}"),
        (("threads",), "threads", "{:d}"),
    ],
    "BENCH_resilience.json": [
        (("degradation_ratio",), "chaos degradation", "{:.2f}x"),
        (("chaos", "pilot_lost"), "pilots lost", "{:d}"),
        (("chaos", "ckpt_resumed"), "ckpt resumes", "{:d}"),
        (("chaos", "replaced"), "replaced", "{:d}"),
    ],
    "BENCH_costmodel.json": [
        (("placement", "ratio"), "cost vs counted", "{:.2f}x"),
        (("placement", "cost_model", "makespan_s"), "probe makespan s",
         "{:.3f}"),
        (("straggler", "per_kind", "replicas"), "per-kind replicas", "{:d}"),
        (("straggler", "global_p95", "replicas"), "global-p95 replicas",
         "{:d}"),
    ],
}


def bench_summary() -> list:
    """Aggregate the repo-root BENCH_*.json artifacts into summary rows."""
    rows = []
    for name in sorted(_BENCH_HEADLINES):
        path = REPO / name
        if not path.exists():
            rows.append((name, "missing — run its benchmarks/exp*.py"))
            continue
        data = json.loads(path.read_text())
        cells = []
        for keys, label, fmt in _BENCH_HEADLINES[name]:
            v = data
            try:
                for k in keys:
                    v = v[k]
                cells.append(f"{label}={fmt.format(v)}")
            except (KeyError, TypeError):
                cells.append(f"{label}=?")
        rows.append((name, "  ".join(cells)))
    print("\n### canonical BENCH artifacts (repo root)")
    for name, line in rows:
        print(f"{name:26s} {line}")
    return rows


def _run(name, fn, *a, **kw):
    t0 = time.monotonic()
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        out = fn(*a, **kw)
    dt = time.monotonic() - t0
    sys.stdout.write(buf.getvalue())
    return name, dt, out


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--bench-summary" in argv:
        bench_summary()
        return

    from benchmarks import exp1_executor, exp2_usecases, roofline_report

    summary = []

    print("### exp1: SPMD executor scaling (Table II analog)")
    name, dt, rows = _run("exp1", exp1_executor.main,
                          ["--profile", "quick", "--repeats", "2",
                           "--strong-tasks", "64", "--tasks-per-slot", "3"])
    # derived: throughput at largest weak-scaling point
    ts_max = max(r[6] for r in rows if r[1] == "weak")
    summary.append(("exp1_executor", dt * 1e6 / max(1, len(rows)),
                    f"peak_ts={ts_max}tasks/s"))

    print("\n### exp1-ablation: cold communicator (--no-cache, §V-A)")
    name, dt, rows_nc = _run("exp1nc", exp1_executor.main,
                             ["--profile", "quick", "--repeats", "1",
                              "--strong-tasks", "16", "--tasks-per-slot", "1",
                              "--no-cache"])
    ts_nc = max(r[6] for r in rows_nc if r[1] == "weak")
    summary.append(("exp1_no_cache", dt * 1e6 / max(1, len(rows_nc)),
                    f"peak_ts={ts_nc}tasks/s"))

    print("\n### exp2: Colmena + IWP use cases (Table III / Fig. 6 analog)")
    name, dt, _ = _run("exp2", exp2_usecases.main,
                       ["--nodes", "4", "8", "16", "--repeats", "2",
                        "--sim-ms", "50"])
    summary.append(("exp2_usecases", dt * 1e6, "see CSV above"))

    print("\n### exp2-bulk: bulk submission (paper future work)")
    name, dt, _ = _run("bulk", exp2_usecases.main,
                       ["--app", "colmena", "--nodes", "16", "--repeats",
                        "2", "--sim-ms", "50", "--bulk"])
    summary.append(("exp2_bulk", dt * 1e6, "see CSV above"))

    print("\n### roofline: dry-run derived table (single pod)")
    try:
        name, dt, rows = _run("roofline", roofline_report.main, ["--csv"])
        ok = [r for r in rows if r.get("status") == "ok"]
        best = max(ok, key=lambda r: r["frac"]) if ok else None
        summary.append(("roofline_table", dt * 1e6,
                        f"cells={len(rows)},best_frac="
                        f"{best['frac']:.4f}@{best['arch']}/{best['shape']}"
                        if best else "n/a"))
    except FileNotFoundError:
        summary.append(("roofline_table", 0.0, "artifacts missing"))

    print("\nname,us_per_call,derived")
    for row in summary:
        print(f"{row[0]},{row[1]:.1f},{row[2]}")

    bench_summary()


if __name__ == "__main__":
    main()
