"""Benchmark entry point — one harness per paper table/figure.

  exp1   -> Table II / Fig. 4  (SPMD executor weak/strong scaling)
  exp1nc -> §V-A cold-communicator ablation (--no-cache)
  exp2   -> Table III / Fig. 5 (Colmena + IWP TTX and overheads)
  bulk   -> paper's future-work bulk-submission mode, measured
  roofline -> §Roofline table from the dry-run artifacts (assignment)

Prints ``name,us_per_call,derived`` CSV summary lines at the end.
"""
from __future__ import annotations

import contextlib
import io
import sys
import time


def _run(name, fn, *a, **kw):
    t0 = time.monotonic()
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        out = fn(*a, **kw)
    dt = time.monotonic() - t0
    sys.stdout.write(buf.getvalue())
    return name, dt, out


def main() -> None:
    from benchmarks import exp1_executor, exp2_usecases, roofline_report

    summary = []

    print("### exp1: SPMD executor scaling (Table II analog)")
    name, dt, rows = _run("exp1", exp1_executor.main,
                          ["--profile", "quick", "--repeats", "2",
                           "--strong-tasks", "64", "--tasks-per-slot", "3"])
    # derived: throughput at largest weak-scaling point
    ts_max = max(r[6] for r in rows if r[1] == "weak")
    summary.append(("exp1_executor", dt * 1e6 / max(1, len(rows)),
                    f"peak_ts={ts_max}tasks/s"))

    print("\n### exp1-ablation: cold communicator (--no-cache, §V-A)")
    name, dt, rows_nc = _run("exp1nc", exp1_executor.main,
                             ["--profile", "quick", "--repeats", "1",
                              "--strong-tasks", "16", "--tasks-per-slot", "1",
                              "--no-cache"])
    ts_nc = max(r[6] for r in rows_nc if r[1] == "weak")
    summary.append(("exp1_no_cache", dt * 1e6 / max(1, len(rows_nc)),
                    f"peak_ts={ts_nc}tasks/s"))

    print("\n### exp2: Colmena + IWP use cases (Table III / Fig. 6 analog)")
    name, dt, _ = _run("exp2", exp2_usecases.main,
                       ["--nodes", "4", "8", "16", "--repeats", "2",
                        "--sim-ms", "50"])
    summary.append(("exp2_usecases", dt * 1e6, "see CSV above"))

    print("\n### exp2-bulk: bulk submission (paper future work)")
    name, dt, _ = _run("bulk", exp2_usecases.main,
                       ["--app", "colmena", "--nodes", "16", "--repeats",
                        "2", "--sim-ms", "50", "--bulk"])
    summary.append(("exp2_bulk", dt * 1e6, "see CSV above"))

    print("\n### roofline: dry-run derived table (single pod)")
    try:
        name, dt, rows = _run("roofline", roofline_report.main, ["--csv"])
        ok = [r for r in rows if r.get("status") == "ok"]
        best = max(ok, key=lambda r: r["frac"]) if ok else None
        summary.append(("roofline_table", dt * 1e6,
                        f"cells={len(rows)},best_frac="
                        f"{best['frac']:.4f}@{best['arch']}/{best['shape']}"
                        if best else "n/a"))
    except FileNotFoundError:
        summary.append(("roofline_table", 0.0, "artifacts missing"))

    print("\nname,us_per_call,derived")
    for row in summary:
        print(f"{row[0]},{row[1]:.1f},{row[2]}")


if __name__ == "__main__":
    main()
