"""Kernel-adjusted memory term.

The dry-run lowers the pure-jnp flash/SSD formulations (Pallas cannot lower
for the CPU backend), so the measured HBM-traffic term includes score-sized
intermediates that the TPU Pallas kernels keep in VMEM.  This report
subtracts the traffic of ops whose einsum signatures identify them as
kernel-interior (conservative: fused elementwise neighbors are NOT
subtracted), giving the memory term the Pallas execution path would see.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.roofline.analysis import HBM_BW

ART = Path(__file__).resolve().parent / "artifacts"

# einsum label tails that live inside the Pallas kernels' VMEM tiles
KERNEL_INTERIOR = (
    "bshgd,bkhd->bshgk",   # flash scores (fwd + bwd dp)
    "bshgk,bkhd->bshgd",   # flash AV / dq
    "bshgk,bshgd->bkhd",   # flash dk/dv
    "bhgd,bshd->bhgs",     # decode scores
    "bhgs,bshd->bhgd",     # decode AV
    "bin,bjn->bij",        # SSD C.B^T
    "bhij,bjhp->bihp",     # SSD intra-chunk apply
    "bhq,bqh,bqn,bqhp->bhpn",   # SSD chunk state
    "bqn,bhq,bhpn->bqhp",  # SSD inter-chunk apply
)


def adjusted(artifact: dict):
    scopes = artifact["cost"].get("bytes_by_scope") or {}
    interior = sum(v for k, v in scopes.items()
                   if any(sig in k for sig in KERNEL_INTERIOR))
    raw = artifact["cost"]["bytes_per_device"]
    adj = raw - interior
    return raw / HBM_BW, adj / HBM_BW, interior / max(raw, 1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="perf/pod16x16")
    args = ap.parse_args(argv)
    print(f"{'cell':<58}{'mem_jnp_s':>10}{'mem_kern_s':>11}{'interior%':>10}")
    for f in sorted((ART / args.dir).glob("*.json")):
        a = json.loads(f.read_text())
        if a.get("status") != "ok":
            continue
        raw_s, adj_s, frac = adjusted(a)
        name = f"{a['arch']}/{a['shape']}/{a.get('tag', '')}"
        print(f"{name:<58}{raw_s:>10.3f}{adj_s:>11.3f}{frac*100:>9.1f}%")


if __name__ == "__main__":
    main()
