"""Roofline report (§Roofline of EXPERIMENTS.md): reads the dry-run
artifacts and prints the three-term table per (arch x shape x mesh), the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the achievable roofline
fraction."""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"


def rows_for(mesh: str):
    rows = []
    for f in sorted((ART / mesh).glob("*.json")):
        a = json.loads(f.read_text())
        if a.get("status") != "ok":
            rows.append({"arch": a["arch"], "shape": a["shape"],
                         "status": a.get("status", "?")})
            continue
        r = a["roofline"]
        rows.append({
            "arch": a["arch"], "shape": a["shape"], "status": "ok",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "useful": r["model_flops_over_hlo_flops"],
            "frac": r["roofline_fraction"],
            "peak_gb": a["peak_bytes_per_device"] / 1e9,
            "fits": a["fits_16GB"],
            "mu": a.get("microbatches", 1),
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args(argv)
    rows = rows_for(args.mesh)
    if args.csv:
        print("arch,shape,status,compute_s,memory_s,collective_s,dominant,"
              "useful_flops_ratio,roofline_fraction,peak_gb,fits_16GB,mu")
        for r in rows:
            if r["status"] != "ok":
                print(f"{r['arch']},{r['shape']},{r['status']},,,,,,,,,")
                continue
            print(",".join(str(x) for x in (
                r["arch"], r["shape"], "ok", f"{r['compute_s']:.4f}",
                f"{r['memory_s']:.4f}", f"{r['collective_s']:.4f}",
                r["dominant"], f"{r['useful']:.4f}", f"{r['frac']:.5f}",
                f"{r['peak_gb']:.2f}", r["fits"], r["mu"])))
        return rows
    print(f"{'arch':<24}{'shape':<13}{'comp_s':>9}{'mem_s':>9}{'coll_s':>9}"
          f"  {'dominant':<11}{'useful':>7}{'frac':>9}{'peak':>8}")
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:<24}{r['shape']:<13}  -- {r['status']}")
            continue
        print(f"{r['arch']:<24}{r['shape']:<13}{r['compute_s']:>9.3f}"
              f"{r['memory_s']:>9.3f}{r['collective_s']:>9.3f}  "
              f"{r['dominant']:<11}{r['useful']:>7.3f}{r['frac']:>9.5f}"
              f"{r['peak_gb']:>7.1f}G")
    return rows


if __name__ == "__main__":
    main()
