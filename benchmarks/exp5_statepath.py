"""Experiment 5: per-task bookkeeping overhead + wide fan-in launch latency.

PR 1/2 removed polling from the submit->schedule->complete spine; what was
left on the critical path was bookkeeping: a synchronous ``json.dumps`` +
line-buffered disk write under the StateStore lock per state transition, a
linear scan per restart lookup, and per-future dependency callbacks plus a
fresh ``threading.Timer`` per bulk window in the DFK.  PR 3 made the state
path write-behind (group commit), indexed (O(1) ``completed_result``) and
batched (one dependency-manager pass + one persistent flusher).  This
experiment measures all three against faithful reimplementations of the
PR-2 baselines:

  * ``record``   — per-task journal bookkeeping cost on the stream path
                   (full 6-transition lifecycle per task, drained to disk);
  * ``lookup``   — ``completed_result`` latency at restart scale;
  * ``fanin``    — N producers -> 1 consumer: latency from the last
                   producer completing to the aggregated consumer result;
  * ``fanout``   — 1 producer -> N consumers: producer completion to the
                   last consumer result (single-pass batch launch).

Emits ``BENCH_statepath.json`` at the repo root.  ``--min-speedup`` gates
the journaled record path (CI requires >= 2x) and ``--min-fanin-speedup``
gates the fan-in launch latency (CI requires >= 3x).
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import threading
import time
from pathlib import Path

from repro.core import (EVENTS, DataFlowKernel, PilotDescription,
                        RPEXExecutor, TaskRecord, TaskState)
from repro.core.dfk import _find_futures, _resolve
from repro.core.executors import ParslTask


# ------------------- PR-2 baseline: synchronous journal ------------------- #

def _jsonable(x) -> bool:
    """PR-2's serializability probe — itself a dumps, paid per DONE record
    on the caller's thread."""
    try:
        json.dumps(x)
        return True
    except (TypeError, ValueError):
        return False


class SyncStateStore:
    """The PR-2 StateStore write path, kept for comparison: every record
    does json.dumps + a line-buffered write (one syscall per line) while
    holding the store lock, and completed_result scans every record."""

    def __init__(self, journal_path: str):
        self.journal_path = Path(journal_path)
        self._lock = threading.Lock()
        self.tasks = {}
        self.events = []
        self.journal_path.parent.mkdir(parents=True, exist_ok=True)
        self._fh = open(self.journal_path, "a", buffering=1)

    def record(self, task: TaskRecord, workflow_key=None):
        rec = {"uid": task.uid, "key": workflow_key, "kind": task.kind,
               "state": task.state.value, "retries": task.retries,
               "slot_ids": list(task.slot_ids), "t": time.time()}
        if task.state == TaskState.DONE and _jsonable(task.result):
            rec["result"] = task.result
        ev = {"event": EVENTS.STATE, "uid": task.uid,
              "state": task.state.value,
              "t": time.monotonic(), "slots": len(task.slot_ids) or 1}
        with self._lock:
            prev = self.tasks.get(task.uid, {})
            if rec.get("key") is None:
                rec["key"] = prev.get("key")
            self.tasks[task.uid] = {**prev, **rec}
            self.events.append(ev)
            self._fh.write(json.dumps(self.tasks[task.uid]) + "\n")

    def completed_result(self, workflow_key: str):
        with self._lock:
            for rec in self.tasks.values():
                if rec.get("key") == workflow_key and \
                        rec.get("state") == TaskState.DONE.value and \
                        "result" in rec:
                    return True, rec["result"]
        return False, None

    def close(self):
        with self._lock:
            if self._fh:
                self._fh.close()
                self._fh = None


# ------------- PR-2 baseline: callback-chain + Timer-window DFK ------------ #

class BaselineDFK(DataFlowKernel):
    """The PR-2 dependency/flush control flow, reimplemented on today's
    kernel: one done-callback per (consumer, dependency) edge with a
    per-node lock, and a fresh threading.Timer spawned per bulk window."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._timers = {}

    def submit(self, fn, args=(), kwargs=None, resources=None, retries=0,
               executor=None, sticky=None):
        from repro.core.futures import (AppFuture, ResourceSpec, TaskRecord,
                                        new_uid)
        kwargs = kwargs or {}
        name = getattr(fn, "__name__", "app")
        with self._lock:
            idx = self._invocation_idx.get(name, 0)
            self._invocation_idx[name] = idx + 1
        key = f"{self.run_id}/{name}:{idx}" if self.run_id else None
        node = TaskRecord(uid=new_uid("dfk"), kind="parsl", fn=fn,
                          args=args, kwargs=kwargs,
                          resources=resources or ResourceSpec())
        future = AppFuture(node)
        self.tasks[node.uid] = node
        label = (executor or getattr(fn, "__executor__", None)
                 or self.default_executor)
        ex = self.executors[label]

        deps = [f for f in _find_futures((args, kwargs)) if not f.done()]

        def launch():
            try:
                r_args = tuple(_resolve(a) for a in args)
                r_kwargs = {k: _resolve(v) for k, v in kwargs.items()}
            except BaseException as e:
                node.transition(TaskState.FAILED)
                if not future.done():
                    future.set_exception(e)
                return
            pt = ParslTask(fn, r_args, r_kwargs, node.resources, retries,
                           key, executor=label)
            node.transition(TaskState.TRANSLATED)
            self._old_dispatch(ex, pt, future)

        if not deps:
            launch()
        else:
            remaining = [len(deps)]
            rlock = threading.Lock()

            def on_dep(_):
                with rlock:
                    remaining[0] -= 1
                    ready = remaining[0] == 0
                if ready:
                    launch()

            for d in deps:               # one callback per edge (PR-2)
                d.add_done_callback(on_dep)
        return future

    def _old_dispatch(self, ex, pt, future):
        if self.bulk and ex.supports_bulk:
            label = pt.executor or ex.label
            with self._lock:
                self._pending_bulk.setdefault(label, []).append((pt, future))
                if label not in self._timers:
                    t = threading.Timer(self.bulk_window, self.flush, [label])
                    t.daemon = True
                    self._timers[label] = t
                    t.start()
        else:
            ex.submit(pt, future)

    def flush(self, executor=None):
        with self._lock:
            labels = ([executor] if executor is not None
                      else list(self._pending_bulk))
            batches = {}
            for label in labels:
                pairs = self._pending_bulk.pop(label, [])
                if pairs:
                    batches[label] = pairs
                timer = self._timers.pop(label, None)
                if timer is not None:
                    timer.cancel()
        for label, pairs in batches.items():
            self.executors[label].submit_bulk(pairs)


# ------------------------------ measurements ------------------------------ #

def _lifecycle(store, uid, key, result):
    t = TaskRecord(uid=uid, kind="python")
    for st in (TaskState.TRANSLATED, TaskState.SCHEDULED,
               TaskState.LAUNCHING, TaskState.RUNNING):
        t.state = st
        store.record(t, workflow_key=key)
    t.result = result
    t.state = TaskState.DONE
    store.record(t, workflow_key=key)


def bench_record(store_factory, n_tasks: int, path: str) -> float:
    """Seconds per task for a full journaled lifecycle (6 records), drained
    to disk (close() included, so write-behind pays for its queue)."""
    store = store_factory(path)
    t0 = time.monotonic()
    for i in range(n_tasks):
        _lifecycle(store, f"t{i}", f"k{i}", i)
    store.close()
    return (time.monotonic() - t0) / n_tasks


def bench_lookup(store_factory, n_records: int, n_lookups: int,
                 path: str) -> float:
    """Seconds per completed_result lookup at restart scale."""
    store = store_factory(path)
    for i in range(n_records):
        t = TaskRecord(uid=f"t{i}", kind="python")
        t.result = i
        t.state = TaskState.DONE
        store.record(t, workflow_key=f"k{i}")
    keys = [f"k{(i * 7919) % n_records}" for i in range(n_lookups)]
    t0 = time.monotonic()
    for k in keys:
        found, _ = store.completed_result(k)
        assert found
    dt = (time.monotonic() - t0) / n_lookups
    store.close()
    return dt


def _noop(x):
    return x


def _agg(xs):
    return len(xs)


def _fan_rpex(n_slots: int) -> RPEXExecutor:
    return RPEXExecutor(PilotDescription(n_slots=n_slots))


def bench_fanin(dfk_cls, n_producers: int, n_slots: int) -> dict:
    """N producers -> 1 consumer.  Launch latency = last producer
    completion -> consumer SCHEDULED on the pilot (from the unified event
    stream): the time the dependency/bookkeeping machinery takes to get
    the aggregator into the executor, excluding its execution.  The
    completion latency (-> result available) is reported alongside."""
    rpex = _fan_rpex(n_slots)
    try:
        with dfk_cls(executors={"rpex": rpex}, bulk=True) as dfk:
            # ---- fan-in: N -> 1 ----
            done_t = []
            tlock = threading.Lock()

            def stamp(_f):
                with tlock:
                    done_t.append(time.monotonic())

            prods = [dfk.submit(_noop, (i,)) for i in range(n_producers)]
            for f in prods:
                f.add_done_callback(stamp)
            agg = dfk.submit(_agg, (prods,))
            dfk.flush()
            assert agg.result(timeout=60) == n_producers
            t_agg = time.monotonic()
            tl = rpex.pilot.store.timeline()
            sched = tl[agg.task.uid]["SCHEDULED"]
            fanin_launch = sched - max(done_t)
            fanin_total = t_agg - max(done_t)

            # ---- fan-out: 1 -> N ----
            gate = threading.Event()

            def root():
                gate.wait(30)
                return 0

            froot = dfk.submit(root)
            t_root = [None]
            froot.add_done_callback(
                lambda _f: t_root.__setitem__(0, time.monotonic()))
            cons = [dfk.submit(_noop, (froot,)) for _ in range(n_producers)]
            dfk.flush()
            time.sleep(0.05)             # consumers are all registered
            gate.set()
            for f in cons:
                f.result(timeout=60)
            fanout_total = time.monotonic() - t_root[0]
            tl = rpex.pilot.store.timeline()
            # launch = every consumer routed into the executor (TRANSLATED
            # on the pilot); SCHEDULED would fold in slot-drain time when
            # the fan width exceeds the slot count
            fanout_launch = max(
                tl[f.task.uid]["TRANSLATED"] for f in cons) - t_root[0]
        return {"fanin_launch_s": fanin_launch,
                "fanin_total_s": fanin_total,
                "fanout_launch_s": fanout_launch,
                "fanout_total_s": fanout_total}
    finally:
        rpex.shutdown()


def main(argv=None):
    from repro.core import StateStore

    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=2000,
                    help="tasks for the record-path benchmark (6 journal "
                         "records each)")
    ap.add_argument("--records", type=int, default=20000,
                    help="store size for the lookup benchmark")
    ap.add_argument("--lookups", type=int, default=2000)
    ap.add_argument("--producers", type=int, default=256,
                    help="fan width for the dependency benchmarks")
    ap.add_argument("--slots", type=int, default=2,
                    help="pilot slots for the dependency benchmarks; few "
                         "slots keep the producer-settle churn away from "
                         "the measured launch window on small containers")
    ap.add_argument("--repeats", type=int, default=5,
                    help="repeat each measurement, keep the best: single "
                         "samples on a shared 2-core container swing "
                         "several-fold with scheduling noise, so min-of-N "
                         "estimates the machine floor for both sides")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit nonzero if the journaled record path is not "
                         "at least this much faster than the PR-2 "
                         "synchronous baseline (0 = report only)")
    ap.add_argument("--min-fanin-speedup", type=float, default=0.0,
                    help="exit nonzero if fan-in launch latency is not at "
                         "least this much lower than the PR-2 callback/"
                         "Timer baseline (0 = report only)")
    ap.add_argument("--scratch", default=None,
                    help="journal scratch dir (default: a temp dir, "
                         "removed afterwards)")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent.parent
                                         / "BENCH_statepath.json"))
    args = ap.parse_args(argv)

    scratch = Path(args.scratch or tempfile.mkdtemp(prefix="exp5_scratch_"))
    scratch.mkdir(parents=True, exist_ok=True)
    results = {"config": {k: getattr(args, k) for k in
                          ("tasks", "records", "lookups", "producers",
                           "slots", "repeats")}}

    def fresh(name, i):
        p = scratch / f"{name}_{i[0]}.jsonl"
        i[0] += 1
        if p.exists():
            p.unlink()
        return str(p)

    try:
        print("# record path: journaled lifecycle, per task")
        # interleave the A/B repeats: running all baseline samples before
        # all candidate samples lets slow machine drift (shared-container
        # load, frequency scaling) land entirely on one side of the
        # ratio; alternating samples both sides across the same windows,
        # so min-of-N estimates both floors under comparable conditions
        i = [0]
        sync_samples, wb_samples = [], []
        for _ in range(max(1, args.repeats)):
            sync_samples.append(bench_record(
                SyncStateStore, args.tasks, fresh("sync", i)))
            wb_samples.append(bench_record(
                StateStore, args.tasks, fresh("wb", i)))
        sync_rec, wb_rec = min(sync_samples), min(wb_samples)
        # gate statistic: the *median per-window ratio*.  Each interleaved
        # repeat is one window in which both sides ran back to back, so
        # its ratio is drift-free; the median across windows discards the
        # windows a background burst poisoned.  (The ratio of global
        # minima mixes floors from different windows and swings past the
        # gate either way on a shared 2-core container.)
        pair = sorted(s / w for s, w in zip(sync_samples, wb_samples))
        rec_speedup = pair[len(pair) // 2]
        results["record"] = {"sync_us_per_task": sync_rec * 1e6,
                             "write_behind_us_per_task": wb_rec * 1e6,
                             "speedup": rec_speedup,
                             "speedup_of_mins": sync_rec / wb_rec}
        print(f"  sync (PR-2):    {sync_rec * 1e6:9.1f} us/task")
        print(f"  write-behind:   {wb_rec * 1e6:9.1f} us/task"
              f"   ({rec_speedup:.1f}x lower, median window ratio)")

        print("# completed_result: restart lookup latency")
        sync_lk = bench_lookup(SyncStateStore, args.records, args.lookups,
                               fresh("synclk", i))
        wb_lk = bench_lookup(StateStore, args.records, args.lookups,
                             fresh("wblk", i))
        lk_speedup = sync_lk / wb_lk
        results["lookup"] = {"sync_us_per_lookup": sync_lk * 1e6,
                             "indexed_us_per_lookup": wb_lk * 1e6,
                             "speedup": lk_speedup,
                             "records": args.records}
        print(f"  linear scan (PR-2): {sync_lk * 1e6:9.1f} us/lookup "
              f"@ {args.records} records")
        print(f"  indexed:            {wb_lk * 1e6:9.1f} us/lookup"
              f"   ({lk_speedup:.0f}x lower)")

        print(f"# dependency resolution: {args.producers}-wide fan-in/out")
        base, new = [], []
        for _ in range(max(1, args.repeats)):     # interleaved (see above)
            base.append(bench_fanin(BaselineDFK, args.producers,
                                    args.slots))
            new.append(bench_fanin(DataFlowKernel, args.producers,
                                   args.slots))

        def mins(rows, k):
            return min(r[k] for r in rows)

        b_in = mins(base, "fanin_launch_s")
        n_in = mins(new, "fanin_launch_s")
        fanin_speedup = b_in / n_in
        results["fanin"] = {
            "baseline_launch_ms": b_in * 1e3,
            "batched_launch_ms": n_in * 1e3,
            "baseline_total_ms": mins(base, "fanin_total_s") * 1e3,
            "batched_total_ms": mins(new, "fanin_total_s") * 1e3,
            "speedup": fanin_speedup}
        b_out = mins(base, "fanout_launch_s")
        n_out = mins(new, "fanout_launch_s")
        results["fanout"] = {
            "baseline_launch_ms": b_out * 1e3,
            "batched_launch_ms": n_out * 1e3,
            "baseline_total_ms": mins(base, "fanout_total_s") * 1e3,
            "batched_total_ms": mins(new, "fanout_total_s") * 1e3,
            "speedup": b_out / n_out}
        print(f"  fan-in  launch latency: PR-2 {b_in * 1e3:7.2f} ms   "
              f"batched {n_in * 1e3:7.2f} ms   ({fanin_speedup:.1f}x lower)")
        print(f"  fan-out launch latency: PR-2 {b_out * 1e3:7.2f} ms   "
              f"batched {n_out * 1e3:7.2f} ms   ({b_out / n_out:.1f}x lower)")
    finally:
        if args.scratch is None:
            shutil.rmtree(scratch, ignore_errors=True)

    out = Path(args.out)
    out.write_text(json.dumps(results, indent=2))
    print(f"wrote {out}")
    failures = []
    if args.min_speedup and rec_speedup < args.min_speedup:
        failures.append(f"record path {rec_speedup:.2f}x < required "
                        f"{args.min_speedup:.1f}x")
    if args.min_fanin_speedup and fanin_speedup < args.min_fanin_speedup:
        failures.append(f"fan-in latency {fanin_speedup:.2f}x < required "
                        f"{args.min_fanin_speedup:.1f}x")
    if failures:
        raise SystemExit("REGRESSION: " + "; ".join(failures))
    return results


if __name__ == "__main__":
    main()
