"""Experiment 11: the data plane — zero-copy result delivery and
byte-weighted placement (docs/dataplane.md).

Part A — delivery overhead.  One proc-transport pilot runs a fan-out: a
producer returns a multi-MB array and N consumers each read it.  With
the data plane OFF (``data_plane=False``, no shm) that payload travels
*by value*: pickled child->parent once for the result, then pickled
parent->child again for every consumer's arguments — the PR-8 baseline.
With the data plane ON the result is published once as an ObjectRef,
consumers deref it zero-copy on the same pilot, and the proc transport
ships the array through a ``multiprocessing.shared_memory`` segment
instead of the pipe.  The per-edge result-delivery overhead is
``(makespan - ideal compute) / edges``; the gate requires the OFF/ON
ratio to clear ``--min-delivery-ratio``.

Part B — placement.  Three small producers are pinned to pilot p0 and
one large producer to pilot p1; a sink consumes all four.  Byte-weighted
affinity (the default) follows the *largest* input to p1; the legacy
uid-counted stamp sees one hint per producer pilot, ties, and
first-of-equals lands the sink on p0 — next to kilobytes instead of the
large array.  ``--require-placement`` gates both outcomes.

Emits ``BENCH_dataplane.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import concurrent.futures
import json
import time
from pathlib import Path

import numpy as np

from repro.core import (DataFlowKernel, LocalityAware, PilotDescription,
                        ResourceSpec, RPEXExecutor, python_app)


@python_app
def produce(n_elems, compute_s):
    time.sleep(compute_s)
    return np.ones(n_elems, dtype=np.float64)


@python_app
def consume(x, compute_s):
    time.sleep(compute_s)
    return float(x[0]) + float(x[-1])


# ----------------------- Part A: delivery overhead ----------------------- #

def run_fanout(data_plane: bool, payload_mb: float, edges: int,
               compute_s: float) -> dict:
    """One measured fan-out: producer -> N consumers on a single
    proc-transport pilot (slots=1, so compute serializes and the ideal
    makespan is exact)."""
    n_elems = int(payload_mb * 1024 * 1024) // 8
    desc = PilotDescription(
        name="dp", n_slots=1, transport="proc",
        shm_threshold=(256 * 1024 if data_plane else None))
    ex = RPEXExecutor(desc, steal=False, data_plane=data_plane)
    try:
        with DataFlowKernel(executors={"rpex": ex}):
            # warm the worker (fork + numpy import) outside the timing
            consume(produce(1024, 0.0), 0.0).result()

            t0 = time.monotonic()
            root = produce(n_elems, compute_s)
            sinks = [consume(root, compute_s) for _ in range(edges)]
            for f in sinks:
                assert f.result(timeout=300) == 2.0
            makespan = time.monotonic() - t0
        ideal = (edges + 1) * compute_s
        stats = ex.objectstore.stats() if ex.objectstore else {}
        return {"makespan_s": makespan, "ideal_s": ideal,
                "overhead_ms_per_edge": (makespan - ideal) * 1e3 / edges,
                "objectstore": stats}
    finally:
        ex.shutdown()


def measure_delivery(data_plane: bool, args) -> dict:
    runs = [run_fanout(data_plane, args.payload_mb, args.edges,
                       args.compute_ms / 1000.0)
            for _ in range(max(1, args.repeats))]
    best = min(runs, key=lambda r: r["overhead_ms_per_edge"])
    return {**best, "runs": len(runs)}


# ----------------------- Part B: placement routing ----------------------- #

@python_app
def small_produce():
    return np.ones(64 * 1024 // 8, dtype=np.float64)


@python_app
def big_produce(n_elems):
    return np.ones(n_elems, dtype=np.float64)


@python_app
def sink(big, *smalls):
    return float(big.sum()) + sum(float(s.sum()) for s in smalls)


def run_placement(byte_affinity: bool, payload_mb: float) -> dict:
    """Pinned producers (3 small on p0, 1 large on p1), then a sink with
    all four as inputs; which pilot the sink lands on is the measurement.
    Producers drain first so routing sees idle, equal loads — the
    affinity term alone decides."""
    n_elems = int(payload_mb * 1024 * 1024) // 8
    ex = RPEXExecutor([PilotDescription(name="p0", n_slots=4),
                       PilotDescription(name="p1", n_slots=4)],
                      steal=False,
                      placement=LocalityAware(locality_weight=10.0))
    try:
        res_p0 = ResourceSpec(slots=1, cpu_only=True, sticky=True,
                              affinity=("p0",))
        res_p1 = ResourceSpec(slots=1, cpu_only=True, sticky=True,
                              affinity=("p1",))
        with DataFlowKernel(executors={"rpex": ex},
                            byte_affinity=byte_affinity) as dfk:
            smalls = [dfk.submit(small_produce.__wrapped_app__, (),
                                 resources=res_p0) for _ in range(3)]
            big = dfk.submit(big_produce.__wrapped_app__, (n_elems,),
                             resources=res_p1)
            concurrent.futures.wait(smalls + [big])
            ex.drain(timeout=30.0)
            s = dfk.submit(sink.__wrapped_app__, (big, *smalls))
            s.result(timeout=120)
            names = {p.uid: p.desc.name for p in ex.pool.pilots}
            return {"sink_pilot": names.get(s.task.pilot_uid, "?"),
                    "edge_bytes_total": dfk.edge_bytes_total,
                    "bytes_moved": (ex.objectstore.stats()["bytes_moved"]
                                    if ex.objectstore else None)}
    finally:
        ex.shutdown()


# --------------------------------- main ---------------------------------- #

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--payload-mb", type=float, default=4.0,
                    help="producer result size (the >= 1 MB edge payload)")
    ap.add_argument("--edges", type=int, default=12,
                    help="consumers reading the producer's result")
    ap.add_argument("--compute-ms", type=float, default=10.0)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--min-delivery-ratio", type=float, default=0.0,
                    help="exit nonzero if OFF/ON per-edge delivery "
                         "overhead falls below this (0 = report only)")
    ap.add_argument("--require-placement", action="store_true",
                    help="exit nonzero unless byte-weighted affinity "
                         "routes the sink to the large producer's pilot "
                         "AND uid counting demonstrably does not")
    ap.add_argument("--out", default=str(Path(__file__).resolve()
                                         .parent.parent
                                         / "BENCH_dataplane.json"))
    args = ap.parse_args(argv)

    results = {"config": {
        "payload_mb": args.payload_mb, "edges": args.edges,
        "compute_ms": args.compute_ms, "repeats": args.repeats}}

    print(f"# fan-out: 1 producer ({args.payload_mb:g} MB result) -> "
          f"{args.edges} consumers, proc transport, 1 slot")
    off = measure_delivery(False, args)
    on = measure_delivery(True, args)
    ratio = (off["overhead_ms_per_edge"]
             / max(1e-9, on["overhead_ms_per_edge"]))
    results["delivery"] = {"off": off, "on": on, "ratio": ratio}
    for name, r in (("pickled (off)", off), ("data plane (on)", on)):
        print(f"  {name:16s}: makespan {r['makespan_s']:.3f}s "
              f"(ideal {r['ideal_s']:.3f}s), "
              f"{r['overhead_ms_per_edge']:.2f} ms/edge")
    print(f"  per-edge delivery overhead reduction: {ratio:.1f}x")

    print(f"# placement: 3 small producers @p0, 1 large @p1, one sink")
    byte_run = run_placement(True, args.payload_mb)
    uid_run = run_placement(False, args.payload_mb)
    byte_ok = byte_run["sink_pilot"] == "p1"
    uid_wrong = uid_run["sink_pilot"] != "p1"
    results["placement"] = {
        "byte_weighted": byte_run, "uid_counted": uid_run,
        "byte_follows_largest": byte_ok,
        "uid_misroutes": uid_wrong}
    print(f"  byte-weighted sink pilot: {byte_run['sink_pilot']} "
          f"(bytes_moved={byte_run['bytes_moved']})")
    print(f"  uid-counted  sink pilot: {uid_run['sink_pilot']} "
          f"(bytes_moved={uid_run['bytes_moved']})")

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=2))
    print(f"wrote {out}")

    if args.min_delivery_ratio and ratio < args.min_delivery_ratio:
        raise SystemExit(
            f"REGRESSION: data-plane delivery overhead reduction "
            f"{ratio:.2f}x < required {args.min_delivery_ratio:.2f}x")
    if args.require_placement and not (byte_ok and uid_wrong):
        raise SystemExit(
            f"REGRESSION: placement gate — byte-weighted landed on "
            f"{byte_run['sink_pilot']!r} (want 'p1'), uid-counted on "
            f"{uid_run['sink_pilot']!r} (want != 'p1')")
    return results


if __name__ == "__main__":
    main()
