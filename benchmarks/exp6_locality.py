"""Experiment 6: data-locality placement — LocalityAware vs LeastLoaded.

Workload: N independent producer/consumer chains over two identical
pilots.  Each chain is a skewed pipeline — a heavier root producer
followed by lighter consumer steps, each consuming the previous step's
output.  Roots carry no affinity and spread least-loaded across the
pilots; every consumer's translated task is stamped (by the DFK dep
manager -> translator thread) with the pilot that produced its input.

Under ``LeastLoaded`` a consumer lands wherever the load currently
points, so a chain's data ping-pongs between pilots: each producer ->
consumer edge whose endpoints ran on different pilots is a *cross-pilot
hop* — on a real deployment, a device-to-device transfer of the
intermediate.  Under ``LocalityAware`` the consumer follows its
producer's pilot unless the load gap exceeds the locality weight, and
stealing declines to migrate an affine task unless the victim's backlog
beats the affinity penalty — so chains stay put and hops collapse, while
the makespan stays at the balanced optimum (the chains were spread by
their roots; locality never piles work onto one pilot).

Each intermediate is a real ndarray (``--payload-kb``, above the object
store's publish threshold), so every cross-pilot hop is also a counted
object-store fetch: ``bytes_moved`` (docs/dataplane.md) is reported
alongside the hop count — the same reduction, but in the unit the cost
model prices (bytes over a bandwidth, not edge crossings).

Emits ``BENCH_locality.json`` at the repo root.  ``--min-hop-ratio``
gates the hop reduction (LeastLoaded hops / LocalityAware hops) and
``--max-makespan-ratio`` gates against a locality-induced makespan
regression.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core import (EVENTS, DataFlowKernel, PilotDescription,
                        RPEXExecutor, python_app)


def run_chains(placement: str, n_chains: int, depth: int,
               producer_s: float, task_s: float, payload_kb: float) -> dict:
    """One measured run: build the chains, wait them out, count hops."""
    rpex = RPEXExecutor([PilotDescription(n_slots=2, name="p0"),
                         PilotDescription(n_slots=2, name="p1")],
                        placement=placement)
    n_elems = max(1, int(payload_kb * 1024) // 8)
    try:
        @python_app
        def produce(c):
            time.sleep(producer_s)
            return np.full(n_elems, float(c))

        @python_app
        def consume(x):
            time.sleep(task_s)
            return x + 1.0              # frozen input: the add allocates

        t0 = time.monotonic()
        with DataFlowKernel(executors={"rpex": rpex}):
            chains = []
            for c in range(n_chains):
                futs = [produce(c)]
                for _ in range(depth - 1):
                    futs.append(consume(futs[-1]))
                chains.append(futs)
            for c, futs in enumerate(chains):
                out = futs[-1].result(timeout=120)
                assert float(out[0]) == c + depth - 1
        makespan = time.monotonic() - t0

        hops = edges = 0
        per_pilot = {}
        for futs in chains:
            pilots = [f.task.pilot_uid for f in futs]
            for uid in pilots:
                per_pilot[uid] = per_pilot.get(uid, 0) + 1
            for src, dst in zip(pilots, pilots[1:]):
                edges += 1
                hops += src != dst
        stolen = sum(1 for e in rpex.pool.events()
                     if e["event"] == EVENTS.STOLEN)
        stats = rpex.objectstore.stats() if rpex.objectstore else {}
        return {"makespan_s": makespan, "hops": hops, "edges": edges,
                "stolen": stolen, "tasks_per_pilot": per_pilot,
                "bytes_moved": stats.get("bytes_moved", 0),
                "bytes_published": stats.get("bytes_published", 0)}
    finally:
        rpex.shutdown()


def measure(placement: str, args) -> dict:
    """Best-of-N makespan (container scheduling noise), hops and bytes
    summed over every repeat so one lucky run cannot carry the gate."""
    runs = [run_chains(placement, args.chains, args.depth,
                       args.producer_ms / 1000.0, args.task_ms / 1000.0,
                       args.payload_kb)
            for _ in range(max(1, args.repeats))]
    best = min(runs, key=lambda r: r["makespan_s"])
    return {**best,
            "hops_total": sum(r["hops"] for r in runs),
            "edges_total": sum(r["edges"] for r in runs),
            "stolen_total": sum(r["stolen"] for r in runs),
            "bytes_moved_total": sum(r["bytes_moved"] for r in runs),
            "runs": len(runs)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--chains", type=int, default=8)
    ap.add_argument("--depth", type=int, default=6,
                    help="tasks per chain (1 producer + depth-1 consumers)")
    ap.add_argument("--producer-ms", type=float, default=60.0)
    ap.add_argument("--task-ms", type=float, default=25.0)
    ap.add_argument("--payload-kb", type=float, default=128.0,
                    help="intermediate ndarray size; above the publish "
                         "threshold so hops are also counted bytes")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--min-hop-ratio", type=float, default=0.0,
                    help="exit nonzero if LeastLoaded hops / LocalityAware "
                         "hops falls below this (0 = report only)")
    ap.add_argument("--max-makespan-ratio", type=float, default=0.0,
                    help="exit nonzero if LocalityAware makespan / "
                         "LeastLoaded makespan exceeds this "
                         "(0 = report only)")
    ap.add_argument("--out", default=str(Path(__file__).resolve()
                                         .parent.parent
                                         / "BENCH_locality.json"))
    args = ap.parse_args(argv)

    results = {"config": {
        "chains": args.chains, "depth": args.depth,
        "producer_ms": args.producer_ms, "task_ms": args.task_ms,
        "payload_kb": args.payload_kb, "repeats": args.repeats}}

    print(f"# {args.chains} producer/consumer chains x depth {args.depth}, "
          f"2 pilots x 2 slots")
    least = measure("least-loaded", args)
    loc = measure("locality", args)
    hop_ratio = least["hops_total"] / max(1, loc["hops_total"])
    makespan_ratio = loc["makespan_s"] / least["makespan_s"]
    bytes_ratio = (least["bytes_moved_total"]
                   / max(1, loc["bytes_moved_total"]))
    results["least_loaded"] = least
    results["locality"] = loc
    results["hop_ratio"] = hop_ratio
    results["makespan_ratio"] = makespan_ratio
    results["bytes_moved_ratio"] = bytes_ratio

    for name, r in (("least-loaded", least), ("locality", loc)):
        print(f"  {name:13s}: makespan {r['makespan_s']:.3f}s, "
              f"hops {r['hops_total']}/{r['edges_total']}, "
              f"{r['bytes_moved_total'] / 1e6:.1f} MB moved "
              f"(stolen={r['stolen_total']})")
    print(f"  cross-pilot hop reduction: {hop_ratio:.1f}x, "
          f"bytes moved: {bytes_ratio:.1f}x  "
          f"(makespan ratio {makespan_ratio:.2f})")

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=2))
    print(f"wrote {out}")

    if args.min_hop_ratio and hop_ratio < args.min_hop_ratio:
        raise SystemExit(
            f"REGRESSION: locality hop reduction {hop_ratio:.2f}x < "
            f"required {args.min_hop_ratio:.2f}x")
    if args.max_makespan_ratio and makespan_ratio > args.max_makespan_ratio:
        raise SystemExit(
            f"REGRESSION: locality makespan ratio {makespan_ratio:.2f} > "
            f"allowed {args.max_makespan_ratio:.2f}")
    return results


if __name__ == "__main__":
    main()
