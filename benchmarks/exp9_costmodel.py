"""Experiment 9: cost-model scheduling — predicted seconds vs counted slots.

Phase 1 (placement under a skewed-duration mix): two single-slot pilots
with warm duration models.  Pilot "heavy" holds a few *long* tasks
(~0.5s each); pilot "light" holds more — but much shorter — tasks, so
heavy's queue is the smaller one in slots and the far larger one in
predicted seconds.  A stream of short probe tasks is then routed through
the pool:

  * ``LeastLoaded`` counts slots, sends the probes to heavy, and they
    sit behind seconds of long work;
  * ``CostModelPolicy`` prices both queues with the per-kind EWMA model
    and sends the probes to light.

The gate is the probe-stream makespan ratio (count-based / cost-based),
``--min-makespan-ratio`` (CI: 1.3).

Phase 2 (per-kind straggler deadlines): one pilot whose duration model
knows a slow kind (mean 0.15s) is flooded with ~2ms tasks of a fast
kind, dragging the *global* recent-p95 deadline to its floor; then one
perfectly healthy slow-kind task runs (0.3s).  With the old global
deadline (``per_kind_deadlines=False``) the monitor judges the slow task
against the fast population and spawns a spurious replica; per-kind
deadlines judge it against its own population and spawn none.  Both
counts are recorded and gated: per-kind must spawn 0 while the global
baseline spawns >= 1.

Emits ``BENCH_costmodel.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from pathlib import Path

from repro.core import (CostModelPolicy, Pilot, PilotDescription, PilotPool,
                        TaskManager, translate)


def _kinded(name, body):
    body.__app_kind__ = name
    return body


# ----------------------- phase 1: placement pricing ----------------------- #

def run_placement(use_cost: bool, n_long: int, long_ms: float,
                  n_short: int, short_ms: float,
                  n_probe: int, probe_ms: float) -> dict:
    policy = CostModelPolicy() if use_cost else None   # None = least-loaded
    pool = PilotPool([PilotDescription(n_slots=1, name="heavy",
                                       straggler_factor=1e9),
                      PilotDescription(n_slots=1, name="light",
                                       straggler_factor=1e9)],
                     steal=False, preempt=False, policy=policy)
    tm = TaskManager(pool)
    try:
        heavy, light = pool.pilots
        # warm model: both pilots know every kind's duration population
        # (cross-pilot seeding keeps elastic newcomers warm the same way)
        for p in (heavy, light):
            p.store.seed_durations("long", long_ms / 1000.0, 0.0, 10)
            p.store.seed_durations("short", short_ms / 1000.0, 0.0, 10)
            p.store.seed_durations("probe", probe_ms / 1000.0, 0.0, 10)
        # the skew: few long tasks vs many short ones — heavy's queue is
        # smaller in slots, larger in predicted seconds
        for _ in range(n_long):
            heavy.agent.submit(translate(
                _kinded("long",
                        lambda: time.sleep(long_ms / 1000.0)), (), {}))
        for _ in range(n_short):
            light.agent.submit(translate(
                _kinded("short",
                        lambda: time.sleep(short_ms / 1000.0)), (), {}))
        assert (heavy.agent.load() < light.agent.load()), \
            "skew setup lost: heavy must hold fewer slots than light"

        probes = [translate(_kinded("probe",
                                    lambda: time.sleep(probe_ms / 1000.0)),
                            (), {})
                  for _ in range(n_probe)]
        t0 = time.monotonic()
        for t in probes:
            tm.submit(t)
        assert tm.wait(timeout=120), "probe stream timed out"
        makespan = time.monotonic() - t0
        landed = {"heavy": 0, "light": 0}
        for t in probes:
            landed["heavy" if t.pilot_uid == heavy.uid else "light"] += 1
        for p in pool.pilots:
            assert p.agent.wait_idle(timeout=120)
        return {"makespan_s": makespan, "probes_on": landed}
    finally:
        pool.close()


# -------------------- phase 2: per-kind straggler deadlines ---------------- #

def run_straggler(per_kind: bool, n_fast: int, fast_ms: float,
                  slow_mean_ms: float, probe_ms: float) -> dict:
    pilot = Pilot(PilotDescription(n_slots=2, per_kind_deadlines=per_kind,
                                   straggler_factor=3.0, name="strag"))
    try:
        # the slow kind's population is well known (e.g. from earlier
        # runs or cross-pilot seeding): mean ~0.15s, tight variance
        pilot.store.seed_durations("slow", slow_mean_ms / 1000.0, 1e-6, 10)
        done = threading.Event()
        left = [n_fast]
        lock = threading.Lock()

        def _one(_t):
            with lock:
                left[0] -= 1
                if left[0] == 0:
                    done.set()
        for _ in range(n_fast):
            pilot.agent.submit(translate(
                _kinded("fast", lambda: time.sleep(fast_ms / 1000.0)),
                (), {}), done_cb=_one)
        assert done.wait(60), "fast flood timed out"
        probe_done = threading.Event()
        pilot.agent.submit(
            translate(_kinded("slow",
                              lambda: time.sleep(probe_ms / 1000.0)),
                      (), {}),
            done_cb=lambda _t: probe_done.set())
        assert probe_done.wait(60), "slow probe timed out"
        time.sleep(0.1)                 # let any late monitor tick land
        replicas = sum(1 for uid in pilot.store.states()
                       if uid.startswith("replica."))
        return {"replicas": replicas,
                "deadline_s": pilot.agent._deadline("slow")}
    finally:
        pilot.close()


# --------------------------------- main ----------------------------------- #

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--longs", type=int, default=3,
                    help="long tasks queued on the heavy pilot")
    ap.add_argument("--long-ms", type=float, default=500.0)
    ap.add_argument("--shorts", type=int, default=8,
                    help="short tasks queued on the light pilot")
    ap.add_argument("--short-ms", type=float, default=30.0)
    ap.add_argument("--probes", type=int, default=6,
                    help="probe stream length (the measured makespan)")
    ap.add_argument("--probe-ms", type=float, default=20.0)
    ap.add_argument("--fast-tasks", type=int, default=60,
                    help="fast-kind flood size (phase 2)")
    ap.add_argument("--fast-ms", type=float, default=2.0)
    ap.add_argument("--slow-mean-ms", type=float, default=150.0,
                    help="seeded slow-kind EWMA mean (phase 2)")
    ap.add_argument("--slow-probe-ms", type=float, default=300.0,
                    help="healthy slow task runtime — above the floored "
                         "global p95 deadline, below 3x the kind mean")
    ap.add_argument("--repeats", type=int, default=3,
                    help="repeat each measurement, keep the best per mode "
                         "(container scheduling noise)")
    ap.add_argument("--min-makespan-ratio", type=float, default=0.0,
                    help="gate: cost-model probe-makespan speedup over "
                         "count-based least-loaded (0 = report only)")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent
                                         .parent / "BENCH_costmodel.json"))
    args = ap.parse_args(argv)
    reps = max(1, args.repeats)

    print("# phase 1: skewed-duration placement — predicted seconds vs "
          "counted slots")
    counted = min((run_placement(False, args.longs, args.long_ms,
                                 args.shorts, args.short_ms,
                                 args.probes, args.probe_ms)
                   for _ in range(reps)), key=lambda r: r["makespan_s"])
    priced = min((run_placement(True, args.longs, args.long_ms,
                                args.shorts, args.short_ms,
                                args.probes, args.probe_ms)
                  for _ in range(reps)), key=lambda r: r["makespan_s"])
    ratio = counted["makespan_s"] / priced["makespan_s"]
    print(f"  least-loaded (slots)  : {counted['makespan_s']:.3f}s "
          f"(probes on {counted['probes_on']})")
    print(f"  cost model (seconds)  : {priced['makespan_s']:.3f}s "
          f"(probes on {priced['probes_on']})")
    print(f"  probe-makespan speedup: {ratio:.2f}x")

    print("# phase 2: mixed-kind straggler — per-kind vs global-p95 "
          "deadlines")
    per_kind = global_p95 = None
    for _ in range(reps):                # worst case across repeats: the
        r = run_straggler(True, args.fast_tasks, args.fast_ms,   # per-kind
                          args.slow_mean_ms, args.slow_probe_ms)  # side must
        if per_kind is None or r["replicas"] > per_kind["replicas"]:
            per_kind = r                 # never replicate, not just usually
        r = run_straggler(False, args.fast_tasks, args.fast_ms,
                          args.slow_mean_ms, args.slow_probe_ms)
        if global_p95 is None or r["replicas"] > global_p95["replicas"]:
            global_p95 = r
    print(f"  per-kind deadlines : {per_kind['replicas']} spurious "
          f"replicas (deadline {per_kind['deadline_s']:.3f}s)")
    print(f"  global p95 baseline: {global_p95['replicas']} spurious "
          f"replicas")

    results = {
        "config": dict(vars(args)),
        "placement": {"least_loaded": counted, "cost_model": priced,
                      "ratio": ratio},
        "straggler": {"per_kind": per_kind, "global_p95": global_p95},
    }
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=2))
    print(f"wrote {out}")

    if priced["probes_on"]["light"] <= priced["probes_on"]["heavy"]:
        raise SystemExit(
            "REGRESSION: the cost model did not steer the probe stream "
            f"away from the heavy queue (landed {priced['probes_on']})")
    if per_kind["replicas"] != 0:
        raise SystemExit(
            "REGRESSION: per-kind deadlines spawned "
            f"{per_kind['replicas']} spurious replicas (want 0)")
    if global_p95["replicas"] < 1:
        raise SystemExit(
            "REGRESSION: the global-p95 baseline no longer reproduces "
            "the spurious replica — the scenario lost its discriminating "
            "power")
    if args.min_makespan_ratio and ratio < args.min_makespan_ratio:
        raise SystemExit(
            f"REGRESSION: cost-model makespan speedup {ratio:.2f}x "
            f"< required {args.min_makespan_ratio:.2f}x")
    return results


if __name__ == "__main__":
    main()
