"""Experiment 2 (paper Table III / Fig. 5-6): use-case scaling + overheads.

Colmena analog — ML-steered ensemble: per iteration a 1-slot "pre-process"
Python function, an N-slot SPMD "simulation" (fixed-duration compute), and a
1-slot "post-process" collector, with dataflow dependencies — exactly the
paper's heterogeneous workflow of single-core functions + multi-node MPI
executables.

IWP analog — tiling + inference pipeline: a tiling task splits an "image"
into tiles (CPU slot), then an SPMD inference function processes the tiles
on a device block; per-image 2-stage dataflow, many images concurrent.

Metrics exactly as defined in §V:
  TTX           — total time to execution (includes idle/wait);
  RP overhead   — runtime-system time: the wall-clock union of
                  SCHEDULED->RUNNING intervals from the unified StateStore
                  event stream (per-task sums double-counted concurrent
                  launches and retries, and implied overhead during
                  slot-idle gaps between dependent tasks);
  RPEX overhead — RP overhead + Parsl-side time (DFK DAG build, dependency
                  resolution, submission, shutdown).

``--utilization`` integrates per-slot timelines into the paper's Fig. 6
breakdown: Scheduled / Launching / Running / Idle fractions.
``--bulk`` enables the DFK bulk-submission mode (the paper's future work).
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax
import jax.numpy as jnp

from repro.core import (DataFlowKernel, PilotDescription, RPEXExecutor,
                        overhead_from_events, python_app, spmd_app,
                        TaskState)


def _mk_apps(sim_slots: int, sim_ms: float):
    @python_app
    def pre(i):
        return {"sim_id": i, "param": i * 0.1}

    @spmd_app(slots=sim_slots, jit=False)
    def simulate(mesh, spec):
        # fixed-duration "simulation": real jax compute sized to ~sim_ms
        x = jnp.ones((128, 128)) * spec["param"]
        t0 = time.monotonic()
        while (time.monotonic() - t0) * 1000 < sim_ms:
            x = jnp.tanh(x @ x.T / 128.0)
            x.block_until_ready()
        return {"sim_id": spec["sim_id"], "energy": float(x.sum())}

    @python_app
    def post(result):
        return result["energy"]

    return pre, simulate, post


def _mk_iwp(tile_slots: int, infer_ms: float):
    @python_app
    def tile(img_id):
        import numpy as np
        rng = np.random.default_rng(img_id)
        img = rng.standard_normal((8, 360, 360)).astype("float32")
        return img  # 8 tiles of 360x360 (the paper's tile size)

    @spmd_app(slots=tile_slots, jit=False)
    def infer(mesh, tiles):
        x = jnp.asarray(tiles).reshape(8, -1)
        t0 = time.monotonic()
        out = None
        while (time.monotonic() - t0) * 1000 < infer_ms:
            out = jax.nn.sigmoid(x @ x.T)
            out.block_until_ready()
        return float(out.mean())

    return tile, infer


def utilization_breakdown(tasks, n_slots, t0, t1):
    """Fig. 6: integrate slot-seconds per state over [t0, t1]."""
    occupied = {"Scheduled": 0.0, "Launching": 0.0, "Running": 0.0}
    for t in tasks:
        ts = t.timestamps
        slots = max(1, len(t.slot_ids))
        if "SCHEDULED" in ts and "LAUNCHING" in ts:
            occupied["Scheduled"] += slots * (ts["LAUNCHING"] - ts["SCHEDULED"])
        if "LAUNCHING" in ts and "RUNNING" in ts:
            occupied["Launching"] += slots * (ts["RUNNING"] - ts["LAUNCHING"])
        end = ts.get("DONE", ts.get("FAILED", ts.get("CANCELED")))
        if "RUNNING" in ts and end:
            occupied["Running"] += slots * (end - ts["RUNNING"])
    total = n_slots * (t1 - t0)
    # single-CPU container: worker threads timeshare a core, so measured
    # slot-seconds can slightly exceed capacity; normalize to 1.0
    scale = min(1.0, total / max(sum(occupied.values()), 1e-12))
    occupied = {k: v * scale for k, v in occupied.items()}
    idle = max(0.0, total - sum(occupied.values()))
    out = {k: v / total for k, v in occupied.items()}
    out["Idle"] = idle / total
    return out


def run_colmena(n_slots, n_iters, sim_slots, sim_ms, bulk, repeats=3):
    rows = []
    for _ in range(repeats):
        rpex = RPEXExecutor(PilotDescription(
            n_slots=n_slots, max_workers=max(32, n_slots)))
        pre, simulate, post = _mk_apps(sim_slots, sim_ms)
        t_init = time.monotonic()
        with DataFlowKernel(executors={"rpex": rpex}, bulk=bulk) as dfk:
            t0 = time.monotonic()
            results = []
            for i in range(n_iters):
                results.append(post(simulate(pre(i))))
            if bulk:
                dfk.flush()
            vals = [f.result() for f in results]
            t1 = time.monotonic()
            tasks = list(rpex.tmgr.tasks.values())
            util = utilization_breakdown(tasks, n_slots, t0, t1)
        t_end = time.monotonic()
        ttx = t1 - t0
        # RP overhead recomputed from the unified event stream: wall-clock
        # union of SCHEDULED->RUNNING intervals (no double-counting of
        # concurrent launches, no phantom overhead while slots idle
        # between dependent tasks)
        rp_oh = overhead_from_events(rpex.pool.events())
        # RPEX overhead: RP + DFK side (submit/DAG/shutdown wall time beyond
        # task execution)
        rpex_oh = rp_oh + max(0.0, (t_end - t_init) - ttx)
        rows.append((ttx, rp_oh, rpex_oh, util))
        rpex.shutdown()
    ttx = statistics.mean(r[0] for r in rows)
    ttx_sd = statistics.stdev([r[0] for r in rows]) if repeats > 1 else 0.0
    rp = statistics.mean(r[1] for r in rows)
    rpx = statistics.mean(r[2] for r in rows)
    util = rows[-1][3]
    return ttx, ttx_sd, rp, rpx, util


def run_iwp(n_slots, n_images, tile_slots, infer_ms, bulk, repeats=3):
    rows = []
    for _ in range(repeats):
        rpex = RPEXExecutor(PilotDescription(
            n_slots=n_slots, max_workers=max(32, n_slots)))
        tile, infer = _mk_iwp(tile_slots, infer_ms)
        t_init = time.monotonic()
        with DataFlowKernel(executors={"rpex": rpex}, bulk=bulk) as dfk:
            t0 = time.monotonic()
            futs = [infer(tile(i)) for i in range(n_images)]
            if bulk:
                dfk.flush()
            _ = [f.result() for f in futs]
            t1 = time.monotonic()
            tasks = list(rpex.tmgr.tasks.values())
            util = utilization_breakdown(tasks, n_slots, t0, t1)
        t_end = time.monotonic()
        ttx = t1 - t0
        rp_oh = overhead_from_events(rpex.pool.events())
        rpex_oh = rp_oh + max(0.0, (t_end - t_init) - ttx)
        rows.append((ttx, rp_oh, rpex_oh, util))
        rpex.shutdown()
    ttx = statistics.mean(r[0] for r in rows)
    ttx_sd = statistics.stdev([r[0] for r in rows]) if repeats > 1 else 0.0
    return ttx, ttx_sd, rows[-1][1], rows[-1][2], rows[-1][3]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", choices=["colmena", "iwp", "both"],
                    default="both")
    ap.add_argument("--nodes", type=int, nargs="+", default=[4, 8, 16, 32])
    ap.add_argument("--bulk", action="store_true")
    ap.add_argument("--utilization", action="store_true")
    ap.add_argument("--sim-ms", type=float, default=100.0)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)

    print("app,scaling,nodes,tasks,ttx_s,ttx_sd,rp_oh_s,rpex_oh_s,"
          "util_sched,util_launch,util_run,util_idle")
    for app in (["colmena", "iwp"] if args.app == "both" else [args.app]):
        for scaling in ("strong", "weak"):
            for n in args.nodes:
                if app == "colmena":
                    iters = 32 if scaling == "strong" else 2 * n
                    sim_slots = max(1, n // 4)
                    ttx, sd, rp, rpx, util = run_colmena(
                        n, iters, sim_slots, args.sim_ms, args.bulk,
                        args.repeats)
                    ntasks = iters * 3
                else:
                    imgs = 24 if scaling == "strong" else 2 * n
                    ttx, sd, rp, rpx, util = run_iwp(
                        n, imgs, max(1, n // 4), args.sim_ms, args.bulk,
                        args.repeats)
                    ntasks = imgs * 2
                print(",".join(str(round(x, 4)) if isinstance(x, float)
                               else str(x) for x in (
                    app, scaling, n, ntasks, ttx, sd, rp, rpx,
                    util["Scheduled"], util["Launching"], util["Running"],
                    util["Idle"])), flush=True)


if __name__ == "__main__":
    main()
