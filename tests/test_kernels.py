"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
plus hypothesis property tests on the SSD recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # fall back to the vendored shim
    from _propshim import given, settings, st

from repro.kernels import ops
from repro.kernels.ref import (attention_reference, ssd_reference,
                               ssd_sequential)
from repro.models.attention import blockwise_attention

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("B,Sq,Hq,Hkv,D", [
    (1, 32, 2, 2, 16),
    (2, 64, 4, 2, 32),
    (1, 100, 8, 8, 64),      # ragged seq (padding path)
    (2, 96, 6, 3, 16),
    (1, 128, 16, 4, 64),     # deep GQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_sweep(B, Sq, Hq, Hkv, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Sq, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sq, Hkv, D), dtype)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    for window, cap in [(0, 0.0), (13, 0.0), (0, 30.0), (13, 30.0)]:
        got = ops.flash_attention(q, k, v, causal=True, window=window,
                                  attn_softcap=cap, block_q=32, block_k=32)
        want = attention_reference(q, k, v, causal=True, window=window,
                                   attn_softcap=cap)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 32, 2, 8, 4, 8),
    (2, 64, 4, 16, 8, 16),
    (1, 128, 2, 32, 16, 32),
    (2, 48, 3, 8, 8, 16),    # chunk not dividing heads evenly is fine
])
def test_ssd_kernel_sweep(B, S, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B_ = jax.random.normal(ks[3], (B, S, N))
    C_ = jax.random.normal(ks[4], (B, S, N))
    y1, h1 = ops.ssd(x, dt, A, B_, C_, chunk)
    y2, h2 = ssd_sequential(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=5e-4, rtol=5e-4)


def test_flash_vjp_matches_reference_grads():
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (2, 40, 6, 16))
    k = jax.random.normal(ks[1], (2, 40, 3, 16))
    v = jax.random.normal(ks[2], (2, 40, 3, 16))
    do = jax.random.normal(ks[3], (2, 40, 6, 16))
    zero = jnp.zeros((), jnp.int32)
    for window, cap in [(0, 0.0), (11, 20.0)]:
        g1 = jax.grad(lambda q, k, v: (blockwise_attention(
            q, k, v, zero, True, window, cap, 16, 16) * do).sum(),
            argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(lambda q, k, v: (attention_reference(
            q, k, v, causal=True, window=window, attn_softcap=cap)
            * do).sum(), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5, rtol=5e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(0, 1000))
def test_ssd_chunking_invariance(b, h, seed):
    """Chunked == sequential for any chunk size dividing S (property)."""
    S, P, N = 32, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, S, h, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B_ = jax.random.normal(ks[3], (b, S, N))
    C_ = jax.random.normal(ks[4], (b, S, N))
    y_seq, h_seq = ssd_sequential(x, dt, A, B_, C_)
    for chunk in (4, 8, 16, 32):
        y_c, h_c = ssd_reference(x, dt, A, B_, C_, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_seq),
                                   atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_seq),
                                   atol=1e-3, rtol=1e-3)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_flash_blockwise_invariance(seed):
    """blockwise == reference for random block sizes (property)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 24, 4, 8))
    k = jax.random.normal(ks[1], (1, 24, 2, 8))
    v = jax.random.normal(ks[2], (1, 24, 2, 8))
    want = attention_reference(q, k, v, causal=True)
    rng = np.random.default_rng(seed)
    bq, bk = int(rng.integers(1, 25)), int(rng.integers(1, 25))
    got = blockwise_attention(q, k, v, jnp.zeros((), jnp.int32), True, 0,
                              0.0, bk, bq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)
