"""Optimizer, data pipeline, checkpointing, partition rules, MoE dispatch."""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # fall back to the vendored shim
    from _propshim import given, settings, st

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs import get_config, reduce_config
from repro.data.pipeline import DataConfig, ShardedLoader, SyntheticCorpus
from repro.models.moe import _positions, moe_ffn, moe_params_spec
from repro.optim import AdamW, cosine_schedule
from repro.sharding.partition import NULL_CTX, PartitionRules


# ------------------------------- optimizer ------------------------------ #

def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=lambda s: 0.1, weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_grad_clipping():
    opt = AdamW(lr=lambda s: 1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, gnorm = opt.update(params, {"w": jnp.full(3, 1e6)}, state)
    assert float(gnorm) > 1e5      # reported norm is pre-clip


def test_adamw_bf16_states():
    opt = AdamW(state_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    st_ = opt.init(params)
    assert st_.m["w"].dtype == jnp.bfloat16
    p2, st2, _ = opt.update(params, {"w": jnp.ones((4, 4), jnp.bfloat16)},
                            st_)
    assert st2.v["w"].dtype == jnp.bfloat16


def test_cosine_schedule():
    lr = cosine_schedule(1.0, 10, 100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(100))) < 1e-6


# --------------------------------- data --------------------------------- #

def test_corpus_deterministic_and_seekable():
    c = SyntheticCorpus(DataConfig(vocab_size=1000, seed=7))
    a = c.tokens_at(0, 5000)
    b = c.tokens_at(0, 5000)
    np.testing.assert_array_equal(a, b)
    # seek: arbitrary offset equals slice of longer read
    np.testing.assert_array_equal(c.tokens_at(1234, 100), a[1234:1334])
    assert a.min() >= 1 and a.max() < 1000


def test_loader_cursor_resume():
    cfg = DataConfig(vocab_size=500, seq_len=32, global_batch=2, seed=3)
    l1 = ShardedLoader(cfg)
    b1 = next(l1)
    b2 = next(l1)
    cur = l1.state()["cursor"]
    l1.close()
    # restart from the checkpointed cursor: next batch identical to b3
    l2 = ShardedLoader(cfg, start_cursor=cur)
    l1b = ShardedLoader(cfg)
    next(l1b), next(l1b)
    b3a = next(l1b)
    b3b = next(l2)
    np.testing.assert_array_equal(b3a["tokens"], b3b["tokens"])
    l2.close()
    l1b.close()


def test_targets_shift_by_one():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=1, seed=9)
    l = ShardedLoader(cfg)
    b = next(l)
    np.testing.assert_array_equal(b["tokens"][0, 1:], b["targets"][0, :-1])
    l.close()


# ------------------------------ checkpoint ------------------------------ #

def test_checkpoint_roundtrip_bf16(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.ones((3, 4), jnp.bfloat16) * 1.5,
            "b": {"c": jnp.arange(5, dtype=jnp.int32)},
            "d": np.float64(3.25)}
    ck.save(7, tree)
    step, out = ck.restore(tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_checkpoint_float64_host_leaf_keeps_dtype(tmp_path):
    """Regression: a float64 host-side leaf used to be routed through
    jnp.asarray, which truncates to float32 under default x32 (with a
    UserWarning); host leaves must round-trip through numpy exactly."""
    ck = Checkpointer(str(tmp_path))
    tree = {"host": np.linspace(0, 1, 7, dtype=np.float64),
            "scalar": np.float64(2.5),
            "dev": jnp.arange(4, dtype=jnp.float32)}
    ck.save(3, tree)
    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)   # truncation warns
        step, out = ck.restore(tree)
    assert step == 3
    assert out["host"].dtype == np.float64
    assert isinstance(out["host"], np.ndarray)
    assert not isinstance(out["host"], jax.Array)
    np.testing.assert_array_equal(out["host"], tree["host"])
    assert np.asarray(out["scalar"]).dtype == np.float64
    assert float(out["scalar"]) == 2.5
    assert out["dev"].dtype == jnp.float32            # device leaf intact
    np.testing.assert_array_equal(out["dev"], tree["dev"])


def test_checkpoint_gc_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ck.save(s, t)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_async_and_atomic(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(1, {"x": jnp.ones(1000)})
    ck.wait()
    assert ck.latest_step() == 1
    assert not list(tmp_path.glob("*.tmp"))


def test_checkpoint_structure_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"x": jnp.zeros(2)})
    with pytest.raises(ValueError):
        ck.restore({"x": jnp.zeros(2), "y": jnp.zeros(2)})


# ------------------------------ partitioning ----------------------------- #

def test_partition_fallbacks():
    import os
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    # synthetic 2D mesh shape check via spec_for on an abstract mesh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = PartitionRules()
    # degenerate mesh: everything falls back to replicated
    assert r.spec_for(("vocab", "embed_w"), (1000, 64), mesh) == \
        jax.sharding.PartitionSpec()


def test_partition_divisibility_logic():
    r = PartitionRules()

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    m = FakeMesh()
    # smollm: 15 heads cannot shard on model=16 -> falls to head_dim
    spec = r.spec_for(("embed_w", "heads", "head_dim"), (960, 15, 64), m)
    assert tuple(spec) == (("data",) if False else "data", None, "model") or \
        tuple(spec) == ("data", None, "model")
    # granite vocab 49155 not divisible by 16 -> replicated vocab dim
    spec2 = r.spec_for(("vocab", "embed_w"), (49155, 2048), m)
    assert tuple(spec2) == (None, "data")
    # qwen kv heads 4 not divisible -> None
    spec3 = r.spec_for(("embed_w", "kv_heads", "head_dim"), (4096, 4, 64), m)
    assert tuple(spec3) == ("data", None, "model")


# --------------------------------- MoE ----------------------------------- #

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 500))
def test_moe_positions_property(seed):
    """Slot positions are unique per expert and dense from 0 (property)."""
    rng = np.random.default_rng(seed)
    G, T, K, E = 2, 16, 2, 4
    idx = jnp.asarray(rng.integers(0, E, size=(G, T, K)))
    pos = np.asarray(_positions(idx, E, C=T * K))
    for g in range(G):
        for e in range(E):
            got = sorted(pos[g][np.asarray(idx[g]) == e].tolist())
            assert got == list(range(len(got)))   # dense, unique, from 0


def test_moe_einsum_gather_parity():
    """The zero-FLOP gather dispatch computes the same function as the
    GShard einsum dispatch."""
    import dataclasses
    cfg = reduce_config(get_config("qwen3-moe-235b-a22b"))
    key = jax.random.PRNGKey(0)
    d, e, f = cfg.d_model, cfg.num_experts, cfg.d_ff
    w = {"router": jax.random.normal(key, (d, e)) * 0.02,
         "wi": jax.random.normal(key, (e, d, f)) * 0.02,
         "wg": jax.random.normal(key, (e, d, f)) * 0.02,
         "wo": jax.random.normal(key, (e, f, d)) * 0.02}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
    cfg_e = dataclasses.replace(cfg, moe_dispatch="einsum")
    cfg_g = dataclasses.replace(cfg, moe_dispatch="gather")
    y1, a1 = moe_ffn(x, w, cfg_e, NULL_CTX)
    y2, a2 = moe_ffn(x, w, cfg_g, NULL_CTX)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)
