"""WorkerTransport + serializer: the process/GIL boundary.

What must hold:
  * serializer round-trips: module-level functions by reference; closures,
    lambdas and nested functions by value; exceptions with their remote
    traceback; jax arrays/pytrees host-transferred to numpy; graceful
    degradation for what cannot cross (results -> placeholder, globals ->
    dropped, exceptions -> RemoteError carrier);
  * the local pool is bounded AND reaped: a 64-task burst does not leave
    64 live threads at steady state, and the pool regrows afterwards;
  * transport="proc" runs python/bash bodies in worker processes with
    identical task semantics: results, remote exceptions (traceback
    preserved), unpicklable results completing (journal line slimmed),
    spmd staying inproc, checkpoint save/restore and cooperative
    preemption proxied over the control pipe.
"""
import pickle
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DataFlowKernel, Pilot, PilotDescription,
                        RemoteError, ResourceSpec, RPEXExecutor, TaskState,
                        UnserializableResult, bash_app, python_app,
                        spmd_app, translate)
from repro.core import serializer
from repro.core.transport import InprocTransport, ProcessTransport


# ------------------------------ serializer ------------------------------- #

def test_module_level_function_roundtrips_by_reference():
    import os.path
    fn = serializer.loads(serializer.dumps(os.path.join))
    assert fn is os.path.join


def test_closure_roundtrips_by_value():
    base = 41

    def add(x):
        return base + x

    fn = serializer.loads(serializer.dumps(add))
    assert fn(1) == 42


def test_lambda_roundtrips():
    fn = serializer.loads(serializer.dumps(lambda x, y=3: x * y))
    assert fn(4) == 12
    assert fn(4, y=5) == 20


def test_nested_function_with_module_global():
    # `time` lives in this module's globals; it must travel as an import
    # reference, not a pickled module
    def stamp():
        return time.monotonic() >= 0

    fn = serializer.loads(serializer.dumps(stamp))
    assert fn() is True


_MODULE_LOCK = threading.Lock()        # an unpicklable module global


def test_unserializable_global_is_dropped_not_fatal():
    # a referenced global that cannot pickle is probed and dropped (a
    # call-time NameError on the branch that uses it, never a submit
    # failure); the rest of the function still ships and runs
    def uses_global(x):
        if x > 10**9:
            return _MODULE_LOCK        # never taken
        return x * 2

    fn = serializer.loads(serializer.dumps(uses_global))
    assert fn(4) == 8
    with pytest.raises(NameError):
        fn(10**9 + 1)


def test_exception_roundtrip_preserves_remote_traceback():
    def deep():
        raise ValueError("remote kaboom")

    try:
        deep()
    except ValueError as e:
        blob = serializer.pack_exception(e)
    exc = serializer.unpack_exception(blob)
    assert isinstance(exc, ValueError)
    assert "remote kaboom" in str(exc)
    assert "deep" in exc.remote_traceback
    assert "deep" in str(exc.__cause__)   # renders as the causal chain


def test_unpicklable_exception_degrades_to_remote_error():
    class Gnarly(Exception):              # nested class: not importable
        def __init__(self, a, b):
            super().__init__(f"{a}/{b}")
            self.lock = threading.Lock()  # and unpicklable state

    try:
        raise Gnarly("x", "y")
    except Exception as e:
        blob = serializer.pack_exception(e)
    exc = serializer.unpack_exception(blob)
    assert isinstance(exc, RemoteError)
    assert "Gnarly" in str(exc) and "x/y" in str(exc)
    assert "Gnarly" in exc.remote_traceback


def test_jax_array_crosses_as_numpy():
    arr = jnp.arange(6, dtype=jnp.float32)
    out = serializer.loads(serializer.dumps(arr))
    assert isinstance(out, np.ndarray)
    np.testing.assert_array_equal(out, np.arange(6, dtype=np.float32))


def test_jax_pytree_leaves_host_transferred():
    tree = {"w": jnp.ones((2, 2)), "meta": [jnp.arange(3), "tag", 7]}
    out = serializer.loads(serializer.dumps(tree))
    assert isinstance(out["w"], np.ndarray)
    assert isinstance(out["meta"][0], np.ndarray)
    assert out["meta"][1:] == ["tag", 7]


def test_pack_result_degrades_gracefully():
    blob, info = serializer.pack_result({"ok": 1})
    assert blob is not None and info is None
    blob, info = serializer.pack_result(threading.Lock())
    assert blob is None
    assert info[0] == "lock" and "lock" in info[1]


# ----------------------------- pool hygiene ------------------------------ #

def _run_burst(pilot, n, sleep_s):
    done = threading.Event()
    remaining = [n]

    def cb(t):
        remaining[0] -= 1
        if remaining[0] == 0:
            done.set()

    for _ in range(n):
        t = translate(lambda s=sleep_s: time.sleep(s), (), {})
        t.transition(TaskState.TRANSLATED, pilot.store)
        assert pilot.agent.submit(t, done_cb=cb)
    assert done.wait(30)


@pytest.mark.timeout(60)
def test_burst_does_not_leave_threads_at_steady_state():
    """The hygiene regression: 64 concurrent tasks grow the pool to ~64
    threads, and idle reaping shrinks it back instead of leaking them
    for the agent's lifetime."""
    p = Pilot(PilotDescription(n_slots=64, max_workers=64,
                               worker_idle_s=0.3))
    try:
        _run_burst(p, 64, 0.3)
        tr = p.agent.transport
        assert tr.n_threads > 8          # the burst really fanned out
        deadline = time.monotonic() + 10
        while tr.n_threads > 0 and time.monotonic() < deadline:
            time.sleep(0.1)
        assert tr.n_threads == 0         # every idle worker reaped
        _run_burst(p, 8, 0.05)           # and the pool regrows on demand
    finally:
        p.close()


@pytest.mark.timeout(60)
def test_reaped_pool_still_drains_new_work():
    tr = InprocTransport(max_workers=4, idle_s=0.2)
    ran = []
    tr.start(lambda item: ran.append(item), executor=None)
    for i in range(4):
        tr.dispatch(i)
    deadline = time.monotonic() + 5
    while tr.n_threads > 0 and time.monotonic() < deadline:
        time.sleep(0.05)
    assert tr.n_threads == 0
    tr.dispatch("after-reap")
    deadline = time.monotonic() + 5
    while "after-reap" not in ran and time.monotonic() < deadline:
        time.sleep(0.02)
    assert "after-reap" in ran
    tr.shutdown()


@pytest.mark.timeout(60)
def test_dispatch_after_shutdown_raises_instead_of_stranding():
    """Regression: a dispatch racing shutdown() could spawn a fresh
    thread that consumed a leftover poison pill and retired, leaving the
    task in the queue forever with no thread to drain it.  A closed pool
    must refuse loudly instead."""
    tr = InprocTransport(max_workers=4, idle_s=30.0)
    ran = []
    tr.start(lambda item: ran.append(item), executor=None)
    tr.dispatch("before")
    deadline = time.monotonic() + 5
    while "before" not in ran and time.monotonic() < deadline:
        time.sleep(0.02)
    assert "before" in ran
    tr.shutdown()
    with pytest.raises(RuntimeError, match="shut down"):
        tr.dispatch("stranded")
    assert ran == ["before"]             # nothing silently swallowed
    # shutdown is idempotent and the refusal persists
    tr.shutdown()
    with pytest.raises(RuntimeError, match="shut down"):
        tr.dispatch("still-stranded")


# ------------------------------ proc mode -------------------------------- #

def _proc_rpex(**kw):
    return RPEXExecutor(PilotDescription(n_slots=2, transport="proc", **kw))


@pytest.mark.timeout(120)
def test_proc_mode_runs_python_and_bash_bodies():
    rpex = _proc_rpex()
    try:
        base = 100

        @python_app
        def closure_add(a):
            return base + a

        @bash_app
        def greet(name):
            return f"echo hello-{name}"

        with DataFlowKernel(executors={"rpex": rpex}):
            fs = [closure_add(i) for i in range(8)]
            g = greet("proc")
            assert [f.result(timeout=30) for f in fs] == [100 + i
                                                          for i in range(8)]
            assert g.result(timeout=30).strip() == "hello-proc"
    finally:
        rpex.shutdown()


@pytest.mark.timeout(120)
def test_proc_mode_remote_exception_preserves_traceback():
    rpex = _proc_rpex()
    try:
        @python_app
        def boom():
            raise KeyError("remote-key")

        with DataFlowKernel(executors={"rpex": rpex}):
            f = boom()
            with pytest.raises(KeyError) as ei:
                f.result(timeout=30)
        assert "remote-key" in str(ei.value)
        assert "boom" in ei.value.remote_traceback
    finally:
        rpex.shutdown()


@pytest.mark.timeout(120)
def test_proc_mode_unpicklable_result_completes_and_journal_slims(tmp_path):
    """The docs/performance.md contract, extended across the boundary: a
    result that cannot cross completes the task with a placeholder, and
    the journal line is slimmed rather than the write failing."""
    journal = tmp_path / "proc.jsonl"
    rpex = RPEXExecutor(PilotDescription(n_slots=2, transport="proc",
                                         journal=str(journal)))
    try:
        @python_app
        def make_lock():
            import threading as th
            return th.Lock()

        with DataFlowKernel(executors={"rpex": rpex}):
            f = make_lock()
            out = f.result(timeout=30)
        assert isinstance(out, UnserializableResult)
        assert out.type_name == "lock"
        assert f.task.state == TaskState.DONE
    finally:
        rpex.shutdown()
    import json
    recs = [json.loads(l) for l in journal.read_text().splitlines() if l]
    done = [r for r in recs if r.get("uid") == f.task.uid
            and r.get("state") == "DONE"]
    assert done and all("result" not in r for r in done)


@pytest.mark.timeout(120)
def test_proc_mode_spmd_stays_inproc():
    rpex = RPEXExecutor(PilotDescription(n_slots=2, transport="proc"))
    try:
        @spmd_app(slots=2)
        def double(mesh, x):
            return x * 2.0

        with DataFlowKernel(executors={"rpex": rpex}):
            f = double(jnp.ones((4,)))
            np.testing.assert_allclose(np.asarray(f.result(timeout=60)),
                                       2.0 * np.ones((4,)))
        assert f.task.inproc_only
    finally:
        rpex.shutdown()


@pytest.mark.timeout(120)
def test_proc_mode_unserializable_body_falls_back_inproc():
    """A body the serializer cannot ship (closure over a live lock that
    it *uses*) degrades to in-process execution instead of failing."""
    rpex = _proc_rpex()
    try:
        lock = threading.Lock()

        @python_app
        def guarded(x, _l=lock):       # unpicklable default: cannot ship
            with _l:
                return x + 1

        with DataFlowKernel(executors={"rpex": rpex}):
            assert guarded(41).result(timeout=30) == 42
    finally:
        rpex.shutdown()


# -------------------- proc checkpoint / preemption ----------------------- #

def _ckpt_body(n, ckpt=None):
    got = ckpt.restore()
    start = got[0] + 1 if got is not None else 0
    state = list(got[1]) if got is not None else []
    for step in range(start, n):
        state.append(step)
        ckpt.save(step, state)
        if got is None and step == 2:
            raise RuntimeError("induced crash after step 2")
    return (start, state)


@pytest.mark.timeout(120)
def test_proc_checkpoint_save_and_resume_across_retry():
    """First attempt saves steps 0..2 through the pipe then dies; the
    retry restores parent-side step 2 and resumes at 3 — each step runs
    exactly once, proving save/restore proxying is durable."""
    p = Pilot(PilotDescription(n_slots=2, transport="proc"))
    try:
        t = translate(_ckpt_body, (6,), {},
                      ResourceSpec(checkpointable=True), max_retries=1)
        t.transition(TaskState.TRANSLATED, p.store)
        done = threading.Event()
        box = {}

        def cb(task):
            box["state"] = task.state
            box["result"] = task.result
            done.set()

        assert p.agent.submit(t, done_cb=cb)
        assert done.wait(60)
        assert box["state"] == TaskState.DONE
        start, steps = box["result"]
        assert start == 3                # resumed, not recomputed
        assert steps == [0, 1, 2, 3, 4, 5]
        assert t.retries == 1
    finally:
        p.close()


@pytest.mark.timeout(120)
def test_proc_cooperative_preempt_crosses_the_pipe():
    """agent.preempt() on a proc-mode task forwards the flag down the
    worker pipe; the body unwinds at its next save with the step durable
    parent-side, and a resubmission resumes from it."""
    p = Pilot(PilotDescription(n_slots=2, transport="proc"))
    try:
        def slow_ckpt(n, ckpt=None):
            got = ckpt.restore()
            start = got[0] + 1 if got is not None else 0
            state = list(got[1]) if got is not None else []
            for step in range(start, n):
                time.sleep(0.05)
                state.append(step)
                ckpt.save(step, state)
            return (start, state)

        t = translate(slow_ckpt, (20,), {},
                      ResourceSpec(checkpointable=True))
        t.transition(TaskState.TRANSLATED, p.store)
        done = threading.Event()
        box = {}

        def cb(task):
            box["result"] = task.result
            done.set()

        handed = threading.Event()

        def handoff(task, task_cb):
            if task is None:
                return               # overtaken by a normal finish
            box["handed"] = task
            box["cb"] = task_cb
            handed.set()

        assert p.agent.submit(t, done_cb=cb)
        deadline = time.monotonic() + 30
        while p.ckpt.step(t.ckpt_key) is None:
            assert time.monotonic() < deadline, "no checkpoint ever saved"
            time.sleep(0.02)
        assert p.agent.preempt(t.uid, handoff)
        assert handed.wait(30), "preempt never unwound the remote body"
        saved = p.ckpt.step(t.ckpt_key)
        assert saved is not None and saved >= 0
        assert box["handed"].state == TaskState.TRANSLATED

        # resubmit the handed-off task: it must resume past the saved step
        assert p.agent.submit(box["handed"], done_cb=box["cb"] or cb)
        assert done.wait(60)
        start, steps = box["result"]
        assert start == saved + 1        # resumed from the preempt point
        assert steps == list(range(20))  # and every step ran exactly once
    finally:
        p.close()


# ----------------------------- mixed pools ------------------------------- #

@pytest.mark.timeout(120)
def test_heterogeneous_pool_mixes_transports():
    """One pool, one executor: an inproc device pilot for spmd next to a
    proc CPU pilot for python — both kinds complete."""
    rpex = RPEXExecutor([
        PilotDescription(n_slots=2, kinds=("spmd",), name="dev"),
        PilotDescription(n_slots=2, kinds=("python", "bash"),
                         transport="proc", name="cpu"),
    ])
    try:
        @spmd_app(slots=2)
        def scale(mesh, x):
            return x * 3.0

        @python_app
        def pyadd(a, b):
            return a + b

        with DataFlowKernel(executors={"rpex": rpex}):
            fs = scale(jnp.ones((4,)))
            fp = pyadd(20, 22)
            np.testing.assert_allclose(np.asarray(fs.result(timeout=60)),
                                       3.0 * np.ones((4,)))
            assert fp.result(timeout=30) == 42
        dev, cpu = rpex.pool.pilots
        assert isinstance(dev.agent.transport, InprocTransport)
        assert isinstance(cpu.agent.transport, ProcessTransport)
    finally:
        rpex.shutdown()


def test_inproc_default_and_transport_validation():
    d = PilotDescription()
    assert d.transport == "inproc"
    with pytest.raises(ValueError):
        from repro.core import make_transport
        make_transport("carrier-pigeon")
