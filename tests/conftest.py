"""Shared test harness pieces.

``@pytest.mark.timeout(seconds)`` — wall-clock cap for a single test,
enforced with SIGALRM (no third-party plugin in the container).  Used to
cap journal-heavy StateStore tests so a write-behind deadlock fails fast
with a traceback instead of wedging the whole CI job.  If pytest-timeout
is installed it takes over (its hook runs instead); on platforms without
SIGALRM the marker is a no-op.
"""
import signal

import pytest

_HAS_ALARM = hasattr(signal, "SIGALRM")
_HAS_PLUGIN = False
try:                                    # defer to the real plugin if present
    import pytest_timeout  # noqa: F401
    _HAS_PLUGIN = True
except ImportError:
    pass


@pytest.hookimpl(wrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout")
    if marker is None or not _HAS_ALARM or _HAS_PLUGIN:
        return (yield)
    seconds = int(marker.args[0]) if marker.args else 60

    def _expired(signum, frame):
        raise TimeoutError(
            f"test exceeded timeout marker ({seconds}s): {item.nodeid}")

    old = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(seconds)
    try:
        return (yield)
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)
