"""Shared test harness pieces.

``@pytest.mark.timeout(seconds)`` — wall-clock cap for a single test,
enforced with SIGALRM (no third-party plugin in the container).  Used to
cap journal-heavy StateStore tests so a write-behind deadlock fails fast
with a traceback instead of wedging the whole CI job.  If pytest-timeout
is installed it takes over (its hook runs instead); on platforms without
SIGALRM the marker is a no-op.

Lock-order watchdog (``REPRO_LOCK_WATCHDOG=1``): when the env switch is
set, importing ``repro.core`` installs the instrumented-lock mode (see
``repro.analysis.watchdog``) and this conftest turns the whole suite
into a race detector — at session end the merged per-thread acquisition
graph must be acyclic, no lock may exceed the hold-time ceiling
(``REPRO_LOCK_HOLD_CEILING_S``, default 2s), and every observed task
transition must be a declared STATE_MACHINE edge.  Findings fail the
run; ``REPRO_LOCK_WATCHDOG_OUT`` additionally writes the graph report.
"""
import os
import signal

import pytest

_HAS_ALARM = hasattr(signal, "SIGALRM")
_HAS_PLUGIN = False
try:                                    # defer to the real plugin if present
    import pytest_timeout  # noqa: F401
    _HAS_PLUGIN = True
except ImportError:
    pass


@pytest.hookimpl(wrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout")
    if marker is None or not _HAS_ALARM or _HAS_PLUGIN:
        return (yield)
    seconds = int(marker.args[0]) if marker.args else 60

    def _expired(signum, frame):
        raise TimeoutError(
            f"test exceeded timeout marker ({seconds}s): {item.nodeid}")

    old = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(seconds)
    try:
        return (yield)
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def pytest_sessionfinish(session, exitstatus):
    """Fail the run on watchdog findings when instrumented locks are on."""
    try:
        from repro.analysis import watchdog
    except ImportError:
        return
    wd = watchdog.active()
    if wd is None:
        return
    ceiling = float(
        os.environ.get("REPRO_LOCK_HOLD_CEILING_S",
                       watchdog.DEFAULT_HOLD_CEILING_S))
    findings = wd.check(hold_ceiling_s=ceiling)
    out = os.environ.get("REPRO_LOCK_WATCHDOG_OUT")
    if out:
        wd.write_report(out)
    snap = wd.snapshot()
    reporter = session.config.pluginmanager.get_plugin("terminalreporter")
    if reporter is not None:
        reporter.write_line(
            f"lock watchdog: {snap['locks']} locks, "
            f"{snap['edge_count']} order edges, "
            f"{sum(snap['acquisitions'].values())} acquisitions, "
            f"max hold {snap['max_hold_ms_overall']:.1f} ms")
    if findings:
        for f in findings:
            msg = f"{f.code} {f.message}"
            if reporter is not None:
                reporter.write_line(msg, red=True)
            else:  # pragma: no cover - no terminal plugin
                print(msg)
        session.exitstatus = 3
