"""Trip-count-aware HLO cost model vs hand-computable programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.hlo_cost import analyze


def _compile_text(f, *avals):
    return jax.jit(f).lower(*avals).compile().as_text()


def test_scan_flops_scaled_by_trip_count():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    a = analyze(_compile_text(f, x, w))
    assert a["flops"] == 2 * 10 * 64 ** 3


def test_nested_scan_multiplies():
    def g(x):
        def outer(c, _):
            def inner(c2, _):
                return jnp.sin(c2 @ c2), None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        c, _ = jax.lax.scan(outer, x, None, length=3)
        return c
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    a = analyze(_compile_text(g, x))
    assert a["flops"] == 2 * 15 * 32 ** 3


def test_grad_counts_fwd_and_bwd():
    def h(x, w):
        return jnp.tanh(x @ w).sum()
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    a = analyze(_compile_text(jax.grad(h, argnums=(0, 1)), x, w))
    assert a["flops"] == 3 * 2 * 64 ** 3        # fwd + two bwd matmuls


def test_bytes_exclude_plumbing():
    def f(x):
        def body(c, _):
            return c * 2.0, None
        c, _ = jax.lax.scan(body, x, None, length=100)
        return c
    x = jax.ShapeDtypeStruct((1024,), jnp.float32)
    a = analyze(_compile_text(f, x))
    # 100 iterations x (read 4KB + write 4KB) ~ 800KB; plumbing-free
    assert 0.5e6 < a["bytes"] < 5e6


def test_collective_census_ring_costs():
    hlo = """
HloModule m

ENTRY %main (a: f32[1024]) -> f32[1024] {
  %a = f32[1024]{0} parameter(0)
  %ag = f32[4096]{0} all-gather(%a), replica_groups=[2,4], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%a), replica_groups=[1,8], to_apply=%add
  ROOT %cp = f32[1024]{0} collective-permute(%a), source_target_pairs={{0,1}}
}
"""
    a = analyze(hlo)
    c = a["collectives"]
    assert c["all-gather"]["moved_bytes"] == 4096 * 4 * 3 / 4
    assert c["all-reduce"]["moved_bytes"] == 2 * 1024 * 4 * 7 / 8
    assert c["collective-permute"]["moved_bytes"] == 1024 * 4
