"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step + one decode step on CPU; asserts shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, cells, get_config, reduce_config
from repro.models import model as M
from repro.models import transformer as T
from repro.optim import AdamW


def _batch(cfg, key, B=2, S=16):
    nfe = cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0
    b = {"tokens": jax.random.randint(key, (B, S - nfe), 0, cfg.vocab_size),
         "targets": jax.random.randint(key, (B, S - nfe), 0, cfg.vocab_size),
         "loss_mask": jnp.ones((B, S - nfe))}
    if nfe:
        b["patches"] = jax.random.normal(key, (B, nfe, cfg.d_model),
                                         jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduce_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    batch = _batch(cfg, key)
    loss, metrics = M.loss_fn(cfg, params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    opt = AdamW()
    step = jax.jit(M.make_train_step(cfg, opt))
    p2, o2, m2 = step(params, opt.init(params), batch)
    assert not bool(jnp.isnan(m2["loss"]))
    assert float(m2["grad_norm"]) > 0
    # parameters actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, f"{arch}: train step changed nothing"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Greedy decode state built token-by-token matches a fresh prefill."""
    cfg = reduce_config(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    nfe = cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0
    if nfe:  # decode-only check for vlm: feed text tokens only
        pass
    prefill = jax.jit(M.make_prefill_step(cfg))
    decode = jax.jit(M.make_decode_step(cfg))
    batch = {"tokens": toks}
    if nfe:
        batch["patches"] = jnp.zeros((B, nfe, cfg.d_model), jnp.bfloat16)
    logits_p, _ = prefill(params, batch)
    assert logits_p.shape == (B, 1, cfg.vocab_size)
    # token-by-token decode over the same prompt (text part only)
    cache = T.init_cache(cfg, B, 32)
    lg = None
    for t in range(S):
        lg, cache = decode(params, toks[:, t:t + 1], cache, jnp.int32(t))
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(lg).any())
    if not nfe:
        # same last-token distribution as prefill (pure-text archs).
        # MoE archs get looser tolerance: capacity-based dropping differs
        # between grouped prefill and single-token decode by design.
        import numpy as np
        tol = 0.25 if cfg.num_experts else 0.1
        np.testing.assert_allclose(
            np.asarray(lg, np.float32), np.asarray(logits_p, np.float32),
            atol=tol, rtol=tol)
        assert (np.asarray(lg).argmax(-1) ==
                np.asarray(logits_p).argmax(-1)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_init(arch):
    cfg = reduce_config(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n_init = sum(l.size for l in jax.tree.leaves(params))
    assert n_init == cfg.param_count(), \
        f"{arch}: analytic {cfg.param_count()} != init {n_init}"


def test_cells_cover_40():
    total = sum(len(cells(a)) for a in ARCHS)
    assert total == 40
    runs = sum(1 for a in ARCHS for _, s in cells(a) if s == "RUN")
    skips = total - runs
    assert runs == 33 and skips == 7
