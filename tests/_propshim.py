"""Minimal hypothesis stand-in so property tests run where hypothesis is
not installed.

Implements exactly the strategy surface this suite uses — ``integers``,
``lists``, ``tuples``, ``sampled_from`` — plus ``given``/``settings``.
Examples are generated from a fixed seed per example index, so runs are
deterministic and a falsifying example is reproducible.  When hypothesis is
available the real library is used instead (see the try/except import in
each test module); this shim is a fallback, not a replacement — it does no
shrinking and no coverage-guided generation.
"""
from __future__ import annotations

import functools
import random
from types import SimpleNamespace

_DEFAULT_EXAMPLES = 100
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def _tuples(*strategies):
    return _Strategy(lambda r: tuple(s.draw(r) for s in strategies))


def _lists(elements, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 10

    def draw(r):
        return [elements.draw(r) for _ in range(r.randint(min_size, hi))]
    return _Strategy(draw)


st = SimpleNamespace(integers=_integers, sampled_from=_sampled_from,
                     tuples=_tuples, lists=_lists)


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            for i in range(n):
                rng = random.Random(_SEED + i)
                example = [s.draw(rng) for s in strategies]
                try:
                    fn(*args, *example, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i}: {example!r}") from e
        # pytest must see the zero-arg wrapper signature, not the wrapped
        # function's generated parameters (it would hunt for fixtures)
        del wrapper.__wrapped__
        wrapper._max_examples = _DEFAULT_EXAMPLES
        return wrapper
    return deco


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
