"""Task checkpointing + cooperative preemption: the CheckpointStore's
journal/payload/GC/compaction behavior, checkpoint-resumed straggler
replicas, preempt-and-migrate of RUNNING tasks, partial restarts, and the
straggler-path bugfixes that ride along:

  * `_deadline` p95 over the *recent* durations (it used to sort the
    whole deque then slice, taking the 100 largest samples — the deadline
    drifted to the all-time max and replicas stopped firing);
  * a FAILED replica with retries remaining is dropped, never requeued as
    an ordinary task;
  * replica records keep the translator's sticky/affinity/kind stamps.

The hard invariant throughout: checkpointed steps execute exactly once
across preempt / migrate / restart (replicas may legitimately overlap the
leader's in-flight step — first finisher wins)."""
import itertools
import json
import threading
import time

import pytest

from repro.core import (Checkpoint, CheckpointStore, DataFlowKernel, Pilot,
                        PilotDescription, PilotPool, ResourceSpec,
                        RPEXExecutor, SlotScheduler, SPMDFunctionExecutor,
                        StateStore, TaskPreempted, TaskState, python_app,
                        spmd_app, translate)
from repro.core.agent import Agent


# --------------------------- CheckpointStore ----------------------------- #

def test_checkpoint_store_save_latest_discard():
    store = StateStore()                     # journal-less: memory payloads
    ck = CheckpointStore(store)
    assert ck.latest("k") is None and not ck.has("k")
    assert ck.save("k", 0, {"s": 0})
    assert ck.save("k", 3, {"s": 3})
    assert ck.latest("k") == (3, {"s": 3})
    assert ck.step("k") == 3
    # steps are monotonic: a lagging writer cannot roll the key back
    assert not ck.save("k", 1, {"s": 1})
    assert ck.latest("k") == (3, {"s": 3})
    ck.discard("k")
    assert ck.latest("k") is None and ck.step("k") is None
    # save/gc markers land in the unified event stream
    evs = [e for e in store.events_snapshot()
           if e.get("event") == "CHECKPOINT"]
    assert [e.get("gc", False) for e in evs] == [False, False, True]


def test_checkpoint_store_journal_replay(tmp_path):
    """A restarted store replays its checkpoint map from CHECKPOINT
    events and lazy-loads the payload from the on-disk snapshot."""
    j = str(tmp_path / "j.jsonl")
    s = StateStore(j)
    ck = CheckpointStore(s)
    ck.save("wf/task:0", 0, {"w": [0]})
    ck.save("wf/task:0", 4, {"w": [0, 4]})
    ck.save("gone", 2, "x")
    ck.discard("gone")
    assert s.flush(timeout=10)
    s.close()

    s2 = StateStore(j)
    ck2 = CheckpointStore(s2)
    assert ck2.step("wf/task:0") == 4
    assert ck2.latest("wf/task:0") == (4, {"w": [0, 4]})
    assert ck2.latest("gone") is None     # gc marker replayed
    s2.close()

    # payload GC: one live payload file per key (older steps unlinked,
    # discarded keys gone entirely)
    pkls = list((tmp_path / "j.jsonl.ckpt").glob("*.pkl"))
    assert len(pkls) == 1 and ".4." in pkls[0].name


def test_checkpoint_events_collapse_under_compaction(tmp_path):
    """A long task journals one CHECKPOINT per saved step; compaction
    keeps only the latest per live key and drops gc'd keys, so the
    compacted journal stays O(live keys), and a restart still resumes
    from the right step."""
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j), compact_min_lines=64, compact_factor=2)
    ck = CheckpointStore(s)
    for step in range(300):
        ck.save("live", step, {"s": step})
        ck.save("done", step, step)
    ck.discard("done")
    s.flush(timeout=10)
    s.close()

    lines = [json.loads(l) for l in j.read_text().splitlines()]
    assert any(r.get("event") == "_SNAPSHOT" for r in lines)
    ckpt_lines = [r for r in lines if r.get("event") == "CHECKPOINT"]
    keys = [r.get("key") for r in ckpt_lines if not r.get("gc")]
    # 600 saves happened; each compaction collapses history to one line
    # per live key, so only the post-last-compaction tail remains
    assert len(lines) < 200, f"journal never compacted: {len(lines)}"
    assert keys.count("live") < 64, "CHECKPOINT events were not collapsed"

    s2 = StateStore(str(j))
    ck2 = CheckpointStore(s2)
    assert ck2.step("live") == 299
    assert ck2.latest("live") == (299, {"s": 299})
    assert not ck2.has("done")
    s2.close()


def test_unpicklable_save_keeps_previous_durable_payload(tmp_path):
    """A newer save whose state cannot be pickled must not delete the
    previous step's payload: the journal still points at it, and a
    post-crash replay resumes from there (in-process, the newer step is
    served from memory)."""
    j = str(tmp_path / "j.jsonl")
    s = StateStore(j)
    ck = CheckpointStore(s)
    ck.save("k", 0, {"fine": 0})
    ck.save("k", 1, {"bad": threading.Lock()})     # unpicklable
    assert ck.latest("k")[0] == 1                  # in-process: memory
    pkls = list((tmp_path / "j.jsonl.ckpt").glob("*.pkl"))
    assert len(pkls) == 1 and ".0." in pkls[0].name, \
        "the durable step-0 payload was GC'd by the failed step-1 save"
    # a later successful save still GCs the old file
    ck.save("k", 2, {"fine": 2})
    pkls = list((tmp_path / "j.jsonl.ckpt").glob("*.pkl"))
    assert len(pkls) == 1 and ".2." in pkls[0].name
    assert s.flush(timeout=10)
    s.close()
    s2 = StateStore(j)
    ck2 = CheckpointStore(s2)
    # replay agrees with what restore() can deliver: step 1 was never
    # journaled (no durable payload), steps 0 and 2 were
    assert ck2.latest("k") == (2, {"fine": 2})
    s2.close()


def test_spawn_replica_rolls_back_when_agent_refuses():
    """A deadline firing while the agent is draining must not leave
    stale _replicas bookkeeping: the refused replica's entries roll
    back, so the leader stays eligible for the drain's preempt sweep."""
    pilot = Pilot(PilotDescription(n_slots=2, straggler_factor=1e9))
    try:
        lock, log = threading.Lock(), []
        t = translate(_resumable, (6, 0.05, log, lock), {},
                      ResourceSpec(checkpointable=True))
        pilot.agent.submit(t)
        time.sleep(0.12)                   # running, ctx live
        pilot.agent.stop_accepting()
        rep = pilot.agent._spawn_replica(t)
        assert rep.uid not in pilot.agent._replicas
        assert t.uid not in pilot.agent._replicated
        assert [x.uid for x in pilot.agent.preemptable_tasks()] == [t.uid]
        assert pilot.agent.wait_idle(timeout=10)
    finally:
        pilot.close()


def test_checkpoint_adopt_copies_newest():
    a, b = CheckpointStore(StateStore()), CheckpointStore(StateStore())
    a.save("k", 5, "five")
    assert b.adopt("k", a)
    assert b.latest("k") == (5, "five")
    # never rolls back: an older source is refused
    b.save("k", 7, "seven")
    assert not b.adopt("k", a)
    assert b.latest("k") == (7, "seven")
    assert not b.adopt("missing", a)


def test_checkpoint_context_preempt_boundary():
    ck = CheckpointStore(StateStore())
    ctx = Checkpoint(ck, "k")
    ctx.save(0, "a")                         # no preempt pending: returns
    assert not ctx.preempt_requested()
    ctx.request_preempt()
    with pytest.raises(TaskPreempted) as ei:
        ctx.save(1, "b")
    # the step was persisted BEFORE the unwind: nothing is lost
    assert ck.latest("k") == (1, "b")
    assert ei.value.step == 1 and ei.value.key == "k"
    assert ctx.restore() == (1, "b")


# ------------------------- straggler bugfixes ---------------------------- #

def _bare_agent(**kw):
    return Agent(SlotScheduler(2), SPMDFunctionExecutor(), **kw)


def test_deadline_uses_recent_durations_not_largest():
    """Regression: the p95 must be over the ~100 most recent samples.
    Sorting the whole 256-deep deque first and slicing [-100:] took the
    100 *largest*, so one early slow phase inflated the deadline forever
    and replicas stopped firing."""
    ag = _bare_agent(straggler_factor=3.0)
    for _ in range(150):
        ag._durations.append(10.0)           # old, slow phase
    for _ in range(100):
        ag._durations.append(0.05)           # recent, fast phase
    dl = ag._deadline()
    assert dl is not None
    assert dl < 1.0, f"deadline {dl:.1f}s still reflects the oldest samples"
    assert dl == pytest.approx(0.15, rel=0.01)

    # the floor: micro-task p95s no longer produce deadlines shorter
    # than the monitor could even observe
    fast = _bare_agent(straggler_factor=3.0)
    for _ in range(100):
        fast._durations.append(0.001)
    assert fast._deadline() == pytest.approx(fast.straggler_min_deadline)


def test_replica_record_keeps_translator_stamps():
    """The monitor's replica TaskRecord must carry the original's
    sticky/affinity/kind stamps (journal + placement records match) and
    share its checkpoint key (that is what makes replicas resume)."""
    pilot = Pilot(PilotDescription(n_slots=2, straggler_factor=1e9))
    try:
        t = translate(lambda: "x", (), {},
                      ResourceSpec(sticky=True, affinity=("px", "py"),
                                   checkpointable=True, res_kind="cpu"))
        t.pilot_uid = pilot.uid
        rep = pilot.agent._spawn_replica(t)
        assert rep.replica_of == t.uid
        assert rep.sticky and rep.affinity == ("px", "py")
        assert rep.res_kind == "cpu" and rep.app_kind == t.app_kind
        assert rep.pilot_uid == pilot.uid
        assert rep.checkpointable and rep.ckpt_key == t.uid
        assert pilot.agent.wait_idle(timeout=10)
    finally:
        pilot.close()


def _straggler_body(counter, lock, log, n, leader_step_s, step_s,
                    leader_slow_after=0, fail_leader_at=None,
                    replica_raises=False, ckpt=None):
    """First invocation is the leader; it straggles (``leader_step_s``
    per step) from ``leader_slow_after`` on.  Later invocations are
    replicas running at the healthy ``step_s``."""
    with lock:
        me = next(counter)
    start = 0
    if ckpt is not None:
        got = ckpt.restore()
        if got is not None:
            start = got[0] + 1
    if me > 0 and replica_raises:
        raise RuntimeError("replica blew up")
    for step in range(start, n):
        slow = me == 0 and step >= leader_slow_after
        time.sleep(leader_step_s if slow else step_s)
        with lock:
            log.append((me, step))
        if ckpt is not None:
            ckpt.save(step, step)
        if me == 0 and fail_leader_at is not None and step == fail_leader_at:
            raise RuntimeError("leader failed")
    return {"who": me, "start": start}


def _seeded_pilot(**desc_kw):
    """Pilot whose agent has duration samples, so the straggler deadline
    is live (~3 x 30ms)."""
    pilot = Pilot(PilotDescription(n_slots=2, straggler_factor=3.0,
                                   **desc_kw))
    seeds = [translate(lambda: time.sleep(0.03), (), {}) for _ in range(5)]
    for s in seeds:
        pilot.agent.submit(s)
    assert pilot.agent.wait_idle(timeout=10)
    return pilot


def _run_straggler(pilot, timeout=20.0, **body_kw):
    lock = threading.Lock()
    log = []
    body_kw.setdefault("n", 6)
    t = translate(
        _straggler_body,
        (itertools.count(), lock, log, body_kw.pop("n"),
         body_kw.pop("leader_step_s"), body_kw.pop("step_s")), body_kw,
        ResourceSpec(checkpointable=True))
    t.max_retries = body_kw.get("max_retries", 0)
    res = []
    pilot.agent.submit(t, done_cb=res.append)
    deadline = time.monotonic() + timeout
    while not res and time.monotonic() < deadline:
        time.sleep(0.02)
    assert res, "straggler task never completed"
    assert pilot.agent.wait_idle(timeout=10)
    return t, res[0], log


@pytest.mark.timeout(60)
def test_replica_resumes_from_leader_checkpoint():
    """The replica restores the leader's latest saved step and wins from
    there — partial restart, not recompute-from-scratch."""
    pilot = _seeded_pilot()
    try:
        # leader saves steps 0-2 quickly, then grinds at 0.5s/step: the
        # replica fires past the ~100ms deadline and restores step >= 2
        t, done, log = _run_straggler(pilot, n=6, leader_step_s=0.5,
                                      step_s=0.02, leader_slow_after=3)
        assert done.state == TaskState.DONE
        assert done.result["who"] == 1, "replica did not win"
        assert done.result["start"] > 0, "replica recomputed from step 0"
        assert t.state == TaskState.CANCELED
        # every step completed by the winner exactly once; the leader may
        # only have contributed steps below the replica's start
        replica_steps = sorted(s for who, s in log if who == 1)
        assert replica_steps == list(range(done.result["start"], 6))
        # checkpoint GC'd once the task completed
        assert not pilot.agent.ckpt.has(t.ckpt_key)
    finally:
        pilot.close()


@pytest.mark.timeout(60)
def test_failed_replica_is_dropped_not_retried():
    """A replica that FAILs with retries remaining must be dropped: the
    original (still running) resolves the future, nothing requeues the
    replica as an ordinary task, and no third invocation ever happens."""
    pilot = _seeded_pilot()
    try:
        counter = itertools.count()
        lock, log = threading.Lock(), []
        t = translate(_straggler_body,
                      (counter, lock, log, 4, 0.1, 0.02),
                      {"replica_raises": True},
                      ResourceSpec(checkpointable=True))
        t.max_retries = 3                     # bait for the old retry path
        res = []
        pilot.agent.submit(t, done_cb=res.append)
        deadline = time.monotonic() + 20
        while not res and time.monotonic() < deadline:
            time.sleep(0.02)
        assert res and res[0].state == TaskState.DONE
        assert res[0].result["who"] == 0, "the leader must win"
        assert pilot.agent.wait_idle(timeout=10)
        time.sleep(0.2)                       # a requeued ghost would rerun
        invocations = next(counter)
        assert invocations >= 2, "replica never fired (deadline broken?)"
        # exactly one replica: dropped on failure (not retried as an
        # ordinary task) and not respawned in a storm while the leader
        # keeps running
        assert invocations == 2, "a failed replica was retried or respawned"
        assert t.retries == 0, "the original was charged the replica's retry"
        # the replica's FAILED is terminal in the store — never TRANSLATED
        # again afterwards
        reps = [uid for uid in pilot.store.states() if "replica" in uid]
        assert all(pilot.store.states()[u] == "FAILED" for u in reps)
    finally:
        pilot.close()


@pytest.mark.timeout(60)
def test_retryable_failure_on_original_still_retries():
    """The replica fix must not break ordinary retries: a non-replica
    FAILED task with retries remaining requeues (and, being
    checkpointable, resumes from its last saved step)."""
    pilot = Pilot(PilotDescription(n_slots=2, straggler_factor=1e9))
    try:
        counter = itertools.count()
        lock, log = threading.Lock(), []
        t = translate(_straggler_body,
                      (counter, lock, log, 5, 0.01, 0.01),
                      {"fail_leader_at": 2}, ResourceSpec(checkpointable=True))
        t.max_retries = 1
        res = []
        pilot.agent.submit(t, done_cb=res.append)
        assert pilot.agent.wait_idle(timeout=15)
        assert res and res[0].state == TaskState.DONE
        assert t.retries == 1
        # attempt 2 resumed after the failed step's checkpoint: each step
        # ran exactly once across both attempts
        assert sorted(s for _, s in log) == list(range(5))
    finally:
        pilot.close()


@pytest.mark.timeout(60)
def test_replica_succeeds_while_original_finishing():
    """Race the first-finisher-wins window: leader and replica complete
    nearly together; exactly one callback fires, the loser is CANCELED,
    and the agent settles."""
    pilot = _seeded_pilot()
    try:
        for _ in range(3):
            t, done, _ = _run_straggler(pilot, n=4, leader_step_s=0.06,
                                        step_s=0.05)
            assert done.state == TaskState.DONE
            states = {t.state, done.state}
            assert TaskState.DONE in states
            assert pilot.agent.wait_idle(timeout=10)
    finally:
        pilot.close()


# ------------------------- preempt-and-migrate --------------------------- #

def _resumable(n, step_s, log, lock, ckpt=None):
    start = 0
    got = ckpt.restore()
    if got is not None:
        start = got[0] + 1
    for step in range(start, n):
        time.sleep(step_s)
        with lock:
            log.append(step)
        ckpt.save(step, step)
    return {"start": start}


@pytest.mark.timeout(60)
def test_preempt_and_migrate_running_task():
    """The tentpole: a RUNNING checkpointable task behind which
    un-stealable (sticky) work is queued migrates to the idle pilot at
    its next checkpoint boundary — STOLEN(reason=preempt), resumed at its
    saved step, every step executed exactly once."""
    pool = PilotPool([PilotDescription(n_slots=2, name="gen",
                                       straggler_factor=1e9),
                      PilotDescription(n_slots=2, kinds=("spmd", "device"),
                                       name="dev", straggler_factor=1e9)])
    try:
        gen, dev = pool.pilots
        lock, log = threading.Lock(), []
        lt = translate(_resumable, (10, 0.05, log, lock), {},
                       ResourceSpec(slots=2, checkpointable=True,
                                    res_kind="device"))
        lt.pilot_uid = gen.uid
        res = []
        gen.agent.submit(lt, done_cb=res.append)
        time.sleep(0.12)                    # running, >=1 step saved
        sres = []
        for _ in range(4):                  # sticky backlog: unstealable
            s = translate(lambda: time.sleep(0.03) or "s", (), {},
                          ResourceSpec(sticky=True))
            s.pilot_uid = gen.uid
            gen.agent.submit(s, done_cb=sres.append)

        assert pool.request_work(dev) > 0   # preempt requested
        deadline = time.monotonic() + 15
        while (not res or len(sres) < 4) and time.monotonic() < deadline:
            time.sleep(0.02)
        assert res and res[0].state == TaskState.DONE
        assert len(sres) == 4

        stolen = [e for e in pool.events() if e["event"] == "STOLEN"]
        assert [e["reason"] for e in stolen] == ["preempt"]
        assert stolen[0]["src"] == gen.uid and stolen[0]["dst"] == dev.uid
        assert lt.pilot_uid == dev.uid, "binding not re-stamped"
        assert res[0].result["start"] > 0, "did not resume from checkpoint"
        assert sorted(log) == list(range(10)) and len(log) == 10, \
            "a checkpointed step re-executed after the migration"
        # the checkpoint moved with the task and was GC'd on completion:
        # no pilot holds a stale copy a restart could wrongly resume from
        assert pool.checkpoint_step(lt.ckpt_key) is None
        assert not gen.ckpt.has(lt.ckpt_key), \
            "the migration left a stale checkpoint on the victim"
    finally:
        pool.close()


def test_preempt_declines_without_victim_backlog():
    """No queued demand on the victim -> preemption is pure thrash (two
    idle pilots would ping-pong the task) and must not fire."""
    pool = PilotPool([PilotDescription(n_slots=2, name="a",
                                       straggler_factor=1e9),
                      PilotDescription(n_slots=2, name="b",
                                       straggler_factor=1e9)])
    try:
        a, b = pool.pilots
        lock, log = threading.Lock(), []
        t = translate(_resumable, (6, 0.04, log, lock), {},
                      ResourceSpec(checkpointable=True))
        t.pilot_uid = a.uid
        res = []
        a.agent.submit(t, done_cb=res.append)
        time.sleep(0.1)
        assert pool.request_work(b) == 0
        deadline = time.monotonic() + 10
        while not res and time.monotonic() < deadline:
            time.sleep(0.02)
        assert res and res[0].state == TaskState.DONE
        assert t.pilot_uid == a.uid
        assert not any(e["event"] == "STOLEN" for e in pool.events())
    finally:
        pool.close()


def test_overtaken_preempt_notifies_handoff_with_none():
    """A preempt request whose task reaches a normal finish before its
    next save is dropped — and the handoff is invoked once with
    (None, None) so the requester (the pool's in-flight preempt budget)
    can release its reservation instead of leaking it forever."""
    pilot = Pilot(PilotDescription(n_slots=2, straggler_factor=1e9))
    try:
        gate = threading.Event()

        def body(ckpt=None):
            ckpt.save(0, "only")
            gate.wait(10)          # no further saves: preempt never lands
            return "done"

        t = translate(body, (), {}, ResourceSpec(checkpointable=True))
        res = []
        pilot.agent.submit(t, done_cb=res.append)
        time.sleep(0.1)            # running, step 0 saved
        drops = []
        assert pilot.agent.preempt(t.uid, lambda *a: drops.append(a))
        gate.set()
        assert pilot.agent.wait_idle(timeout=10)
        assert res and res[0].state == TaskState.DONE
        assert res[0].result == "done"
        assert drops == [(None, None)], \
            "dropped preempt request did not notify its requester"
    finally:
        gate.set()
        pilot.close()


def test_sticky_running_task_is_never_preempted():
    """sticky is the hard pin for RUNNING tasks too: the steal-path
    enumeration excludes it, so the pool finds no candidate — only the
    drain path (``include_sticky``) may move it, because a dying pilot
    cannot honor stickiness."""
    pilot = Pilot(PilotDescription(n_slots=2, straggler_factor=1e9))
    try:
        lock, log = threading.Lock(), []
        t = translate(_resumable, (5, 0.04, log, lock), {},
                      ResourceSpec(checkpointable=True, sticky=True))
        pilot.agent.submit(t)
        time.sleep(0.1)
        assert pilot.agent.preemptable_tasks() == []
        sticky_too = pilot.agent.preemptable_tasks(include_sticky=True)
        assert [x.uid for x in sticky_too] == [t.uid]
        assert pilot.agent.wait_idle(timeout=10)
        assert t.state == TaskState.DONE
    finally:
        pilot.close()


@pytest.mark.timeout(60)
def test_drain_hands_back_running_checkpointable_task():
    """A retiring pilot preempts its RUNNING checkpointable work at the
    next checkpoint boundary; the orphan resumes from the saved step on
    the survivor instead of blocking the retirement until completion."""
    pool = PilotPool([PilotDescription(n_slots=2, name="dying",
                                       straggler_factor=1e9),
                      PilotDescription(n_slots=2, name="survivor",
                                       straggler_factor=1e9)])
    try:
        dying, survivor = pool.pilots
        lock, log = threading.Lock(), []
        t = translate(_resumable, (10, 0.05, log, lock), {},
                      ResourceSpec(checkpointable=True))
        t.pilot_uid = dying.uid
        res = []
        dying.agent.submit(t, done_cb=res.append)
        time.sleep(0.12)                     # running with progress saved

        assert pool.retire(dying, timeout=15)
        assert survivor.agent.wait_idle(timeout=15)
        deadline = time.monotonic() + 10
        while not res and time.monotonic() < deadline:
            time.sleep(0.02)
        assert res and res[0].state == TaskState.DONE
        assert t.pilot_uid == survivor.uid
        assert res[0].result["start"] > 0, "restarted from scratch"
        assert sorted(log) == list(range(10)) and len(log) == 10
        events = pool.events()
        assert any(e["event"] == "PILOT_RETIRE" and e["pilot"] == dying.uid
                   for e in events)
    finally:
        pool.close()


# ------------------------------ restart ---------------------------------- #

@pytest.mark.timeout(60)
def test_restart_resumes_interrupted_task_from_checkpoint(tmp_path):
    """An interrupted keyed task replays from its last checkpoint on
    restart: the journal-backed CheckpointStore survives the process
    boundary (fresh StateStore + payload from disk), the DFK reports the
    resumed key, and no step runs twice across the two runs."""
    j = str(tmp_path / "p.jsonl")
    log = []
    fail = {"on": True}

    @python_app(checkpointable=True)
    def work(n, ckpt=None):
        start = 0
        got = ckpt.restore()
        if got is not None:
            start = got[0] + 1
        for step in range(start, n):
            log.append(step)
            ckpt.save(step, {"step": step})
            if fail["on"] and step == 3:
                raise RuntimeError("interrupted")
        return start

    r1 = RPEXExecutor(PilotDescription(n_slots=2, journal=j))
    with DataFlowKernel(executors={"rpex": r1}, run_id="ck") as dfk1:
        with pytest.raises(RuntimeError, match="interrupted"):
            work(8).result(timeout=15)
        assert dfk1.resumed_from_checkpoint == {}
    r1.shutdown()
    assert log == [0, 1, 2, 3]

    fail["on"] = False
    r2 = RPEXExecutor(PilotDescription(n_slots=2, journal=j))
    assert r2.checkpoint_step("ck/work:0") == 3
    with DataFlowKernel(executors={"rpex": r2}, run_id="ck") as dfk2:
        f = work(8)
        assert f.result(timeout=15) == 4          # resumed at step 4
        assert dfk2.resumed_from_checkpoint == {"ck/work:0": 3}
    r2.shutdown()
    assert log == list(range(8)), "steps re-executed across the restart"
    # completed: the third run replays DONE from the journal, no resume
    r3 = RPEXExecutor(PilotDescription(n_slots=2, journal=j))
    with DataFlowKernel(executors={"rpex": r3}, run_id="ck") as dfk3:
        assert work(8).result(timeout=15) == 4
        assert dfk3.resumed_from_checkpoint == {}
    r3.shutdown()
    assert log == list(range(8))


@pytest.mark.timeout(60)
def test_spmd_checkpointable_body_gets_mesh_and_ckpt():
    """@spmd_app(checkpointable=True): the body receives the sub-mesh
    first (the communicator analog) plus the ckpt context, un-jitted at
    the wrapper level."""
    rpex = RPEXExecutor(PilotDescription(n_slots=2, straggler_factor=1e9))
    try:
        seen = {}

        @spmd_app(slots=2, checkpointable=True)
        def seg(mesh, n, ckpt=None):
            seen["mesh_devices"] = mesh.devices.size
            start = 0
            got = ckpt.restore()
            if got is not None:
                start = got[0] + 1
            for step in range(start, n):
                ckpt.save(step, step)
            return n - start

        with DataFlowKernel(executors={"rpex": rpex}):
            assert seg(3).result(timeout=15) == 3
        assert seen["mesh_devices"] >= 1
    finally:
        rpex.shutdown()
