"""Inter-pilot work stealing + elastic PilotPool: behaviour, fault
injection, and journal-replay correctness when task->pilot binding is no
longer immutable.

The hard invariants under test:
  * a task racing a steal against a dispatch runs exactly once and its
    completion callback fires exactly once;
  * sticky tasks and straggler replicas never migrate;
  * a draining pilot retires even when its slots fail mid-drain, and its
    orphaned tasks finish elsewhere;
  * an unroutable task during autoscale fails its future cleanly;
  * a restarted run resolves completed stolen tasks from the journal of
    the pilot that actually ran them (STOLEN + PILOT_RETIRE in stream).
"""
import json
import threading
import time

import pytest

from repro.core import (EVENTS, DataFlowKernel, LocalityAware, Pilot,
                        PilotDescription, PilotPool, PoolScaler,
                        ResourceSpec, RetryPolicy, RPEXExecutor,
                        ScalerConfig, TaskState,
                        overhead_from_events, python_app, translate)


def _occupy(tmgr, pilot, n, gate):
    """Pin n gated blocker tasks directly onto one pilot (bypassing
    least-loaded routing) so tests can shape load deterministically."""
    def blocker():
        gate.wait(15)
        return "blk"
    tasks = [translate(blocker, (), {}) for _ in range(n)]
    for t in tasks:
        tmgr._bind(t, pilot=pilot)
        with tmgr._cv:
            tmgr._outstanding += 1
        t.transition(TaskState.TRANSLATED, pilot.store)
        pilot.agent.submit(t, done_cb=tmgr._completion_cb(None))
    return tasks


# ----------------------------- work stealing ---------------------------- #

def test_idle_pilot_steals_queued_work():
    """A pilot going idle pulls queued-but-not-dispatched tasks off the
    loaded sibling: pilot_uid is re-stamped, a STOLEN event is emitted,
    and every future resolves."""
    rpex = RPEXExecutor([PilotDescription(n_slots=2, name="a"),
                         PilotDescription(n_slots=2, name="b")])
    try:
        a, b = rpex.pool.pilots
        gate = threading.Event()
        _occupy(rpex.tmgr, b, 12, gate)         # b is the "loaded" pilot

        work = [translate(lambda d=d: time.sleep(d) or d, (), {})
                for d in [0.05] * 8]
        for t in work:
            rpex.tmgr.submit(t)                 # all route to a (lower load)
        assert {t.pilot_uid for t in work} == {a.uid}

        time.sleep(0.05)                        # a starts 2, queues the rest
        gate.set()                              # b drains -> hungry -> steals
        assert rpex.tmgr.wait(timeout=15)

        stolen_evs = [e for e in rpex.pool.events() if e["event"] == "STOLEN"]
        assert stolen_evs, "no STOLEN event emitted"
        stolen_uids = {e["uid"] for e in stolen_evs}
        moved = [t for t in work if t.uid in stolen_uids]
        assert moved, "no task actually migrated"
        for t in moved:
            assert t.pilot_uid == b.uid         # binding re-stamped
            assert t.state == TaskState.DONE
        for e in stolen_evs:
            assert e["src"] == a.uid and e["dst"] == b.uid
    finally:
        gate.set()
        rpex.shutdown()


def test_sticky_stamp_threads_through_decorators_and_dfk():
    """@python_app(sticky=True) and the DFK's per-invocation override both
    reach the translated TaskRecord the steal predicate inspects."""
    @python_app(sticky=True)
    def pinned():
        return 1

    fn = pinned.__wrapped_app__
    assert fn.__resources__.sticky
    assert translate(fn, (), {}, fn.__resources__).sticky

    rpex = RPEXExecutor(PilotDescription(n_slots=2))
    try:
        with DataFlowKernel(executors={"rpex": rpex}) as dfk:
            f1 = pinned()
            f2 = dfk.submit(fn, (), sticky=False)     # invocation override
            assert f1.result(timeout=10) == 1
            assert f2.result(timeout=10) == 1
        assert f1.task.sticky and not f2.task.sticky
    finally:
        rpex.shutdown()


def test_sticky_tasks_are_never_stolen():
    pilot = Pilot(PilotDescription(n_slots=1, name="v"))
    try:
        gate = threading.Event()
        blocker = translate(lambda: gate.wait(10), (), {})
        pilot.agent.submit(blocker)             # occupies the only slot
        time.sleep(0.05)

        sticky = translate(lambda: "s", (), {}, ResourceSpec(sticky=True))
        normal = translate(lambda: "n", (), {})
        assert sticky.sticky and not normal.sticky
        pilot.agent.submit(sticky)
        pilot.agent.submit(normal)

        batch = pilot.agent.steal(pred=lambda t: True)
        assert [t.uid for t, _ in batch] == [normal.uid]
        assert pilot.agent.queued_demand() == 1   # sticky still queued
        # the drain path (pred=None) does take sticky tasks — a dying
        # pilot cannot honor stickiness
        batch2 = pilot.agent.steal()
        assert [t.uid for t, _ in batch2] == [sticky.uid]
        gate.set()
        assert pilot.agent.wait_idle(timeout=10)
    finally:
        gate.set()
        pilot.close()


def test_steal_racing_dispatch_runs_each_task_exactly_once():
    """Fault-injection: hammer request_work() from two threads while the
    victim's scheduler loop dispatches — every task executes exactly once
    and every completion callback fires exactly once."""
    # huge straggler_factor: sub-ms tasks under hammer load would
    # otherwise trip the p95 replica deadline and legitimately run twice
    pool = PilotPool([PilotDescription(n_slots=1, name="victim",
                                       straggler_factor=1e9),
                      PilotDescription(n_slots=1, name="thief",
                                       straggler_factor=1e9)])
    try:
        victim, thief = pool.pilots
        runs = {}
        dones = {}
        lock = threading.Lock()

        def body(uid):
            with lock:
                runs[uid] = runs.get(uid, 0) + 1

        n = 150
        tasks = [translate(body, (f"u{i}",), {}) for i in range(n)]

        def on_done(t):
            with lock:
                dones[t.uid] = dones.get(t.uid, 0) + 1

        stop = threading.Event()

        def hammer():
            while not stop.is_set():
                pool.request_work(thief)

        hs = [threading.Thread(target=hammer) for _ in range(2)]
        for h in hs:
            h.start()
        for i, t in enumerate(tasks):
            t.pilot_uid = victim.uid
            victim.agent.submit(t, done_cb=on_done)
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            if victim.agent.wait_idle(0.2) and thief.agent.wait_idle(0.2):
                break
        stop.set()
        for h in hs:
            h.join(timeout=5)

        assert set(runs) == {f"u{i}" for i in range(n)}
        assert set(runs.values()) == {1}, "a task ran twice or never"
        assert len(dones) == n and set(dones.values()) == {1}, \
            "a completion callback was lost or fired twice"
        assert all(t.state == TaskState.DONE for t in tasks)
    finally:
        pool.close()


def test_affinity_steal_racing_dispatch_runs_each_task_exactly_once():
    """Fault-injection for the affinity-aware steal gate: hammer
    request_work() under a LocalityAware policy while the victim
    dispatches a mixed affine/non-affine workload.  The gate flips
    per-task between eligible and blocked as the victim's backlog
    drains, racing the scheduler's allocation — every task must still
    run exactly once and deliver its callback exactly once, wherever it
    lands."""
    pool = PilotPool([PilotDescription(n_slots=1, name="victim",
                                       straggler_factor=1e9),
                      PilotDescription(n_slots=1, name="thief",
                                       straggler_factor=1e9)],
                     policy=LocalityAware(locality_weight=0.5))
    try:
        victim, thief = pool.pilots
        runs = {}
        dones = {}
        lock = threading.Lock()

        def body(uid):
            with lock:
                runs[uid] = runs.get(uid, 0) + 1

        n = 150
        tasks = []
        for i in range(n):
            t = translate(body, (f"u{i}",), {})
            if i % 3 == 0:
                t.affinity = (victim.uid,)    # gate weighs these
            elif i % 3 == 1:
                t.affinity = (thief.uid,)     # always eligible
            tasks.append(t)

        def on_done(t):
            with lock:
                dones[t.uid] = dones.get(t.uid, 0) + 1

        stop = threading.Event()

        def hammer():
            while not stop.is_set():
                pool.request_work(thief)

        hs = [threading.Thread(target=hammer) for _ in range(2)]
        for h in hs:
            h.start()
        for t in tasks:
            t.pilot_uid = victim.uid
            victim.agent.submit(t, done_cb=on_done)
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            if victim.agent.wait_idle(0.2) and thief.agent.wait_idle(0.2):
                break
        stop.set()
        for h in hs:
            h.join(timeout=5)

        assert set(runs) == {f"u{i}" for i in range(n)}
        assert set(runs.values()) == {1}, "a task ran twice or never"
        assert len(dones) == n and set(dones.values()) == {1}, \
            "a completion callback was lost or fired twice"
        assert all(t.state == TaskState.DONE for t in tasks)
        # the gate actually bit both ways: something migrated, and
        # thief-affine work migrated at least as readily as victim-affine
        stolen = [e for e in pool.events() if e["event"] == "STOLEN"]
        assert stolen, "no steal ever passed the affinity gate"
    finally:
        pool.close()


@pytest.mark.slow
def test_randomized_steal_fault_churn():
    """Property-style fault injection: a seeded random interleaving of
    submissions, steals, slot failures and grows across a two-pilot pool
    never loses or double-fires a completion callback, and every task
    reaches a terminal state.  (Execution counts may legitimately exceed
    one for failed-and-retried tasks; callback delivery may not.)"""
    import random
    rng = random.Random(0xBA1A)
    pool = PilotPool([PilotDescription(n_slots=2, name="p0",
                                       straggler_factor=1e9),
                      PilotDescription(n_slots=2, name="p1",
                                       straggler_factor=1e9)])
    try:
        runs, dones = {}, {}
        lock = threading.Lock()
        tasks = []

        def body(uid):
            with lock:
                runs[uid] = runs.get(uid, 0) + 1

        def on_done(t):
            with lock:
                dones[t.uid] = dones.get(t.uid, 0) + 1

        for step in range(300):
            op = rng.random()
            p = pool.pilots[rng.randrange(2)]
            if op < 0.55:
                t = translate(body, (f"u{len(tasks)}",), {})
                t.max_retries = 2
                t.pilot_uid = p.uid
                tasks.append(t)
                p.agent.submit(t, done_cb=on_done)
            elif op < 0.80:
                pool.request_work(p)
            elif op < 0.90:
                p.agent.inject_slot_failure([rng.randrange(8)])
                p.grow(1)               # keep capacity alive under faults
            else:
                time.sleep(0.002)

        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            if all(p.agent.wait_idle(0.25) for p in pool.pilots):
                break
        assert all(p.agent.wait_idle(0) for p in pool.pilots), \
            "runtime failed to drain after churn"

        assert len(dones) == len(tasks), "a completion callback was lost"
        assert set(dones.values()) == {1}, "a callback fired twice"
        from repro.core import TaskState as TS
        for t in tasks:
            assert t.state in (TS.DONE, TS.FAILED)
            if t.state == TS.DONE:
                assert runs.get(t.args[0], 0) >= 1
        for p in pool.pilots:
            s = p.scheduler
            assert s.n_free + s.n_busy == s.capacity
    finally:
        pool.close()


# ------------------------------- drain ---------------------------------- #

def test_slot_failure_during_drain_still_retires():
    """inject_slot_failure mid-drain: the running task fails, its retry
    requeues with no capacity left, the drain sweep hands it to the pool,
    and the pilot still retires (PILOT_RETIRE, drained pool survives)."""
    pool = PilotPool([PilotDescription(n_slots=2, name="dying"),
                      PilotDescription(n_slots=2, name="survivor")])
    try:
        dying, survivor = pool.pilots
        gate = threading.Event()
        results = []

        def work():
            gate.wait(10)
            return "ok"

        t = translate(work, (), {})
        t.max_retries = 1
        t.pilot_uid = dying.uid
        dying.agent.submit(t, done_cb=results.append)
        time.sleep(0.1)                          # task is RUNNING on dying

        retire_done = []
        th = threading.Thread(
            target=lambda: retire_done.append(pool.retire(dying, timeout=10)))
        th.start()
        time.sleep(0.15)                         # drain is waiting on it
        dying.agent.inject_slot_failure([0, 1])  # kill its slots
        gate.set()                               # task observes the failure
        th.join(timeout=15)

        assert retire_done == [True]
        events = pool.events()
        assert any(e["event"] == "PILOT_RETIRE" and e["pilot"] == dying.uid
                   for e in events)
        # the retried task was orphaned out of the drain and finished on
        # the survivor
        assert survivor.agent.wait_idle(timeout=10)
        assert results and results[0].state == TaskState.DONE
        assert results[0].result == "ok"
        assert t.pilot_uid == survivor.uid
        assert any(e["event"] == "STOLEN" and e.get("reason") == "drain"
                   for e in events)
        assert dying not in pool.pilots and dying in pool.retired
    finally:
        gate.set()
        pool.close()


def test_migration_into_dying_pilot_is_refused_and_replaced():
    """A steal/migration racing a retire: the dying agent refuses the
    submission (instead of heaping a task it will never run) and the pool
    re-places the task on a surviving pilot — the future never hangs."""
    pool = PilotPool([PilotDescription(n_slots=2, name="alive"),
                      PilotDescription(n_slots=2, name="dying")])
    try:
        alive, dying = pool.pilots
        # simulate the race window: dying has passed its drain barrier but
        # the in-flight request_work still holds it as the destination
        dying.draining = True
        dying.agent.stop_accepting()

        done = []
        t = translate(lambda: "ok", (), {})
        pool._migrate(t, alive, dying, done.append, reason="steal")
        assert alive.agent.wait_idle(timeout=10)
        assert done and done[0].state == TaskState.DONE
        assert t.pilot_uid == alive.uid
        assert t.result == "ok"
    finally:
        pool.close()


def test_oversized_orphan_prefers_pilot_that_fits():
    """retire() re-places a drained orphan on a pilot whose capacity can
    actually fit it, not just any kind-compatible pilot it would wait on
    forever."""
    pool = PilotPool([PilotDescription(n_slots=4, name="dying"),
                      PilotDescription(n_slots=2, name="small"),
                      PilotDescription(n_slots=4, name="big")])
    try:
        dying, small, big = pool.pilots
        gate = threading.Event()
        blocker = translate(lambda: gate.wait(10), (), {},
                            ResourceSpec(slots=4))
        dying.agent.submit(blocker)          # holds all 4 slots
        time.sleep(0.05)
        done = []
        wide = translate(lambda: "wide", (), {}, ResourceSpec(slots=4))
        wide.pilot_uid = dying.uid
        dying.agent.submit(wide, done_cb=done.append)  # queued, stealable

        # make small look least-loaded-but-unfit; retire must skip it
        retired = []
        th = threading.Thread(
            target=lambda: retired.append(pool.retire(dying, timeout=10)))
        th.start()
        time.sleep(0.1)
        gate.set()
        th.join(timeout=15)
        assert retired == [True]
        assert big.agent.wait_idle(timeout=10)
        assert done and done[0].state == TaskState.DONE
        assert wide.pilot_uid == big.uid, \
            "oversized orphan parked on a pilot that can never fit it"
    finally:
        gate.set()
        pool.close()


# ------------------------------ autoscale -------------------------------- #

def test_scaler_spawns_and_retires_pilots():
    """Queue wait above threshold spawns a pilot from the template
    (PILOT_START), stealing moves backlog (STOLEN), idleness retires it
    (PILOT_RETIRE) — the full elastic cycle, visible in the events."""
    cfg = ScalerConfig(template=PilotDescription(n_slots=2, name="elastic"),
                       min_pilots=1, max_pilots=3, scale_up_wait_s=0.1,
                       scale_down_idle_s=0.3, spawn_cooldown_s=0.15,
                       interval_s=0.05)
    rpex = RPEXExecutor(PilotDescription(n_slots=2, name="seed"), scaler=cfg)
    try:
        tasks = [translate(lambda: time.sleep(0.15), (), {})
                 for _ in range(12)]
        rpex.tmgr.submit_bulk(tasks)
        assert rpex.tmgr.wait(timeout=30)
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            if any(e["event"] == "PILOT_RETIRE" for e in rpex.pool.events()):
                break
            time.sleep(0.05)
        kinds = {e["event"] for e in rpex.pool.events()}
        assert {"PILOT_START", "STOLEN", "PILOT_RETIRE"} <= kinds
        acts = [d["action"] for d in rpex.scaler.decisions]
        assert "scale_up" in acts and "retire" in acts
        assert "error" not in acts
        # the seed pilot (user-configured) is never retired
        assert rpex.pool.pilots[0].desc.name == "seed"
        # utilization spans the changed pilot set (seed + retired elastics)
        assert len(rpex.utilization()) >= 2
        assert all(t.state == TaskState.DONE for t in tasks)
    finally:
        rpex.shutdown()


def test_grow_shrink_events_journal_resize():
    """In-place elastic resize is auditable: ``grow``/``shrink`` journal
    GROW/SHRINK events carrying the pilot uid and delta, and capacity
    tracks the event stream (consumer side of the event protocol — the
    static analyzer flags emitted-but-never-consumed names)."""
    pilot = Pilot(PilotDescription(n_slots=2, name="elastic"))
    try:
        pilot.grow(3)
        assert pilot.n_slots == 5
        pilot.shrink(2)
        assert pilot.n_slots == 3
        evs = pilot.store.events_snapshot()
        grows = [e for e in evs if e["event"] == EVENTS.GROW]
        shrinks = [e for e in evs if e["event"] == EVENTS.SHRINK]
        assert [(e["pilot"], e["n"]) for e in grows] == [(pilot.uid, 3)]
        assert [(e["pilot"], e["n"]) for e in shrinks] == [(pilot.uid, 2)]
    finally:
        pilot.close()


def test_scaler_picks_template_matching_starving_kinds():
    """Multi-template scaling: with a queue starving on one resource
    kind, scale-up spawns the template whose ``kinds`` cover that demand
    — not whichever template is listed first."""
    cfg = ScalerConfig(
        templates=[PilotDescription(n_slots=2, kinds=("python", "bash"),
                                    name="cpu-t"),
                   PilotDescription(n_slots=2, kinds=("gpu",),
                                    name="gpu-t")],
        min_pilots=1, max_pilots=2, scale_up_wait_s=0.1,
        spawn_cooldown_s=0.1, scale_down_idle_s=60.0, interval_s=0.05)
    # the seed accepts everything but has one slot: a burst of gpu-kind
    # tasks backs up behind it and starves
    rpex = RPEXExecutor(PilotDescription(n_slots=1, name="seed"),
                        scaler=cfg)
    try:
        tasks = [translate(lambda: time.sleep(0.1), (), {},
                           ResourceSpec(res_kind="gpu"))
                 for _ in range(10)]
        for t in tasks:
            rpex.tmgr.submit(t)
        assert rpex.tmgr.wait(timeout=30)
        ups = [d for d in rpex.scaler.decisions
               if d["action"] == "scale_up"]
        assert ups, "scaler never spawned under a starving queue"
        assert ups[0]["template"] == "gpu-t"
        assert ups[0]["kinds"] == ["gpu"]
        spawned = [p for p in rpex.pool.all_pilots()
                   if p.desc.kinds == ("gpu",)]
        assert spawned, "the gpu template pilot was never added"
        assert all(t.state == TaskState.DONE for t in tasks)
    finally:
        rpex.shutdown()


def test_unroutable_task_during_autoscale_fails_cleanly():
    """A task no pilot (current or template) accepts resolves FAILED via
    its callback while the scaler is live — no hang, no crash, and the
    routable workload is unaffected."""
    cfg = ScalerConfig(
        template=PilotDescription(n_slots=2, kinds=("python",), name="el"),
        max_pilots=2, scale_up_wait_s=0.1, interval_s=0.05)
    rpex = RPEXExecutor(
        PilotDescription(n_slots=2, kinds=("python",), name="seed"),
        scaler=cfg)
    try:
        good = [translate(lambda: time.sleep(0.05), (), {})
                for _ in range(8)]
        rpex.tmgr.submit_bulk(good)

        def dev_fn(mesh):
            return 1
        dev_fn.__app_kind__ = "spmd"
        bad = translate(dev_fn, (), {})
        failed = []
        rpex.tmgr.submit(bad, done_cb=failed.append)

        assert bad.state == TaskState.FAILED
        assert failed and "no pilot accepts" in repr(failed[0].error)
        assert rpex.tmgr.wait(timeout=20)       # nothing left hanging
        assert all(t.state == TaskState.DONE for t in good)
    finally:
        rpex.shutdown()


# --------------------------- journal replay ------------------------------ #

def test_journal_replay_resolves_stolen_tasks(tmp_path):
    """A task stolen to another pilot records its DONE (with the workflow
    key) in the journal of the pilot that ran it; a restarted run with the
    same run_id replays the result without re-executing, and the lookup
    works across retired pilots too."""
    j0, j1 = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    descs = lambda: [PilotDescription(n_slots=2, journal=j0, name="a"),
                     PilotDescription(n_slots=2, journal=j1, name="b")]
    calls = []

    @python_app
    def work(x):
        calls.append(x)
        return x * 7

    r1 = RPEXExecutor(descs())
    a, b = r1.pool.pilots
    gate_a, gate_b = threading.Event(), threading.Event()
    _occupy(r1.tmgr, a, 2, gate_a)      # a: both slots busy, lower load
    _occupy(r1.tmgr, b, 4, gate_b)      # b: higher load -> work routes to a
    with DataFlowKernel(executors={"rpex": r1}, run_id="steal-run"):
        f = work(6)
        time.sleep(0.1)
        assert f.task.pilot_uid == a.uid        # routed to a, queued there
        gate_b.set()                            # b drains and steals it
        assert f.result(timeout=15) == 42
        gate_a.set()
    assert f.task.pilot_uid == b.uid, "task was not stolen to b"
    assert any(e["event"] == "STOLEN" and e["uid"] == f.task.uid
               for e in r1.pool.events())
    # retire the pilot that ran it: lookup must still work (all_pilots)
    assert r1.pool.retire(b, timeout=10)
    found, result = r1.completed_result("steal-run/work:0")
    assert found and result == 42
    r1.shutdown()

    # the DONE record lives in b's journal, stamped with b's uid
    recs = [json.loads(line) for line in open(j1)]
    done = [r for r in recs if r.get("key") == "steal-run/work:0"
            and r.get("state") == "DONE"]
    assert done and done[-1]["pilot"] == b.uid
    assert done[-1]["result"] == 42

    # restart: the future resolves from the journal, work() never re-runs
    assert calls == [6]
    r2 = RPEXExecutor(descs())
    with DataFlowKernel(executors={"rpex": r2}, run_id="steal-run"):
        f2 = work(6)
        assert f2.result(timeout=10) == 42
    r2.shutdown()
    assert calls == [6], "replayed task was re-executed"


# ------------------- overhead from the event stream ---------------------- #

def test_overhead_from_events_synthetic_timeline():
    """Regression for the exp2 rp_oh_s overcount: concurrent launches
    merge into one wall-clock interval, slot-idle gaps between dependent
    tasks contribute nothing, and every retry attempt counts."""
    E = lambda uid, state, t: {"event": "STATE", "uid": uid,
                               "state": state, "t": t}
    events = [
        # a simple task: 0.1s scheduled->running
        E("a", "SCHEDULED", 0.0), E("a", "RUNNING", 0.1), E("a", "DONE", 1.0),
        # slots idle 1.0 -> 5.0 waiting on the dependency: no overhead
        E("b", "SCHEDULED", 5.0), E("b", "RUNNING", 5.2), E("b", "DONE", 6.0),
        # two concurrent launches: union is 0.5, per-task sum says 1.0
        E("c", "SCHEDULED", 10.0), E("d", "SCHEDULED", 10.0),
        E("c", "RUNNING", 10.5), E("d", "RUNNING", 10.5),
        # failed before ever RUNNING: terminal stamp closes the interval
        E("e", "SCHEDULED", 20.0), E("e", "FAILED", 20.25),
        # a retried task: both attempts contribute
        E("f", "SCHEDULED", 30.0), E("f", "RUNNING", 30.1),
        E("f", "FAILED", 31.0),
        E("f", "SCHEDULED", 40.0), E("f", "RUNNING", 40.1),
        # non-STATE noise must be ignored
        {"event": "STOLEN", "uid": "b", "t": 4.0, "src": "x", "dst": "y"},
    ]
    got = overhead_from_events(events)
    want = 0.1 + 0.2 + 0.5 + 0.25 + 0.1 + 0.1
    assert abs(got - want) < 1e-9

    # the old per-task sum overcounts the concurrent window
    old_sum = (0.1 + 0.2 + 0.5 + 0.5 + 0.1 + 0.1)
    assert old_sum > got

    assert overhead_from_events([]) == 0.0


def test_rp_overhead_accessor_live():
    """The executor-level accessor integrates the live stream and stays
    far below wall-clock for an idle-heavy dependent workload."""
    rpex = RPEXExecutor(PilotDescription(n_slots=2))
    try:
        @python_app
        def step(x):
            time.sleep(0.05)
            return x + 1

        t0 = time.monotonic()
        with DataFlowKernel(executors={"rpex": rpex}):
            f = step(step(step(0)))             # a dependent chain
            assert f.result(timeout=15) == 3
        wall = time.monotonic() - t0
        oh = rpex.rp_overhead()
        assert 0.0 <= oh < wall
        # 3 x 50ms of compute is not overhead; the recompute must not
        # charge the dependency idle time either
        assert oh < wall - 0.1
    finally:
        rpex.shutdown()


# --------------------- proc-worker fault injection ----------------------- #

def _wait_for_file(path, timeout=20.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        try:
            txt = path.read_text().strip()
            if txt:
                return txt
        except OSError:
            pass
        time.sleep(0.02)
    raise AssertionError(f"{path} never appeared")


def test_proc_worker_death_fails_task_and_pool_respawns(tmp_path):
    """Chaos: SIGKILL a proc-mode worker mid-task.  The in-flight task
    must FAIL visibly (WorkerDied, not a hang), its slot must come back,
    and the pool must respawn a worker for the next task."""
    import os
    import signal

    from repro.core import WorkerDied

    rpex = RPEXExecutor(PilotDescription(n_slots=2, transport="proc"))
    try:
        pidfile = tmp_path / "victim.pid"

        @python_app
        def stall(pf):
            import os as _os
            import time as _time
            with open(pf, "w") as fh:
                fh.write(str(_os.getpid()))
            _time.sleep(60)            # killed long before this returns

        @python_app
        def probe():
            return "alive"

        with DataFlowKernel(executors={"rpex": rpex}):
            f = stall(str(pidfile))
            pid = int(_wait_for_file(pidfile))
            os.kill(pid, signal.SIGKILL)
            with pytest.raises(WorkerDied):
                f.result(timeout=20)   # FAILED, not hung
            assert f.task.state == TaskState.FAILED
            # slot released + lazy respawn: new work still completes
            assert probe().result(timeout=20) == "alive"
        agent = rpex.pilot.agent
        assert agent.scheduler.n_free == 2     # no leaked allocation
    finally:
        rpex.shutdown()


def test_proc_worker_death_retry_path_fires(tmp_path):
    """A task whose worker is killed retries like any other failure: the
    second attempt lands on a respawned worker and succeeds."""
    import os
    import signal

    p = Pilot(PilotDescription(n_slots=2, transport="proc"))
    try:
        flag = tmp_path / "first-attempt"
        pidfile = tmp_path / "victim.pid"

        def flaky(flagp, pidp):
            import os as _os
            import time as _time
            if not _os.path.exists(flagp):
                with open(flagp, "w") as fh:
                    fh.write("x")
                with open(pidp, "w") as fh:
                    fh.write(str(_os.getpid()))
                _time.sleep(60)        # first attempt: killed here
            return 42                  # retry: clean success

        t = translate(flaky, (str(flag), str(pidfile)), {}, max_retries=1)
        t.transition(TaskState.TRANSLATED, p.store)
        done = threading.Event()
        box = {}

        def cb(task):
            box["state"], box["result"] = task.state, task.result
            done.set()

        assert p.agent.submit(t, done_cb=cb)
        pid = int(_wait_for_file(pidfile))
        os.kill(pid, signal.SIGKILL)
        assert done.wait(30), "retry after worker death never completed"
        assert box["state"] == TaskState.DONE
        assert box["result"] == 42
        assert t.retries == 1
    finally:
        p.close()


# --------------------------- pilot failure domains ------------------------ #

@pytest.mark.timeout(120)
def test_scaler_replaces_lost_pilot_mid_burst():
    """A pilot crashing under a live burst is declared LOST by heartbeat
    supervision, its work re-routes, and the PoolScaler's replace-on-loss
    trigger restores the pool's capacity from the template — bypassing
    the spawn cooldown, since loss is not load."""
    pool = PilotPool([PilotDescription(n_slots=2, name="rla",
                                       straggler_factor=1e9),
                      PilotDescription(n_slots=2, name="rlb",
                                       straggler_factor=1e9)],
                     heartbeat_timeout_s=0.5)
    scaler = PoolScaler(pool, ScalerConfig(
        template=PilotDescription(n_slots=2, name="spare",
                                  straggler_factor=1e9),
        min_pilots=2, max_pilots=3, interval_s=0.05,
        scale_up_wait_s=1e9, scale_down_idle_s=1e9,
        spawn_cooldown_s=1e9)).start()
    from repro.core import TaskManager
    tmgr = TaskManager(pool)
    try:
        a, b = pool.pilots
        pol = RetryPolicy(max_retries=4, backoff_base_s=0.0)
        results = []
        lock = threading.Lock()

        def cb(rec):
            with lock:
                results.append(rec)

        tasks = [translate(lambda i=i: time.sleep(0.05) or i, (), {},
                           retry_policy=pol) for i in range(24)]
        tmgr.submit_bulk(tasks, done_cb=cb)
        time.sleep(0.1)                       # burst in flight everywhere
        a.agent.inject_crash()
        assert tmgr.wait(timeout=60), "burst never drained after the loss"

        assert len(results) == 24
        assert all(r.state == TaskState.DONE for r in results)
        lost = [e for e in pool.events() if e["event"] == "PILOT_LOST"]
        assert lost and lost[0]["pilot"] == a.uid
        replaced = [d for d in scaler.decisions
                    if d["action"] == "replace_lost"]
        assert replaced and replaced[0]["lost"] == a.uid
        # the replacement is a live member; the lost pilot is not
        assert a not in pool.pilots
        assert any(p.desc.name.startswith("spare") for p in pool.active())
    finally:
        scaler.stop()
        pool.close()


@pytest.mark.timeout(120)
def test_checkpoint_readopted_from_lost_pilot_resumes_on_survivor():
    """A RUNNING checkpointable task on a crashed pilot re-adopts its
    last durable snapshot onto the survivor (ensure_checkpoint moves it)
    and resumes at step > 0 — the pilot died, the work did not."""
    pool = PilotPool([PilotDescription(n_slots=1, name="cka",
                                       straggler_factor=1e9),
                      PilotDescription(n_slots=1, name="ckb",
                                       straggler_factor=1e9)],
                     steal=False, heartbeat_timeout_s=0.5)
    try:
        a, b = pool.pilots

        def stepper(n, step_s, ckpt=None):
            got = ckpt.restore()
            start = got[0] + 1 if got is not None else 0
            for step in range(start, n):
                time.sleep(step_s)
                ckpt.save(step, step)
            return {"start": start}

        t = translate(stepper, (10, 0.08), {},
                      ResourceSpec(checkpointable=True))
        t.transition(TaskState.TRANSLATED, a.store)
        box = {}
        done = threading.Event()
        a.agent.submit(t, done_cb=lambda rec: (box.update(r=rec),
                                               done.set()))
        deadline = time.monotonic() + 15
        while a.ckpt.step(t.ckpt_key) is None:
            assert time.monotonic() < deadline, "no checkpoint saved"
            time.sleep(0.02)
        a.agent.inject_crash()                # heartbeat monitor takes over

        assert done.wait(60), "recovered task never completed"
        rec = box["r"]
        assert rec.state == TaskState.DONE
        assert rec.pilot_uid == b.uid         # resumed on the survivor
        assert rec.result["start"] > 0        # from the snapshot, not 0
        assert rec.retries == 0               # re-adoption costs no retry
        lost = [e for e in pool.events() if e["event"] == "PILOT_LOST"]
        assert lost and lost[0]["reason"] == "crash"
    finally:
        pool.close()
