"""Pluggable placement layer: LeastLoaded parity with the PR-2 hardcoded
routing, LocalityAware affinity scoring and steal gating, composable
tie-breaking, kind-aware template selection, and the affinity stamp's
path through ResourceSpec / translator / DFK dep manager."""
import threading
import time

import pytest

from repro.core import (DataFlowKernel, LeastLoaded, LocalityAware,
                        PilotDescription, PilotPool, PlacementPolicy,
                        RPEXExecutor, ResourceSpec, TaskState,
                        affinity_match, prefer_specialized, python_app,
                        resolve_policy, translate)


def _pool(*descs, **kw):
    return PilotPool([PilotDescription(**d) for d in descs], **kw)


def _occupy(pilot, n, gate):
    """Pin n gated blockers straight onto one pilot to shape load."""
    tasks = [translate(lambda: gate.wait(15), (), {}) for _ in range(n)]
    for t in tasks:
        pilot.agent.submit(t)
    return tasks


# ------------------------- LeastLoaded parity ---------------------------- #

def test_least_loaded_route_matches_pr2_min_by_load():
    """Default-policy route() == min(compatible, key=load), first of
    equals — the exact PR-2 expression."""
    pool = _pool(dict(n_slots=2, name="a"), dict(n_slots=2, name="b"),
                 dict(n_slots=2, name="c"))
    try:
        gate = threading.Event()
        a, b, c = pool.pilots
        _occupy(a, 3, gate)
        _occupy(b, 1, gate)
        time.sleep(0.05)
        for _ in range(4):
            t = translate(lambda: 1, (), {})
            want = min([p for p in pool.pilots if p.accepts(t)],
                       key=lambda p: p.load())
            assert pool.route(t) is want
        gate.set()
    finally:
        gate.set()
        pool.close()


def test_least_loaded_route_bulk_matches_pr2_greedy():
    """Bulk placement under the default policy reproduces the PR-2
    greedy: running load estimate includes demand placed earlier in the
    batch, unroutable tasks yield their exception in place."""
    pool = _pool(dict(n_slots=2, name="a", kinds=("python", "bash")),
                 dict(n_slots=4, name="b"))
    try:
        tasks = [translate(lambda: 1, (), {},
                           ResourceSpec(slots=1 + (i % 2)))
                 for i in range(8)]

        def spmd_fn(mesh):
            return 0
        spmd_fn.__app_kind__ = "spmd"
        bad = translate(spmd_fn, (), {})
        bad.res_kind = "weird"
        bad.kind = bad.app_kind = "weird"
        tasks.insert(3, bad)

        # the PR-2 reference implementation, verbatim
        pilots = pool.active()
        loads = {p.uid: p.load() for p in pilots}
        caps = {p.uid: max(1, p.scheduler.capacity) for p in pilots}
        want = []
        for t in tasks:
            compat = [p for p in pilots if p.accepts(t)]
            if not compat:
                want.append(None)                     # exception slot
                continue
            p = min(compat, key=lambda p: loads[p.uid])
            loads[p.uid] += t.resources.slots / caps[p.uid]
            want.append(p)

        got = pool.route_bulk(tasks)
        for g, w in zip(got, want):
            if w is None:
                assert isinstance(g, RuntimeError)
            else:
                assert g is w
    finally:
        pool.close()


def test_resolve_policy_names_and_errors():
    assert isinstance(resolve_policy(None), LeastLoaded)
    assert isinstance(resolve_policy("least-loaded"), LeastLoaded)
    assert isinstance(resolve_policy("LOCALITY"), LocalityAware)
    p = LocalityAware(locality_weight=2.0)
    assert resolve_policy(p) is p
    with pytest.raises(ValueError, match="unknown placement policy"):
        resolve_policy("who-knows")
    with pytest.raises(ValueError, match="locality_weight"):
        LocalityAware(locality_weight=-1)


# ------------------------- LocalityAware scoring ------------------------- #

def test_locality_scoring_follows_affinity_within_weight():
    """An affine pilot wins while the load gap stays under the locality
    weight; past the weight, load takes over (locality is soft)."""
    pool = _pool(dict(n_slots=2, name="a"), dict(n_slots=2, name="b"),
                 policy=LocalityAware(locality_weight=0.5))
    try:
        gate = threading.Event()
        a, b = pool.pilots
        t = translate(lambda: 1, (), {})
        t.affinity = (b.uid,)
        assert pool.route(t) is b           # equal load: affinity wins

        _occupy(b, 1, gate)                 # load gap 0.5 == weight: the
        time.sleep(0.05)                    # affinity bonus no longer wins
        t2 = translate(lambda: 1, (), {})
        t2.affinity = (b.uid,)
        assert pool.route(t2) is a

        # by-name hints work too (device hints name pilots, not uids)
        t3 = translate(lambda: 1, (), {},
                       ResourceSpec(affinity=("a",)))
        assert t3.affinity == ("a",)
        assert pool.route(t3) is a
        gate.set()
    finally:
        gate.set()
        pool.close()


def test_affinity_match_fractions():
    pool = _pool(dict(n_slots=1, name="a"), dict(n_slots=1, name="b"))
    try:
        a, b = pool.pilots
        t = translate(lambda: 1, (), {})
        assert affinity_match(t, a) == 0.0            # no hints
        t.affinity = (a.uid, b.uid)
        assert affinity_match(t, a) == 0.5
        t.affinity = (a.uid, "a")
        assert affinity_match(t, a) == 1.0
        assert affinity_match(t, b) == 0.0
    finally:
        pool.close()


def test_locality_weight_zero_degenerates_to_least_loaded():
    pool = _pool(dict(n_slots=2, name="a"), dict(n_slots=2, name="b"),
                 policy=LocalityAware(locality_weight=0.0))
    try:
        t = translate(lambda: 1, (), {})
        t.affinity = (pool.pilots[1].uid,)
        # zero weight: affinity ignored, first-of-equals like LeastLoaded
        assert pool.route(t) is pool.pilots[0]
    finally:
        pool.close()


# ------------------------------ tie-breaks ------------------------------- #

def test_tie_breaks_compose_after_primary_score():
    """prefer_specialized steers equal-load ties onto kind-restricted
    pilots so generalists stay free; without it, enumeration order
    rules."""
    descs = [dict(n_slots=2, name="generalist"),
             dict(n_slots=2, name="pyonly", kinds=("python", "bash"))]
    plain = _pool(*descs)
    tied = _pool(*descs,
                 policy=LeastLoaded(tie_breaks=(prefer_specialized,)))
    try:
        t = translate(lambda: 1, (), {})
        assert plain.route(t).desc.name == "generalist"   # listing order
        assert tied.route(t).desc.name == "pyonly"        # tie-break
    finally:
        plain.close()
        tied.close()


# --------------------------- steal eligibility --------------------------- #

def test_locality_steal_gate_weighs_affinity_against_imbalance():
    policy = LocalityAware(locality_weight=0.5)
    pool = _pool(dict(n_slots=2, name="v"), dict(n_slots=2, name="th"))
    try:
        victim, thief = pool.pilots
        free = translate(lambda: 1, (), {})
        assert policy.steal_eligible(free, thief, victim, imbalance=0.01)

        affine = translate(lambda: 1, (), {})
        affine.affinity = (victim.uid,)
        # penalty = 0.5: a small backlog does not justify the move...
        assert not policy.steal_eligible(affine, thief, victim,
                                         imbalance=0.25)
        # ...a starving backlog does
        assert policy.steal_eligible(affine, thief, victim, imbalance=1.0)

        # affinity *toward the thief* makes stealing a win at any load
        toward = translate(lambda: 1, (), {})
        toward.affinity = (thief.uid,)
        assert policy.steal_eligible(toward, thief, victim, imbalance=0.0)
    finally:
        pool.close()


def test_affine_tasks_stay_put_when_backlog_is_small():
    """End-to-end: with LocalityAware, a hungry sibling does not strip a
    short affine backlog off the victim (LeastLoaded would)."""
    pool = _pool(dict(n_slots=1, name="v"), dict(n_slots=1, name="th"),
                 steal=False, policy=LocalityAware(locality_weight=2.0))
    try:
        victim, thief = pool.pilots
        gate = threading.Event()
        _occupy(victim, 1, gate)            # occupy the only slot
        time.sleep(0.05)
        affine = translate(lambda: "x", (), {})
        affine.affinity = (victim.uid,)
        victim.agent.submit(affine)         # queued: backlog of 1 slot

        # imbalance 1.0 < weight 2.0: the gate refuses the migration
        assert pool.request_work(thief) == 0
        assert victim.agent.queued_demand() == 1
        gate.set()
        assert victim.agent.wait_idle(timeout=10)
        assert affine.state == TaskState.DONE
        assert affine.pilot_uid != thief.uid
    finally:
        gate.set()
        pool.close()


# ------------------------- pick_template (scaling) ----------------------- #

def test_pick_template_matches_starving_kinds():
    policy = PlacementPolicy()
    cpu = PilotDescription(name="cpu-t", kinds=("python", "bash"))
    dev = PilotDescription(name="dev-t", kinds=("spmd",))
    anyk = PilotDescription(name="any-t")

    # single template: PR-2 clone regardless of the queue
    assert policy.pick_template([(("spmd",), 8)], [cpu]) is cpu
    # empty starving queue: first template
    assert policy.pick_template([], [cpu, dev]) is cpu
    # demand decides: 8 starving spmd slots beat 2 python slots
    starving = [(("python",), 1), (("python",), 1), (("spmd", "device"), 8)]
    assert policy.pick_template(starving, [cpu, dev]) is dev
    assert policy.pick_template([(("python",), 4)], [cpu, dev]) is cpu
    # a kinds=None generalist covers everything but loses specialization
    # ties: equal coverage prefers the restricted template
    assert policy.pick_template([(("spmd",), 4)], [anyk, dev]) is dev
    # ...yet wins when only it covers the demand
    assert policy.pick_template([(("weird",), 4)], [cpu, dev, anyk]) is anyk


# ----------------------- affinity stamp threading ------------------------ #

def test_translator_merges_static_and_runtime_affinity():
    res = ResourceSpec(affinity=("dev0", "dev1"))
    t = translate(lambda: 1, (), {}, res, affinity=("dev1", "pilotX"))
    assert t.affinity == ("dev0", "dev1", "pilotX")     # deduped, ordered
    t2 = translate(lambda: 1, (), {})
    assert t2.affinity == ()

    @python_app(affinity=("warm",))
    def hinted():
        return 1
    fn = hinted.__wrapped_app__
    assert fn.__resources__.affinity == ("warm",)
    # bash translation rebuilds the ResourceSpec; hints must survive
    def cmd():
        return "true"
    cmd.__is_bash__ = True
    tb = translate(cmd, (), {}, ResourceSpec(affinity=("warm",)))
    assert tb.affinity == ("warm",)


def test_dfk_stamps_producer_pilot_as_consumer_affinity():
    """The dep manager records where each input was produced; the
    consumer's translated task carries those pilots in its affinity."""
    rpex = RPEXExecutor([PilotDescription(n_slots=2, name="only")])
    try:
        @python_app
        def produce():
            return 2

        @python_app
        def consume(x, y):
            return x + y["nested"][0]

        with DataFlowKernel(executors={"rpex": rpex}):
            f1, f2 = produce(), produce()
            g = consume(f1, {"nested": [f2]})
            assert g.result(timeout=15) == 4
        producer_pilots = {f1.task.pilot_uid, f2.task.pilot_uid}
        assert producer_pilots == {rpex.pilot.uid}
        assert set(g.task.affinity) == producer_pilots
    finally:
        rpex.shutdown()


def test_locality_consumer_follows_producer_pilot():
    """Two idle pilots: a consumer chain under LocalityAware stays on its
    producer's pilot end-to-end instead of ping-ponging by load.  The
    weight is set far above any transient backlog so the steal gate can
    never justify a migration — chains must stay put deterministically."""
    rpex = RPEXExecutor([PilotDescription(n_slots=2, name="p0"),
                         PilotDescription(n_slots=2, name="p1")],
                        placement=LocalityAware(locality_weight=8.0))
    try:
        @python_app
        def step(x):
            time.sleep(0.01)
            return x + 1

        with DataFlowKernel(executors={"rpex": rpex}):
            chains = []
            for _ in range(4):
                futs = [step(0)]
                for _ in range(3):
                    futs.append(step(futs[-1]))
                chains.append(futs)
            for futs in chains:
                assert futs[-1].result(timeout=15) == 4
        for futs in chains:
            pilots = {f.task.pilot_uid for f in futs}
            assert len(pilots) == 1, \
                f"chain migrated across pilots: {pilots}"
    finally:
        rpex.shutdown()
