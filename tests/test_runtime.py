"""Event-driven multi-pilot runtime behaviour: PilotPool routing, the
condition-variable scheduler (no missed wakeups, no polling), persistent
worker pool, and prompt event-based shutdown."""
import threading
import time

import pytest

from repro.core import (DataFlowKernel, PilotDescription, PilotPool,
                        ResourceSpec, RPEXExecutor, TaskState, python_app,
                        spmd_app, translate)


def _hetero_rpex():
    return RPEXExecutor([
        PilotDescription(n_slots=4, kinds=("python", "bash", "cpu"),
                         name="cpu"),
        PilotDescription(n_slots=4, kinds=("spmd", "device"), name="dev"),
    ])


# ------------------------------ routing -------------------------------- #

def test_pool_routes_kinds_to_different_pilots():
    """An RPEXExecutor backed by 2 pilots sends kind="python" and
    kind="spmd" tasks to different, kind-compatible pilots."""
    rpex = _hetero_rpex()
    try:
        @python_app
        def py_task(x):
            return x + 1

        @spmd_app(slots=2, jit=False)
        def dev_task(mesh):
            return "spmd-ok"

        with DataFlowKernel(executors={"rpex": rpex}):
            fp = py_task(1)
            fd = dev_task()
            assert fp.result() == 2
            assert fd.result() == "spmd-ok"

        cpu_pilot = rpex.pool.pilots[0]
        dev_pilot = rpex.pool.pilots[1]
        assert fp.task.kind == "python" and fd.task.kind == "spmd"
        assert fp.task.pilot_uid == cpu_pilot.uid
        assert fd.task.pilot_uid == dev_pilot.uid
        assert fp.task.pilot_uid != fd.task.pilot_uid
        assert fp.task.res_kind == "cpu" and fd.task.res_kind == "device"
    finally:
        rpex.shutdown()


def test_pool_rejects_unroutable_kind():
    pool = PilotPool([PilotDescription(n_slots=2, kinds=("spmd",))])
    try:
        t = translate(lambda: None, (), {})       # kind="python"
        with pytest.raises(RuntimeError, match="no pilot accepts"):
            pool.route(t)
    finally:
        pool.close()


def test_unroutable_task_fails_future_not_thread():
    """A task no pilot accepts resolves its future with the routing error —
    in stream and in bulk mode (where routing runs in the flush timer
    thread) — and never hangs the rest of the batch."""
    rpex = RPEXExecutor(PilotDescription(n_slots=2, kinds=("spmd",)))
    try:
        @python_app
        def nope():
            return 1

        @spmd_app(slots=1, jit=False)
        def ok(mesh):
            return "ok"

        with DataFlowKernel(executors={"rpex": rpex}, bulk=True) as dfk:
            f_bad = nope()
            f_ok = ok()
            dfk.flush()
            assert f_ok.result(timeout=10) == "ok"   # batch not dropped
            with pytest.raises(RuntimeError, match="no pilot accepts"):
                f_bad.result(timeout=10)
        with DataFlowKernel(executors={"rpex": rpex}):
            with pytest.raises(RuntimeError, match="no pilot accepts"):
                nope().result(timeout=10)            # stream mode too
        assert rpex.tmgr.wait(timeout=5)             # nothing left hanging
    finally:
        rpex.shutdown()


def test_bash_app_routes_to_bash_pilot():
    """@bash_app tasks execute as kind="python" but route on their
    pre-translation app kind, so kinds=("bash",) pilots receive them."""
    from repro.core import bash_app

    rpex = RPEXExecutor([
        PilotDescription(n_slots=2, kinds=("bash",), name="login"),
        PilotDescription(n_slots=2, kinds=("spmd",), name="dev"),
    ])
    try:
        @bash_app
        def say():
            return "echo routed"

        with DataFlowKernel(executors={"rpex": rpex}):
            f = say()
            assert f.result(timeout=10).strip() == "routed"
        assert f.task.pilot_uid == rpex.pool.pilots[0].uid
        assert f.task.app_kind == "bash" and f.task.kind == "python"
    finally:
        rpex.shutdown()


def test_least_loaded_binding_spreads_bulk():
    """Two identical pilots: a bulk batch is spread across both."""
    rpex = RPEXExecutor([PilotDescription(n_slots=2, name="a"),
                         PilotDescription(n_slots=2, name="b")])
    try:
        gate = threading.Event()

        @python_app
        def held(i):
            gate.wait(10)
            return i

        with DataFlowKernel(executors={"rpex": rpex}, bulk=True) as dfk:
            futs = [held(i) for i in range(16)]
            dfk.flush()
            time.sleep(0.3)              # let routing/scheduling settle
            gate.set()
            assert sorted(f.result(timeout=30) for f in futs) == list(range(16))
        pilots_used = {f.task.pilot_uid for f in futs}
        assert len(pilots_used) == 2, "bulk batch never left the first pilot"
    finally:
        rpex.shutdown()


def test_journal_replay_across_pool(tmp_path):
    """Workflow keys recorded on a routed pilot replay through the
    executor-level completed_result lookup."""
    @python_app
    def work(x):
        return x * 7

    calls = []

    @python_app
    def count(x):
        calls.append(x)
        return x

    j1 = str(tmp_path / "cpu.jsonl")
    descs = lambda: [PilotDescription(n_slots=2, kinds=("python", "bash"),
                                      journal=j1, name="cpu"),
                     PilotDescription(n_slots=2, kinds=("spmd",), name="dev")]
    r1 = RPEXExecutor(descs())
    with DataFlowKernel(executors={"rpex": r1}, run_id="rr"):
        assert work(6).result() == 42
    r1.shutdown()
    r2 = RPEXExecutor(descs())
    with DataFlowKernel(executors={"rpex": r2}, run_id="rr"):
        assert work(6).result() == 42     # resolved from journal replay
    r2.shutdown()
    found, result = r2.completed_result("rr/work:0")
    assert found and result == 42


# ----------------------- condition-variable loop ------------------------ #

def test_release_wakes_blocked_scheduler():
    """A task blocked on allocation is scheduled by the release() wakeup —
    no missed-wakeup deadlock, no polling latency."""
    rpex = RPEXExecutor(PilotDescription(n_slots=2))
    try:
        gate = threading.Event()

        @spmd_app(slots=2, jit=False)
        def hog(mesh):
            gate.wait(10)
            return "hog"

        @spmd_app(slots=2, jit=False)
        def blocked(mesh):
            return "ran"

        with DataFlowKernel(executors={"rpex": rpex}):
            fh = hog()
            time.sleep(0.2)               # hog owns every slot
            fb = blocked()
            time.sleep(0.2)               # blocked() cannot be placed yet
            assert not fb.done()
            t0 = time.monotonic()
            gate.set()
            assert fb.result(timeout=10) == "ran"
            dt = time.monotonic() - t0
            assert fh.result(timeout=10) == "hog"
        # generous bound: the wakeup is event-driven, not a poll tick
        assert dt < 2.0
    finally:
        rpex.shutdown()


def test_stream_submission_storm_no_missed_wakeup():
    """Concurrent stream submissions from several threads all complete —
    a lost cv notification would deadlock this test."""
    rpex = RPEXExecutor(PilotDescription(n_slots=4))
    try:
        @python_app
        def inc(x):
            return x + 1

        results = []
        rlock = threading.Lock()

        def feeder(dfk, base):
            for i in range(40):
                f = dfk.submit(inc.__wrapped_app__, (base + i,))
                with rlock:
                    results.append(f)

        with DataFlowKernel(executors={"rpex": rpex}) as dfk:
            threads = [threading.Thread(target=feeder, args=(dfk, k * 100))
                       for k in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            got = sorted(f.result(timeout=30) for f in results)
        want = sorted(k * 100 + i + 1 for k in range(4) for i in range(40))
        assert got == want
    finally:
        rpex.shutdown()


def test_bulk_batch_runs_concurrently():
    """Tasks scheduled in one pass must execute in parallel: the worker
    pool grows to cover the whole batch, not one worker per pass."""
    rpex = RPEXExecutor(PilotDescription(n_slots=8))
    try:
        barrier = threading.Barrier(8, timeout=10)

        @python_app
        def rendezvous(i):
            barrier.wait()            # deadlocks unless all 8 run at once
            return i

        with DataFlowKernel(executors={"rpex": rpex}, bulk=True) as dfk:
            futs = [rendezvous(i) for i in range(8)]
            dfk.flush()
            assert sorted(f.result(timeout=15) for f in futs) == list(range(8))
    finally:
        rpex.shutdown()


def test_worker_pool_is_persistent():
    """Many more tasks than workers reuse the same pool threads instead of
    spawning one thread per task."""
    rpex = RPEXExecutor(PilotDescription(n_slots=4, max_workers=4))
    try:
        @python_app
        def noop(i):
            return i

        with DataFlowKernel(executors={"rpex": rpex}):
            futs = [noop(i) for i in range(100)]
            assert [f.result(timeout=30) for f in futs] == list(range(100))
        agent = rpex.pilot.agent
        assert agent.transport.n_threads <= 4
    finally:
        rpex.shutdown()


# ------------------------------ shutdown -------------------------------- #

def test_shutdown_returns_promptly_when_idle():
    rpex = RPEXExecutor(PilotDescription(n_slots=4))

    @python_app
    def one():
        return 1

    with DataFlowKernel(executors={"rpex": rpex}):
        assert one().result() == 1
    t0 = time.monotonic()
    rpex.pilot.agent.shutdown()           # idle: event wait returns at once
    assert time.monotonic() - t0 < 1.0
    rpex.shutdown()


def test_shutdown_waits_for_inflight_then_returns():
    rpex = RPEXExecutor(PilotDescription(n_slots=2))

    @python_app
    def slowish():
        time.sleep(0.4)
        return "done"

    with DataFlowKernel(executors={"rpex": rpex}):
        f = slowish()
        time.sleep(0.05)                  # ensure it is in flight
        t0 = time.monotonic()
        rpex.pilot.agent.shutdown(wait=True, timeout=10)
        dt = time.monotonic() - t0
        assert f.done() and f.result() == "done"
        assert dt < 5.0
    rpex.shutdown()


# --------------------------- event stream ------------------------------- #

def test_state_store_unified_event_stream():
    rpex = _hetero_rpex()
    try:
        @python_app
        def job(x):
            return x

        with DataFlowKernel(executors={"rpex": rpex}):
            assert job(9).result() == 9

        events = rpex.pool.events()
        kinds = {e["event"] for e in events}
        assert {"PILOT_START", "ROUTED", "STATE"} <= kinds
        states = [e["state"] for e in events if e.get("event") == "STATE"]
        for s in ("TRANSLATED", "SCHEDULED", "LAUNCHING", "RUNNING", "DONE"):
            assert s in states
        # per-pilot utilization is derivable from the stream
        util = rpex.utilization()
        assert set(util) == {p.uid for p in rpex.pool.pilots}
        fig6 = rpex.pilot.store.utilization(rpex.pilot.n_slots)
        assert abs(sum(fig6.values()) - 1.0) < 1e-6
    finally:
        rpex.shutdown()


def test_taskmanager_wait_subset_and_timeout():
    rpex = RPEXExecutor(PilotDescription(n_slots=2))
    try:
        gate = threading.Event()

        def quick():
            return "q"

        def slow():
            gate.wait(10)
            return "s"

        tq = translate(quick, (), {})
        ts = translate(slow, (), {})
        rpex.tmgr.submit(tq)
        rpex.tmgr.submit(ts)
        assert rpex.tmgr.wait(uids=[tq.uid], timeout=10)
        assert not rpex.tmgr.wait(timeout=0.2)     # slow still holds
        gate.set()
        assert rpex.tmgr.wait(timeout=10)
        assert ts.state == TaskState.DONE
    finally:
        gate.set()
        rpex.shutdown()


def test_dfk_per_executor_flush():
    """flush(label) drains exactly one executor's pending bulk batch."""
    r1 = RPEXExecutor(PilotDescription(n_slots=2))
    r2 = RPEXExecutor(PilotDescription(n_slots=2))
    r2.label = "rpex2"
    try:
        @python_app(executor="rpex")
        def a(x):
            return x

        @python_app(executor="rpex2")
        def b(x):
            return -x

        with DataFlowKernel(executors={"rpex": r1, "rpex2": r2}, bulk=True,
                            bulk_window=30.0) as dfk:
            fa = [a(i) for i in range(3)]
            fb = [b(i) for i in range(3)]
            dfk.flush("rpex")
            assert [f.result(timeout=10) for f in fa] == [0, 1, 2]
            assert dfk._pending_bulk.get("rpex2")  # still queued
            dfk.flush()
            assert [f.result(timeout=10) for f in fb] == [0, -1, -2]
    finally:
        r1.shutdown()
        r2.shutdown()
