"""Property tests for the device-slot scheduler (RP Agent analog)."""
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # fall back to the vendored shim
    from _propshim import given, settings, st

from repro.core.scheduler import SlotScheduler, _align_of


def test_align_of():
    assert [_align_of(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]


def test_basic_alloc_release():
    s = SlotScheduler(16)
    a = s.allocate("t1", 4)
    assert a == (0, 1, 2, 3)
    b = s.allocate("t2", 4)
    assert b == (4, 5, 6, 7)
    assert s.n_free == 8
    s.release("t1")
    c = s.allocate("t3", 8)
    assert c == (8, 9, 10, 11, 12, 13, 14, 15)
    d = s.allocate("t4", 4)
    assert d == (0, 1, 2, 3)          # reused released block
    assert s.allocate("t5", 4) is None


def test_alignment_prevents_straddle():
    s = SlotScheduler(16)
    s.allocate("a", 2)                 # 0-1
    got = s.allocate("b", 8)           # must start at 8, not 2
    assert got == tuple(range(8, 16))


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "release", "fail",
                                           "grow", "shrink"]),
                          st.integers(1, 16)), min_size=1, max_size=60))
def test_invariants_under_churn(ops):
    s = SlotScheduler(32)
    live = {}
    i = 0
    for op, n in ops:
        i += 1
        if op == "alloc":
            uid = f"t{i}"
            got = s.allocate(uid, n)
            if got is not None:
                assert len(got) == n
                # contiguity + alignment
                assert list(got) == list(range(got[0], got[0] + n))
                assert got[0] % _align_of(n) == 0
                # no overlap with any live allocation
                for other in live.values():
                    assert not (set(got) & set(other))
                live[uid] = got
        elif op == "release" and live:
            uid = sorted(live)[n % len(live)]
            s.release(uid)
            del live[uid]
        elif op == "fail":
            victims = s.mark_failed([n % 32])
            for v in victims:
                s.release(v)           # agent would fail+release the task
                live.pop(v, None)
        elif op == "grow":
            s.grow(n)
        elif op == "shrink":
            s.shrink(n)
    # capacity accounting: free + busy == capacity
    assert s.n_free + s.n_busy == s.capacity


def _check_invariants(s: SlotScheduler, live: dict):
    """Full invariant battery, checked after *every* op, not just at the
    end of a sequence."""
    # free + busy == capacity
    assert s.n_free + s.n_busy == s.capacity
    # interval list is sorted, disjoint, and coalesced
    blocks = s.free_blocks()
    for b0, b1 in blocks:
        assert b0 < b1
    for (a0, a1), (b0, b1) in zip(blocks, blocks[1:]):
        assert a1 < b0, f"blocks {blocks} not sorted/disjoint/coalesced"
    # no live allocation overlaps another, a free block, or a failed slot
    free = {x for b0, b1 in blocks for x in range(b0, b1)}
    seen = set()
    for uid, slots in live.items():
        got = set(slots)
        assert not (got & seen), "overlapping allocations"
        assert not (got & free), "allocated slot also marked free"
        assert not (got & s._failed), "allocated slot marked failed"
        seen |= got
        # contiguity + power-of-2 aligned start
        lo = min(slots)
        assert sorted(slots) == list(range(lo, lo + len(slots)))
        assert lo % _align_of(len(slots)) == 0


def _churn(ops, n_slots=32):
    """Drive a random op sequence, verifying invariants at every step."""
    s = SlotScheduler(n_slots)
    live = {}
    i = 0
    for op, n in ops:
        i += 1
        if op == "alloc":
            uid = f"t{i}"
            got = s.allocate(uid, n)
            if got is not None:
                assert len(got) == n
                live[uid] = got
        elif op == "release" and live:
            uid = sorted(live)[n % len(live)]
            s.release(uid)
            del live[uid]
        elif op == "fail":
            victims = s.mark_failed([n % (n_slots * 2)])
            for v in victims:
                s.release(v)           # agent would fail+release the task
                live.pop(v, None)
        elif op == "grow":
            s.grow(n)
        elif op == "shrink":
            s.shrink(n)
        _check_invariants(s, live)


@settings(max_examples=150, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "release", "fail",
                                           "grow", "shrink"]),
                          st.integers(1, 16)), min_size=1, max_size=40))
def test_stepwise_invariants_under_churn(ops):
    """free+busy == capacity, no overlapping allocations, aligned starts,
    and a sorted/disjoint/coalesced free-interval list — after every
    single allocate/release/grow/shrink/mark_failed, not just at the end."""
    _churn(ops)


@pytest.mark.slow
@settings(max_examples=500, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "release", "fail",
                                           "grow", "shrink"]),
                          st.integers(1, 32)), min_size=1, max_size=120))
def test_stepwise_invariants_under_churn_deep(ops):
    """The heavy version of the churn property (longer sequences, larger
    requests, more examples) — runs in CI's dedicated property-test job."""
    _churn(ops)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 8), min_size=1, max_size=20))
def test_liveness_all_tasks_eventually_run(sizes):
    """Any finite task list completes: allocate/release in waves."""
    s = SlotScheduler(8)
    pending = [(f"t{i}", n) for i, n in enumerate(sizes)]
    done = []
    for _ in range(1000):
        if not pending:
            break
        still = []
        for uid, n in pending:
            got = s.allocate(uid, n)
            if got is None:
                still.append((uid, n))
            else:
                done.append(uid)
                s.release(uid)
        pending = still
    assert not pending


def test_largest_free_block_always_allocatable():
    """The documented no-lost-capacity invariant: any request up to the
    largest aligned free block must succeed."""
    s = SlotScheduler(16)
    s.allocate("a", 2)
    s.allocate("b", 4)
    s.release("a")
    n = s.largest_free_block()
    assert n == 8                      # [8, 16) is free and aligned
    assert s.allocate("c", n) is not None
    assert s.largest_free_block() == 4  # [0, 4): b was aligned to slot 4
    assert s.allocate("d", 4) == (0, 1, 2, 3)


def test_mark_failed_out_of_extent_is_noop():
    """Found by test_stepwise_invariants_under_churn_deep: failing a slot
    id that was never part of the extent used to decrement capacity (and
    poison the failed set with ids a later grow() would hand out)."""
    s = SlotScheduler(8)
    assert s.mark_failed([40]) == []
    assert s.capacity == 8 and s.n_free == 8
    assert s.mark_failed([-1]) == []
    assert s.capacity == 8
    s.grow(40)                          # extent now covers slot 40
    assert s.n_free + s.n_busy == s.capacity == 48
    got = s.allocate("t", 48)
    assert got is not None and 40 in got


def test_failed_slots_never_reallocated():
    s = SlotScheduler(8)
    s.mark_failed([0, 1, 2, 3])
    got = s.allocate("t", 4)
    assert got == (4, 5, 6, 7)
    assert s.allocate("t2", 2) is None  # only failed slots remain
    s.release("t")
    assert s.allocate("t3", 4) == (4, 5, 6, 7)
