"""DFK + translator + RPEX + agent integration behaviour."""
import time

import jax
import jax.numpy as jnp
import pytest

from repro.core import (DataFlowKernel, PilotDescription, ResourceSpec,
                        RPEXExecutor, TaskState, ThreadPoolExecutor,
                        bash_app, python_app, spmd_app, translate,
                        detect_kind)


@pytest.fixture()
def rpex():
    ex = RPEXExecutor(PilotDescription(n_slots=8))
    yield ex
    ex.shutdown()


def test_translator_kind_detection():
    @python_app
    def f():
        return 1

    @spmd_app(slots=2)
    def g(mesh):
        return 2

    @bash_app
    def h():
        return "echo hi"

    assert detect_kind(f.__wrapped_app__) == "python"
    assert detect_kind(g.__wrapped_app__) == "spmd"
    assert detect_kind(h.__wrapped_app__) == "bash"
    t = translate(g.__wrapped_app__, (), {})
    assert t.resources.slots == 2
    assert t.kind == "spmd"


def test_resource_spec_validation():
    with pytest.raises(ValueError):
        ResourceSpec(slots=0)
    with pytest.raises(ValueError):
        ResourceSpec(slots=4, mesh_shape=(3, 2))
    ResourceSpec(slots=6, mesh_shape=(3, 2))


def test_dataflow_dependencies(rpex):
    order = []

    @python_app
    def a():
        order.append("a")
        return 1

    @python_app
    def b(x):
        order.append("b")
        return x + 1

    @python_app
    def c(x, y):
        order.append("c")
        return x + y

    with DataFlowKernel(executors={"rpex": rpex}):
        fa = a()
        fb = b(fa)
        fc = c(fa, fb)
        assert fc.result() == 3
    assert order.index("a") < order.index("b") < order.index("c")


def test_failure_propagates_downstream(rpex):
    @python_app
    def boom():
        raise ValueError("boom")

    @python_app
    def after(x):
        return x

    with DataFlowKernel(executors={"rpex": rpex}):
        f1 = boom()
        f2 = after(f1)
        with pytest.raises(ValueError):
            f2.result()


def test_spmd_submesh_collective(rpex):
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    @spmd_app(slots=4)
    def psum_task(mesh, x):
        arr = jnp.arange(8.0) * x
        f = shard_map(lambda a: jax.lax.psum(a.sum(), "data"),
                      mesh=mesh, in_specs=P("data"), out_specs=P())
        return f(arr)

    with DataFlowKernel(executors={"rpex": rpex}):
        assert float(psum_task(2).result()) == 56.0


def test_executable_cache_reuse(rpex):
    @spmd_app(slots=2)
    def t(mesh, x):
        return x * 2.0

    with DataFlowKernel(executors={"rpex": rpex}):
        futs = [t(float(i)) for i in range(8)]
        assert [f.result() for f in futs] == [i * 2.0 for i in range(8)]
    assert rpex.pilot.executor.stats["compiles"] == 1
    assert rpex.pilot.executor.stats["cache_hits"] >= 7


def test_bulk_submission(rpex):
    @python_app
    def inc(x):
        return x + 1

    with DataFlowKernel(executors={"rpex": rpex}, bulk=True) as dfk:
        futs = [inc(i) for i in range(20)]
        dfk.flush()
        assert [f.result() for f in futs] == list(range(1, 21))


def test_retry_on_failure(rpex):
    attempts = []

    @python_app(retries=2)
    def flaky():
        attempts.append(1)
        if len(attempts) < 3:
            raise RuntimeError("transient")
        return "ok"

    with DataFlowKernel(executors={"rpex": rpex}):
        assert flaky().result() == "ok"
    assert len(attempts) == 3


def test_slot_failure_mid_run(rpex):
    import threading
    release = threading.Event()

    @spmd_app(slots=2, retries=1, jit=False)
    def slow(mesh):
        release.wait(5.0)
        return "done"

    with DataFlowKernel(executors={"rpex": rpex}):
        f = slow()
        time.sleep(0.3)                      # let it start running
        victims = rpex.pilot.agent.inject_slot_failure([0, 1])
        release.set()
        # first attempt fails (poisoned error), retry lands on good slots
        assert f.result(timeout=30) == "done"
    assert rpex.pilot.scheduler.capacity == 6


def test_elastic_grow_shrink(rpex):
    p = rpex.pilot
    assert p.n_slots == 8
    p.grow(8)
    assert p.n_slots == 16

    @spmd_app(slots=16, jit=False)
    def wide(mesh):
        return "wide-ok"

    with DataFlowKernel(executors={"rpex": rpex}):
        assert wide().result() == "wide-ok"
    p.shrink(8)
    assert p.n_slots == 8


def test_threadpool_executor_baseline():
    @python_app
    def f(x):
        return x * 3

    with DataFlowKernel(executors={"threads": ThreadPoolExecutor(4)}):
        assert f(5).result() == 15


def test_priority_scheduling(rpex):
    """Higher-priority tasks jump the wait queue."""
    import threading
    gate = threading.Event()
    ran = []

    @spmd_app(slots=8, jit=False)
    def hog(mesh):
        gate.wait(10)
        return "hog"

    @spmd_app(slots=8, jit=False, priority=0)
    def low(mesh):
        ran.append("low")
        return "low"

    @spmd_app(slots=8, jit=False, priority=5)
    def high(mesh):
        ran.append("high")
        return "high"

    with DataFlowKernel(executors={"rpex": rpex}):
        fh = hog()
        time.sleep(0.2)
        fl = low()
        fg = high()
        time.sleep(0.2)
        gate.set()
        fl.result(timeout=30)
        fg.result(timeout=30)
    assert ran[0] == "high"
