"""StateStore write-behind journal: group commit, drain-on-close,
torn-tail tolerance, the O(1) completed_result index, incremental
utilization/overhead counters, journal compaction, and event-stream
rebuild on restart (the PR-2 _replay bug: only `tasks` survived, so
post-restart utilization()/rp_overhead() silently undercounted)."""
import json
import threading
import time

import pytest

from repro.core import (DataFlowKernel, PilotDescription, RPEXExecutor,
                        StateStore, TaskRecord, TaskState, python_app,
                        overhead_from_events)

pytestmark = pytest.mark.timeout(120)     # journal-heavy: fail fast, not wedge


def drive(store, uid, key=None, result=None, slots=(), fail_first=False):
    """Record a full task lifecycle through the store."""
    t = TaskRecord(uid=uid, kind="python")
    t.slot_ids = tuple(slots)
    for st in (TaskState.TRANSLATED, TaskState.SCHEDULED,
               TaskState.LAUNCHING, TaskState.RUNNING):
        t.state = st
        store.record(t, workflow_key=key)
    if fail_first:
        t.state = TaskState.FAILED
        store.record(t, workflow_key=key)
        for st in (TaskState.SCHEDULED, TaskState.LAUNCHING,
                   TaskState.RUNNING):
            t.state = st
            store.record(t, workflow_key=key)
    t.result = result
    t.state = TaskState.DONE
    store.record(t, workflow_key=key)
    return t


# --------------------------- write-behind ------------------------------- #

def test_group_commit_drains_on_close(tmp_path):
    """Records buffered in the write-behind queue all land on disk by the
    time close() returns — a clean shutdown loses nothing."""
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j))
    for i in range(500):
        drive(s, f"t{i}", key=f"k{i}", result=i)
    s.close()
    s2 = StateStore(str(j))
    assert len(s2.tasks) == 500
    for i in (0, 123, 499):
        found, result = s2.completed_result(f"k{i}")
        assert found and result == i
    s2.close()


def test_flush_makes_records_durable_without_close(tmp_path):
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j))
    drive(s, "t0", key="k0", result="r0")
    assert s.flush(timeout=10)
    lines = [json.loads(l) for l in j.read_text().splitlines()]
    assert any(r.get("uid") == "t0" and r.get("state") == "DONE"
               for r in lines)
    s.close()


def test_torn_tail_tolerated(tmp_path):
    """A partial (crash-torn) final line is skipped on replay; everything
    before it survives."""
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j))
    drive(s, "a", key="ka", result=1)
    drive(s, "b", key="kb", result=2)
    s.close()
    with open(j, "a") as fh:
        fh.write('{"uid": "c", "state": "DO')     # torn mid-record
    s2 = StateStore(str(j))
    assert set(s2.tasks) == {"a", "b"}
    assert s2.completed_result("ka") == (True, 1)
    assert s2.completed_result("kb") == (True, 2)
    s2.close()


def test_record_after_close_is_memory_only(tmp_path):
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j))
    drive(s, "a", key="ka", result=1)
    s.close()
    drive(s, "late", key="klate", result=9)       # must not raise
    assert s.completed_result("klate") == (True, 9)   # in memory
    s2 = StateStore(str(j))
    assert "late" not in s2.tasks                 # never journaled
    s2.close()
    s.close()                                     # idempotent


def test_concurrent_recorders_lose_nothing(tmp_path):
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j))

    def work(base):
        for i in range(100):
            drive(s, f"t{base}-{i}", key=f"k{base}-{i}", result=i)

    threads = [threading.Thread(target=work, args=(b,)) for b in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    s.close()
    s2 = StateStore(str(j))
    assert len(s2.tasks) == 400
    for b in range(4):
        assert s2.completed_result(f"k{b}-99") == (True, 99)
    s2.close()


def test_non_jsonable_result_dropped_from_disk_and_not_pinned(tmp_path):
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j))
    blob = object()                               # not JSON-serializable
    drive(s, "t0", key="k0", result=blob)
    assert s.flush(timeout=10)
    # once the writer slims the journal line it also unpins the result
    # from the in-memory maps — big device arrays must not accumulate
    found, _ = s.completed_result("k0")
    assert not found
    assert s.tasks["t0"]["state"] == "DONE"
    s.close()
    s2 = StateStore(str(j))
    found, _ = s2.completed_result("k0")          # line was slimmed down
    assert not found
    assert s2.tasks["t0"]["state"] == "DONE"      # record itself survived
    s2.close()


def test_writer_io_error_kills_journal_not_store(tmp_path):
    """A disk error in the writer thread (e.g. ENOSPC) marks the journal
    dead instead of silently killing the writer and wedging producers in
    backpressure: record() keeps working memory-only and never blocks."""
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j), max_queue=8)

    class _BrokenFile:
        def write(self, _):
            raise OSError(28, "No space left on device")

        def flush(self):
            pass

        def close(self):
            pass

    drive(s, "t-pre", key="kpre", result=0)
    assert s.flush(timeout=10)
    with s._lock:
        s._fh.close()
        s._fh = _BrokenFile()
    t0 = time.monotonic()
    for i in range(64):                      # >> max_queue: must not wedge
        drive(s, f"t{i}", key=f"k{i}", result=i)
    assert time.monotonic() - t0 < 10
    deadline = time.monotonic() + 5
    while s.journal_error is None and time.monotonic() < deadline:
        time.sleep(0.01)
    assert s.journal_error and "No space left" in s.journal_error
    assert s.completed_result("k63") == (True, 63)   # memory still live
    # the dead journal discarded queued records: flush() must not claim
    # durability for them
    assert not s.flush(timeout=5)
    s.close()                                # still clean to close
    s2 = StateStore(str(j))                  # pre-failure records survive
    assert s2.completed_result("kpre") == (True, 0)
    s2.close()


# ------------------------- O(1) key index ------------------------------- #

class _NoScanDict(dict):
    def values(self):
        raise AssertionError("completed_result scanned the task table")

    def items(self):
        raise AssertionError("completed_result scanned the task table")


def test_completed_result_is_indexed_not_scanned():
    s = StateStore()
    for i in range(50):
        drive(s, f"t{i}", key=f"k{i}", result=i)
    s.tasks = _NoScanDict(s.tasks)                # poison any scan
    for i in (0, 25, 49):
        assert s.completed_result(f"k{i}") == (True, i)
    assert s.completed_result("nope") == (False, None)
    s.close()


def test_done_record_not_displaced_by_later_incomplete_resubmission():
    """A completed (DONE + result) record keeps answering for its key even
    if a different task is later recorded under the same key without
    finishing — matching the old scan's 'find any completed' semantics."""
    s = StateStore()
    drive(s, "t-done", key="wf/app:0", result=42)
    t2 = TaskRecord(uid="t-retry", kind="python")
    t2.state = TaskState.TRANSLATED
    s.record(t2, workflow_key="wf/app:0")
    assert s.completed_result("wf/app:0") == (True, 42)
    # a different task completing under the same key does not displace the
    # first completion either (the old scan returned the first-inserted
    # completed record; and the newcomer's result may later be stripped
    # as non-serializable, which must not lose the key)
    t2.result = 43
    t2.state = TaskState.DONE
    s.record(t2, workflow_key="wf/app:0")
    assert s.completed_result("wf/app:0") == (True, 42)
    # the same task progressing does update its own entry
    t1 = TaskRecord(uid="t-done", kind="python")
    t1.result = 44
    t1.state = TaskState.DONE
    s.record(t1, workflow_key="wf/app:0")
    assert s.completed_result("wf/app:0") == (True, 44)
    s.close()


# ---------------------- incremental counters ---------------------------- #

def _offline_utilization(events, capacity):
    """The PR-2 full-stream recomputation, kept here as the reference."""
    slots = {}
    evs = [e for e in events if e.get("event") == "STATE"]
    for e in evs:
        slots[e["uid"]] = max(slots.get(e["uid"], 1), e.get("slots", 1))
    tl = {}
    for e in evs:
        tl.setdefault(e["uid"], {}).setdefault(e["state"], e["t"])
    if not tl:
        return {"Scheduled": 0.0, "Launching": 0.0, "Running": 0.0,
                "Idle": 1.0}
    all_t = [t for ts in tl.values() for t in ts.values()]
    t0, t1 = min(all_t), max(all_t)
    occ = {"Scheduled": 0.0, "Launching": 0.0, "Running": 0.0}
    ends_states = ("DONE", "FAILED", "CANCELED")
    for uid, ts in tl.items():
        n = slots.get(uid, 1)
        if "SCHEDULED" in ts and "LAUNCHING" in ts:
            occ["Scheduled"] += n * (ts["LAUNCHING"] - ts["SCHEDULED"])
        if "LAUNCHING" in ts and "RUNNING" in ts:
            occ["Launching"] += n * (ts["RUNNING"] - ts["LAUNCHING"])
        ends = [ts[s] for s in ends_states if s in ts]
        if "RUNNING" in ts and ends:
            occ["Running"] += n * max(0.0, min(ends) - ts["RUNNING"])
    total = max(capacity * (t1 - t0), 1e-12)
    scale = min(1.0, total / max(sum(occ.values()), 1e-12))
    occ = {k: v * scale for k, v in occ.items()}
    out = {k: v / total for k, v in occ.items()}
    out["Idle"] = max(0.0, 1.0 - sum(out.values()))
    return out


def test_incremental_counters_match_offline_recompute():
    s = StateStore()
    for i in range(40):
        drive(s, f"t{i}", slots=(i % 3,) * (i % 3 or 1),
              fail_first=(i % 7 == 0))
    events = s.events_snapshot()
    want = _offline_utilization(events, capacity=8)
    got = s.utilization(8)
    for k in want:
        assert got[k] == pytest.approx(want[k], abs=1e-9), k
    assert s.overhead() == pytest.approx(overhead_from_events(events),
                                         abs=1e-9)
    # timeline cache matches first-occurrence reconstruction
    tl = s.timeline()
    for e in events:
        if e.get("event") == "STATE":
            assert tl[e["uid"]][e["state"]] <= e["t"]
    s.close()


# ----------------------- restart event rebuild -------------------------- #

def test_replay_rebuilds_event_stream(tmp_path):
    """PR-2 dropped the event stream on restart (only `tasks` came back),
    so post-restart utilization()/rp_overhead() silently undercounted.
    Replay now reconstructs STATE events from the journal's monotonic
    stamps and replays journaled runtime events."""
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j))
    s.record_event("PILOT_START", pilot="p0", n_slots=4)
    for i in range(10):
        drive(s, f"t{i}", key=f"k{i}", result=i)
    util_before = s.utilization(4)
    oh_before = s.overhead()
    n_events = len(s.events_snapshot())
    s.close()

    s2 = StateStore(str(j))
    events = s2.events_snapshot()
    assert len(events) == n_events
    kinds = {e["event"] for e in events}
    assert "PILOT_START" in kinds and "STATE" in kinds
    states = {e["state"] for e in events if e.get("event") == "STATE"}
    assert {"TRANSLATED", "SCHEDULED", "RUNNING", "DONE"} <= states
    for k in util_before:
        assert s2.utilization(4)[k] == pytest.approx(util_before[k],
                                                     rel=1e-6, abs=1e-9)
    assert s2.overhead() == pytest.approx(oh_before, rel=1e-6, abs=1e-9)
    assert s2.timeline()                      # not empty post-restart
    s2.close()


def test_rp_overhead_survives_executor_restart(tmp_path):
    """End-to-end: a restarted RPEXExecutor over the same journal reports
    nonzero rp_overhead from the pre-restart run."""
    journal = str(tmp_path / "wf.jsonl")

    @python_app
    def work(x):
        time.sleep(0.01)
        return x + 1

    r1 = RPEXExecutor(PilotDescription(n_slots=2, journal=journal))
    with DataFlowKernel(executors={"rpex": r1}, run_id="rr"):
        assert work(1).result() == 2
    oh1 = r1.rp_overhead()
    r1.shutdown()
    assert oh1 > 0

    r2 = RPEXExecutor(PilotDescription(n_slots=2, journal=journal))
    oh2 = r2.rp_overhead()                    # before running anything new
    assert oh2 == pytest.approx(oh1, rel=1e-6, abs=1e-9)
    util = r2.pilot.store.utilization(2)
    assert util["Idle"] < 1.0                 # history visible, not erased
    r2.shutdown()


# --------------------------- compaction --------------------------------- #

def test_compaction_snapshots_and_preserves_state(tmp_path):
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j), compact_min_lines=64, compact_factor=2)
    # many transitions over few tasks: the journal grows far beyond the
    # live record count, so the writer compacts to snapshot + tail
    for round_ in range(30):
        for i in range(8):
            drive(s, f"t{i}", key=f"k{i}", result=round_)
        s.flush(timeout=10)
    util_before = s.utilization(8)
    oh_before = s.overhead()
    s.close()

    lines = [json.loads(l) for l in j.read_text().splitlines()]
    # 30 rounds x 8 tasks x 6 transitions = 1440 records without compaction
    assert len(lines) < 400, f"journal never compacted: {len(lines)} lines"
    assert any(r.get("event") == "_SNAPSHOT" for r in lines)

    s2 = StateStore(str(j), compact_min_lines=64, compact_factor=2)
    assert len(s2.tasks) == 8
    for i in range(8):
        assert s2.completed_result(f"k{i}") == (True, 29)
    # aggregate stats carried across the snapshot boundary: the busy
    # fraction is preserved within tolerance, not reset to idle
    util_after = s2.utilization(8)
    assert util_after["Idle"] < 1.0
    for k in ("Scheduled", "Launching", "Running"):
        assert util_after[k] == pytest.approx(util_before[k],
                                              rel=0.05, abs=1e-4)
    # overhead survives too: the snapshot's scalar base plus tail
    # intervals (overhead_base feeds rp_overhead after a restart)
    assert s2.overhead() == pytest.approx(oh_before, rel=0.05, abs=1e-4)
    assert s2.overhead_base() > 0
    s2.close()


def test_compaction_with_queued_records_does_not_double_count(tmp_path):
    """Records still in the write-behind queue when the writer compacts
    are already folded into the snapshot stats; they must not also land
    in the tail, or a restart ingests them twice and over-reports
    utilization/overhead."""
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j), compact_min_lines=48, compact_factor=2)
    for round_ in range(40):                 # no flush(): queue stays hot
        for i in range(6):
            drive(s, f"t{i}", key=f"k{i}", result=round_)
    util_before = s.utilization(8)
    oh_before = s.overhead()
    s.close()
    s2 = StateStore(str(j), compact_min_lines=48, compact_factor=2)
    assert len(s2.tasks) == 6
    for i in range(6):
        assert s2.completed_result(f"k{i}") == (True, 39)
    for k in ("Scheduled", "Launching", "Running"):
        assert s2.utilization(8)[k] == pytest.approx(util_before[k],
                                                     rel=0.05, abs=1e-4)
    assert s2.overhead() == pytest.approx(oh_before, rel=0.05, abs=1e-4)
    s2.close()


def test_compaction_preserves_runtime_events(tmp_path):
    """Pilot-lifecycle events (PILOT_START/STOLEN/...) survive compaction
    even after they were flushed to the pre-compaction journal; per-task
    ROUTED events are the documented drop (each task record keeps its
    pilot binding)."""
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j), compact_min_lines=48, compact_factor=2)
    s.record_event("PILOT_START", pilot="p0", n_slots=4)
    s.record_event("STOLEN", uid="tx", src="p0", dst="p1")
    s.record_event("ROUTED", uid="t0", pilot="p0")
    s.flush(timeout=10)                      # events hit the old journal
    for round_ in range(40):                 # force >=1 compaction
        for i in range(6):
            drive(s, f"t{i}", key=f"k{i}", result=round_)
        s.flush(timeout=10)
    s.close()
    s2 = StateStore(str(j), compact_min_lines=48, compact_factor=2)
    kinds = [e["event"] for e in s2.events_snapshot()]
    assert "PILOT_START" in kinds and "STOLEN" in kinds
    assert "ROUTED" not in kinds             # compaction drops these
    s2.close()


def test_compaction_tail_preserves_recent_timelines(tmp_path):
    """The bounded event tail: recent per-task state timelines survive a
    compaction + restart (stamp-exact within the same boot), older ones
    are the documented drop, and the tail never double-counts into the
    aggregate utilization/overhead counters (the snapshot stats already
    carry it)."""
    j = tmp_path / "j.jsonl"
    kw = dict(compact_min_lines=48, compact_factor=2,
              compact_tail_events=64)
    s = StateStore(str(j), **kw)
    # distinct uid per round: early rounds age out of the tail window,
    # late rounds stay inside it
    for round_ in range(30):
        for i in range(3):
            drive(s, f"r{round_}_t{i}", key=f"r{round_}_k{i}",
                  result=round_)
        s.flush(timeout=10)
    tl_before = s.timeline()
    util_before = s.utilization(8)
    oh_before = s.overhead()
    s.close()

    lines = [json.loads(l) for l in j.read_text().splitlines()]
    assert any(r.get("tail") for r in lines), "no event tail written"

    s2 = StateStore(str(j), **kw)
    tl_after = s2.timeline()
    # uids whose transitions exist ONLY as tail events (their task lines
    # are snapshot summaries): the timeline must come from the tail
    regular_uids = {r["uid"] for r in lines
                    if "uid" in r and "event" not in r
                    and not r.get("snap")}
    tail_only = {r["uid"] for r in lines
                 if r.get("tail")} - regular_uids
    assert tail_only, "no uid exercises the tail-only replay path"
    for uid in tail_only:
        got = tl_after.get(uid)
        assert got, f"tail-only uid lost its timeline: {uid}"
        for st, t in got.items():
            assert t == pytest.approx(tl_before[uid][st], abs=1e-9), uid
    # the last rounds' tasks keep their full per-state timeline, with the
    # exact stamps (same boot: no epoch shift)
    recent = [u for u in tl_before if u.startswith("r29_")]
    assert recent
    for uid in recent:
        assert tl_after.get(uid) == tl_before[uid], uid
    # a bounded tail cannot hold everything: the earliest rounds' full
    # timelines are gone (their latest state survives in the snapshot)
    assert "r0_t0" not in tl_after
    assert len(s2.tasks) == 90                 # ...but no record is lost
    assert s2.completed_result("r0_k0") == (True, 0)
    # and the aggregates match pre-restart: tail events were folded in as
    # timeline-only, never double-ingested into the counters
    for k in ("Scheduled", "Launching", "Running"):
        assert s2.utilization(8)[k] == pytest.approx(util_before[k],
                                                     rel=0.05, abs=1e-4)
    assert s2.overhead() == pytest.approx(oh_before, rel=0.05, abs=1e-4)
    s2.close()


def test_replay_translates_monotonic_epoch_across_reboot(tmp_path):
    """A journal written in a previous boot carries monotonic stamps from
    a different epoch; replay re-anchors them via the wall stamps so the
    rebuilt counters stay sane instead of spanning both epochs."""
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j))
    s.record_event("PILOT_START", pilot="p0", n_slots=4)
    for i in range(10):
        drive(s, f"t{i}", key=f"k{i}", result=i)
    util_before = s.utilization(4)
    oh_before = s.overhead()
    s.close()

    # simulate the reboot: shift every monotonic stamp by a huge offset,
    # as if the previous boot's CLOCK_MONOTONIC epoch were far away
    shift = 7.2e6
    lines = []
    for line in j.read_text().splitlines():
        rec = json.loads(line)
        if "event" in rec:
            rec["t"] += shift                # wt stays wall-anchored
        else:
            rec["mt"] += shift               # t stays wall-anchored
        lines.append(json.dumps(rec))
    j.write_text("\n".join(lines) + "\n")

    s2 = StateStore(str(j))
    util_after = s2.utilization(4)
    # each line re-anchors via its own wall stamp, whose sampling jitter
    # vs the monotonic stamp is ~us — integrals match to ~percent
    for k in util_before:
        assert util_after[k] == pytest.approx(util_before[k],
                                              rel=0.05, abs=1e-4), k
    assert s2.overhead() == pytest.approx(oh_before, rel=0.05, abs=1e-4)
    # rebuilt stamps live in the current boot's monotonic domain
    tl = s2.timeline()
    now = __import__("time").monotonic()
    for ts in tl.values():
        for t in ts.values():
            assert abs(t - now) < 3600
    s2.close()


def test_compacted_journal_still_tolerates_torn_tail(tmp_path):
    j = tmp_path / "j.jsonl"
    s = StateStore(str(j), compact_min_lines=32, compact_factor=2)
    for round_ in range(20):
        for i in range(4):
            drive(s, f"t{i}", key=f"k{i}", result=round_)
        s.flush(timeout=10)
    s.close()
    with open(j, "a") as fh:
        fh.write('{"uid": "torn"')
    s2 = StateStore(str(j), compact_min_lines=32, compact_factor=2)
    assert len(s2.tasks) == 4
    assert s2.completed_result("k0") == (True, 19)
    s2.close()
