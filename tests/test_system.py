"""End-to-end system tests: workflow-managed training with checkpoint
restart; journal replay; sharded-model numerics on a multi-device mesh
(subprocess — device count is fixed at jax init, so the 8-device check runs
isolated)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]


def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import main
    losses = main(["--arch", "smollm-360m", "--reduced", "--steps", "20",
                   "--segment", "5", "--batch", "4", "--seq", "64",
                   "--ckpt-dir", str(tmp_path / "ck"),
                   "--ckpt-every", "10", "--eval-every", "20"])
    assert len(losses) == 4
    assert losses[-1] < losses[0] + 0.2      # moving in the right direction
    # restart picks up from the checkpoint
    losses2 = main(["--arch", "smollm-360m", "--reduced", "--steps", "30",
                    "--segment", "5", "--batch", "4", "--seq", "64",
                    "--ckpt-dir", str(tmp_path / "ck"),
                    "--ckpt-every", "10", "--eval-every", "30"])
    assert len(losses2) == 2                 # only steps 20->30 ran


def test_serve_driver_end_to_end():
    from repro.launch.serve import main
    outputs = main(["--arch", "smollm-360m", "--reduced", "--requests", "6",
                    "--batch-slots", "3", "--max-new", "6"])
    assert len(outputs) == 6
    assert all(len(v) >= 1 for v in outputs.values())


def test_store_journal_replay(tmp_path):
    from repro.core import StateStore, TaskRecord, TaskState
    j = tmp_path / "journal.jsonl"
    s1 = StateStore(str(j))
    t = TaskRecord(uid="task.x", kind="python")
    t.result = {"answer": 42}
    t.state = TaskState.DONE
    s1.record(t, workflow_key="wf/app:0")
    s1.close()
    s2 = StateStore(str(j))
    found, result = s2.completed_result("wf/app:0")
    assert found and result == {"answer": 42}
    s2.close()


def test_dfk_replay_skips_done_tasks(tmp_path):
    from repro.core import (DataFlowKernel, PilotDescription, RPEXExecutor,
                            python_app)
    journal = str(tmp_path / "wf.jsonl")
    calls = []

    @python_app
    def work(x):
        calls.append(x)
        return x * 10

    rp1 = RPEXExecutor(PilotDescription(n_slots=4, journal=journal))
    with DataFlowKernel(executors={"rpex": rp1}, run_id="r1"):
        assert work(3).result() == 30
    rp1.shutdown()
    assert calls == [3]
    # "restart": same run_id + journal -> replay, no re-execution
    rp2 = RPEXExecutor(PilotDescription(n_slots=4, journal=journal))
    with DataFlowKernel(executors={"rpex": rp2}, run_id="r1"):
        assert work(3).result() == 30
    rp2.shutdown()
    assert calls == [3]


SHARDED_CHECK = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduce_config
from repro.models import model as M
from repro.models import transformer as T
from repro.optim import AdamW
from repro.sharding.partition import PartitionRules, ShardCtx

# sharded-vs-local train step parity on a reduced MoE config
cfg = reduce_config(get_config("qwen3-moe-235b-a22b"), num_layers=2)
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = PartitionRules()
params = T.init_params(cfg, jax.random.PRNGKey(0))
B, S = 4, 16
key = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "loss_mask": jnp.ones((B, S))}
loss_local, _ = M.loss_fn(cfg, params, batch)

pspecs = T.param_pspecs(cfg, mesh, rules)
shard = lambda t, s: jax.device_put(t, jax.NamedSharding(mesh, s))
params_sh = jax.tree.map(shard, params, pspecs)
sctx = ShardCtx(mesh, rules)
with mesh:
    loss_sh, _ = jax.jit(lambda p, b: M.loss_fn(cfg, p, b, sctx))(params_sh, batch)
err = abs(float(loss_local) - float(loss_sh))
assert err < 5e-2, f"sharded loss diverges: {float(loss_local)} vs {float(loss_sh)}"
print("SHARDED-PARITY-OK", float(loss_local), float(loss_sh))

# sharded attention strategies + decode (exercised via gemma2 family: window+softcap)
cfg2 = reduce_config(get_config("gemma2-9b"), num_layers=2)
params2 = T.init_params(cfg2, jax.random.PRNGKey(2))
batch2 = {"tokens": jax.random.randint(key, (B, S), 0, cfg2.vocab_size),
          "targets": jax.random.randint(key, (B, S), 0, cfg2.vocab_size),
          "loss_mask": jnp.ones((B, S))}
l_loc, _ = M.loss_fn(cfg2, params2, batch2)
p2sh = jax.tree.map(shard, params2, T.param_pspecs(cfg2, mesh, rules))
with mesh:
    l_sh, _ = jax.jit(lambda p, b: M.loss_fn(cfg2, p, b, ShardCtx(mesh, rules)))(p2sh, batch2)
assert abs(float(l_loc) - float(l_sh)) < 5e-2, (float(l_loc), float(l_sh))
print("GEMMA-SHARDED-OK")
"""


@pytest.mark.slow
def test_sharded_model_parity_subprocess():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SHARDED_CHECK], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-PARITY-OK" in out.stdout
    assert "GEMMA-SHARDED-OK" in out.stdout


def test_dryrun_artifacts_complete():
    """The multi-pod dry-run must have produced all 40 cells x 2 meshes."""
    base = REPO / "benchmarks" / "artifacts" / "dryrun"
    if not base.exists():
        pytest.skip("dry-run artifacts not generated yet")
    for mesh in ("pod16x16", "pod2x16x16"):
        files = list((base / mesh).glob("*.json"))
        assert len(files) == 40, f"{mesh}: {len(files)} cells"
        for f in files:
            a = json.loads(f.read_text())
            assert a["status"] in ("ok", "SKIP(full-attn)"), \
                f"{f.name}: {a.get('status')} {a.get('error', '')[:200]}"
            if a["status"] == "ok":
                assert a["cost"]["flops_per_device"] > 0
                assert a["peak_bytes_per_device"] > 0
