"""Pilot failure domains: retry policies (backoff, classification,
quarantine), heartbeat-supervised lost-pilot recovery, and the seeded
chaos harness.

The hard invariants under test:
  * a RetryPolicy's backoff is deterministic per (task, attempt), capped,
    and served through the agent's cv wait (no polling thread);
  * every failed attempt's exception survives on the record and is
    chained (``__cause__``) into the terminal error;
  * infra failures (SlotFailure / WorkerDied / PilotLost) retry on a
    *different* pilot when the policy asks for it;
  * a poison task that kills N workers quarantines (terminal FAILED +
    QUARANTINED journal event) while the pool stays healthy;
  * ``mark_lost`` recovers queued and RUNNING work onto survivors —
    checkpointable tasks resume from their last durable snapshot;
  * a seeded chaos storm over a multi-pilot pool completes every task
    exactly once.
"""
import os
import signal
import threading
import time

import numpy as np
import pytest

from repro.core import (FaultInjector, ObjectRef, Pilot, PilotDescription,
                        PilotPool, PilotLost, ResourceSpec, RetryPolicy,
                        RPEXExecutor, SlotFailure, TaskManager, TaskState,
                        WorkerDied, python_app, translate)


# ----------------------------- RetryPolicy ------------------------------ #

def test_backoff_schedule_deterministic_and_capped():
    pol = RetryPolicy(backoff_base_s=0.1, backoff_factor=2.0,
                      backoff_max_s=0.5, jitter=0.2)
    a = [pol.backoff_s(k, "task.000001") for k in (1, 2, 3, 4, 5)]
    b = [pol.backoff_s(k, "task.000001") for k in (1, 2, 3, 4, 5)]
    assert a == b                           # same task+attempt -> same delay
    for k, d in enumerate(a, start=1):
        nominal = min(0.5, 0.1 * 2.0 ** (k - 1))
        assert abs(d - nominal) <= 0.2 * nominal + 1e-9
    # jitter varies across tasks, not across calls
    assert pol.backoff_s(1, "task.000002") != a[0]
    assert RetryPolicy(backoff_base_s=0.0).backoff_s(3) == 0.0


def test_retry_policy_threads_through_decorator_and_translator():
    pol = RetryPolicy(max_retries=5, backoff_base_s=0.0)

    @python_app(retry_policy=pol)
    def appfn():
        return 1

    fn = appfn.__wrapped_app__
    t = translate(fn, (), {}, fn.__resources__, retry_policy=pol)
    assert t.retry_policy is pol
    assert t.max_retries == 5               # policy supersedes bare count


@pytest.mark.timeout(60)
def test_backoff_delays_requeue_and_attempts_chain_into_success_history():
    """Two failures then success: the agent parks the retry on its delayed
    heap (cv-timed, no poll), and both attempt errors stay on the record."""
    pilot = Pilot(PilotDescription(n_slots=1, name="bk"))
    try:
        calls = []

        def flaky():
            calls.append(time.monotonic())
            if len(calls) < 3:
                raise RuntimeError(f"boom {len(calls)}")
            return "ok"

        pol = RetryPolicy(max_retries=3, backoff_base_s=0.15,
                          backoff_factor=1.0, jitter=0.0)
        t = translate(flaky, (), {}, retry_policy=pol)
        done = threading.Event()
        pilot.agent.submit(t, done_cb=lambda _t: done.set())
        assert done.wait(30)
        assert t.state == TaskState.DONE and t.result == "ok"
        assert len(calls) == 3
        # both gaps honored the configured backoff (minus scheduling slack)
        assert calls[1] - calls[0] >= 0.13
        assert calls[2] - calls[1] >= 0.13
        assert [str(e) for e in t.attempt_errors] == ["boom 1", "boom 2"]
    finally:
        pilot.close()


@pytest.mark.timeout(60)
def test_terminal_failure_chains_attempt_history():
    pilot = Pilot(PilotDescription(n_slots=1))
    try:
        def always():
            raise RuntimeError("attempt")

        pol = RetryPolicy(max_retries=2, backoff_base_s=0.0)
        t = translate(always, (), {}, retry_policy=pol)
        done = threading.Event()
        pilot.agent.submit(t, done_cb=lambda _t: done.set())
        assert done.wait(30)
        assert t.state == TaskState.FAILED
        # final error <- attempt 2 <- attempt 1 via __cause__
        chain = []
        e = t.error
        while e is not None:
            chain.append(str(e))
            e = e.__cause__
        assert chain == ["attempt"] * 3
        # the journal record carries the attempt history too
        assert len(pilot.store.tasks[t.uid]["attempt_errors"]) == 2
    finally:
        pilot.close()


@pytest.mark.timeout(60)
def test_fatal_exception_short_circuits_retries():
    pilot = Pilot(PilotDescription(n_slots=1))
    try:
        calls = []

        def fatal():
            calls.append(1)
            raise ValueError("unretryable")

        pol = RetryPolicy(max_retries=5, backoff_base_s=0.0,
                          fatal_exceptions=(ValueError,))
        t = translate(fatal, (), {}, retry_policy=pol)
        done = threading.Event()
        pilot.agent.submit(t, done_cb=lambda _t: done.set())
        assert done.wait(30)
        assert t.state == TaskState.FAILED and len(calls) == 1
        assert isinstance(t.error, ValueError)
    finally:
        pilot.close()


@pytest.mark.timeout(120)
def test_infra_failure_retries_on_a_different_pilot():
    """A SlotFailure (infra) retry re-places on the sibling pilot, not the
    one whose slot just failed — visible as STOLEN(reason=retry)."""
    pool = PilotPool([PilotDescription(n_slots=1, name="ia"),
                      PilotDescription(n_slots=1, name="ib")], steal=False)
    tmgr = TaskManager(pool)
    try:
        release = threading.Event()
        pol = RetryPolicy(max_retries=2, backoff_base_s=0.0,
                          retry_different_pilot=True)
        t = translate(lambda: release.wait(10) and "done" or "done", (), {},
                      retry_policy=pol)
        tmgr.submit(t)
        src = pool.by_uid(t.pilot_uid)
        deadline = time.monotonic() + 10
        while t.state != TaskState.RUNNING:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        src.agent.inject_slot_failure(list(t.slot_ids))
        release.set()
        assert tmgr.wait(timeout=30)
        assert t.state == TaskState.DONE
        assert t.pilot_uid != src.uid           # re-routed, not requeued
        evs = [e for e in pool.events()
               if e["event"] == "STOLEN" and e.get("reason") == "retry"]
        assert evs and evs[0]["uid"] == t.uid and evs[0]["src"] == src.uid
        assert any(isinstance(e, SlotFailure) for e in t.attempt_errors)
    finally:
        release.set()
        tmgr = None
        pool.close()


@pytest.mark.timeout(120)
def test_quarantine_stops_worker_killing_task():
    """A poison task that SIGKILLs its worker on every attempt quarantines
    after N worker deaths — terminal FAILED + QUARANTINED event — instead
    of grinding through its whole retry budget, and the pilot keeps
    serving healthy work afterwards."""
    pilot = Pilot(PilotDescription(n_slots=1, transport="proc", name="qz"))
    try:
        def poison():
            os.kill(os.getpid(), signal.SIGKILL)

        pol = RetryPolicy(max_retries=10, backoff_base_s=0.0,
                          retry_different_pilot=False, quarantine_after=2)
        t = translate(poison, (), {}, retry_policy=pol)
        done = threading.Event()
        pilot.agent.submit(t, done_cb=lambda _t: done.set())
        assert done.wait(60)
        assert t.state == TaskState.FAILED
        assert t.quarantined and t.worker_deaths == 2
        assert isinstance(t.error, WorkerDied)
        causes = []
        e = t.error.__cause__
        while e is not None:
            causes.append(e)
            e = e.__cause__
        assert any(isinstance(c, WorkerDied) for c in causes)  # attempt 1
        evs = [e for e in pilot.store.events_snapshot()
               if e.get("event") == "QUARANTINED"]
        assert len(evs) == 1 and evs[0]["uid"] == t.uid
        assert evs[0]["worker_deaths"] == 2

        # the pool replaced the dead workers: healthy work still runs
        t2 = translate(lambda: 42, (), {})
        done2 = threading.Event()
        pilot.agent.submit(t2, done_cb=lambda _t: done2.set())
        assert done2.wait(60) and t2.result == 42
    finally:
        pilot.close()


# --------------------------- lost-pilot recovery -------------------------- #

def _resumable(n, step_s, log, lock, ckpt=None):
    start = 0
    got = ckpt.restore()
    if got is not None:
        start = got[0] + 1
    for step in range(start, n):
        time.sleep(step_s)
        with lock:
            log.append(step)
        ckpt.save(step, step)
    return {"start": start}


@pytest.mark.timeout(120)
def test_mark_lost_recovers_queued_and_running_work():
    """mark_lost on a loaded pilot: queued tasks re-route to the survivor
    (STOLEN reason=pilot-lost), a RUNNING checkpointable task re-adopts
    its snapshot and resumes at step > 0, a RUNNING non-checkpointable
    task burns a retry and reruns — every future resolves and PILOT_LOST
    is journaled on the lost pilot."""
    pool = PilotPool([PilotDescription(n_slots=2, name="la",
                                       straggler_factor=1e9),
                      PilotDescription(n_slots=2, name="lb",
                                       straggler_factor=1e9)], steal=False)
    tmgr = TaskManager(pool)
    try:
        a, b = pool.pilots
        lock, log = threading.Lock(), []
        ck = translate(_resumable, (8, 0.1, log, lock), {},
                       ResourceSpec(checkpointable=True))
        plain = translate(lambda: time.sleep(1.0) or "rerun", (), {},
                          retry_policy=RetryPolicy(max_retries=1,
                                                   backoff_base_s=0.0))
        queued = [translate(lambda i=i: i, (), {}) for i in range(4)]
        for t in [ck, plain] + queued:
            tmgr._bind(t, pilot=a)
            with tmgr._cv:
                tmgr._outstanding += 1
            t.transition(TaskState.TRANSLATED, a.store)
        results = {}

        def mk_cb(t):
            return lambda rec, _u=t.uid: results.__setitem__(_u, rec)

        # occupy both of a's slots (ck=1 slot, plain=1 slot); the rest queue
        a.agent.submit(ck, done_cb=mk_cb(ck))
        a.agent.submit(plain, done_cb=mk_cb(plain))
        for t in queued:
            a.agent.submit(t, done_cb=mk_cb(t))
        deadline = time.monotonic() + 15
        while a.ckpt.step(ck.ckpt_key) is None:
            assert time.monotonic() < deadline, "no checkpoint saved"
            time.sleep(0.02)

        assert pool.mark_lost(a, reason="test")
        assert a not in pool.pilots and a in pool.retired
        assert pool.take_lost() == [a.uid]

        deadline = time.monotonic() + 60
        while len(results) < 6 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert len(results) == 6
        assert all(r.state == TaskState.DONE for r in results.values())
        # the checkpointable task resumed on b from its saved step; the
        # boundary step may run twice (the zombie's last save can race
        # the snapshot adoption — crash recovery is at-least-once there,
        # unlike cooperative preemption), but nothing is lost
        assert results[ck.uid].result["start"] > 0
        assert results[ck.uid].pilot_uid == b.uid
        assert set(log) == set(range(8))
        # the plain task burned a retry and carries the PilotLost evidence
        assert results[plain.uid].retries == 1
        assert any(isinstance(e, PilotLost)
                   for e in results[plain.uid].attempt_errors)

        evs = pool.events()
        lost = [e for e in evs if e["event"] == "PILOT_LOST"]
        assert len(lost) == 1 and lost[0]["pilot"] == a.uid
        assert lost[0]["reason"] == "test"
        assert lost[0]["queued"] == 4 and lost[0]["running"] == 2
        moved = [e for e in evs if e["event"] == "STOLEN"
                 and e.get("reason") == "pilot-lost"]
        assert {e["uid"] for e in moved} >= {t.uid for t in queued}
    finally:
        pool.close()


@pytest.mark.timeout(120)
def test_pilot_loss_rehosts_live_objects():
    """A lost pilot's published results move to a survivor: existing refs
    keep resolving without a cross-pilot charge against the dead owner,
    and the hand-off is journaled (docs/dataplane.md)."""
    pool = PilotPool([PilotDescription(n_slots=2, name="oa"),
                      PilotDescription(n_slots=2, name="ob")], steal=False)
    tmgr = TaskManager(pool)
    try:
        a, b = pool.pilots
        t = translate(lambda: np.ones(32_768, dtype=np.float64), (), {})
        tmgr._bind(t, pilot=a)
        with tmgr._cv:
            tmgr._outstanding += 1
        t.transition(TaskState.TRANSLATED, a.store)
        done = threading.Event()
        a.agent.submit(t, done_cb=lambda _t: done.set())
        assert done.wait(30)
        ref = t.result
        assert isinstance(ref, ObjectRef) and ref.pilot_uid == a.uid

        assert pool.mark_lost(a, reason="test")
        e = pool.objectstore.entry(ref.oid)
        assert e.owner == b.uid
        assert pool.objectstore.stats()["rehosted"] >= 1
        got = ref.deref(pilot_uid=b.uid)
        assert float(got.sum()) == 32_768.0
        # re-homed: the survivor's read is local, not a transfer
        assert pool.objectstore.stats()["bytes_moved"] == 0
        evs = pool.events()
        re_ev = [ev for ev in evs if ev["event"] == "OBJECTS_REHOSTED"]
        assert re_ev and re_ev[0]["src"] == a.uid
        assert re_ev[0]["objects"] >= 1
    finally:
        pool.close()


@pytest.mark.timeout(120)
def test_nonretryable_running_task_fails_visibly_on_pilot_loss():
    pool = PilotPool([PilotDescription(n_slots=1, name="fa"),
                      PilotDescription(n_slots=1, name="fb")], steal=False)
    try:
        a = pool.pilots[0]
        gate = threading.Event()
        t = translate(lambda: gate.wait(10), (), {})     # max_retries=0
        t.transition(TaskState.TRANSLATED, a.store)
        box = {}
        done = threading.Event()
        a.agent.submit(t, done_cb=lambda rec: (box.update(r=rec),
                                               done.set()))
        deadline = time.monotonic() + 10
        while t.state != TaskState.RUNNING:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        assert pool.mark_lost(a)
        assert done.wait(30)
        rec = box["r"]
        assert rec.state == TaskState.FAILED
        assert isinstance(rec.error, PilotLost)
    finally:
        gate.set()
        pool.close()


@pytest.mark.timeout(120)
def test_heartbeat_monitor_declares_crashed_pilot_lost():
    """An injected crash silences the agent's loop; the pool's health
    monitor notices within the timeout and recovers the queued work onto
    the survivor without any explicit mark_lost call."""
    pool = PilotPool([PilotDescription(n_slots=1, name="ha"),
                      PilotDescription(n_slots=1, name="hb")],
                     steal=False, heartbeat_timeout_s=0.6)
    tmgr = TaskManager(pool)
    try:
        a, b = pool.pilots
        gate = threading.Event()
        blocker = translate(lambda: gate.wait(10), (), {})
        queued = [translate(lambda i=i: i * 10, (), {}) for i in range(3)]
        results = {}
        for t in [blocker] + queued:
            tmgr._bind(t, pilot=a)
            with tmgr._cv:
                tmgr._outstanding += 1
            t.transition(TaskState.TRANSLATED, a.store)
            a.agent.submit(
                t, done_cb=lambda rec, _u=t.uid: results.__setitem__(_u, rec))
        time.sleep(0.05)
        a.agent.inject_crash()

        deadline = time.monotonic() + 30
        while a not in pool.retired and time.monotonic() < deadline:
            time.sleep(0.02)
        assert a in pool.retired, "health monitor never declared the loss"
        lost = [e for e in pool.events() if e["event"] == "PILOT_LOST"]
        assert lost and lost[0]["reason"] == "crash"

        deadline = time.monotonic() + 30
        while (len([u for u in results if u != blocker.uid]) < 3
               and time.monotonic() < deadline):
            time.sleep(0.05)
        got = {u: r for u, r in results.items() if u != blocker.uid}
        assert len(got) == 3
        assert all(r.state == TaskState.DONE and r.pilot_uid == b.uid
                   for r in got.values())
    finally:
        gate.set()
        pool.close()


@pytest.mark.timeout(60)
def test_shutdown_reports_stranded_tasks():
    pilot = Pilot(PilotDescription(n_slots=1, name="st"))
    gate = threading.Event()
    try:
        running = translate(lambda: gate.wait(10), (), {})
        queued = translate(lambda: "q", (), {})
        pilot.agent.submit(running)
        time.sleep(0.05)
        pilot.agent.submit(queued)
        stranded = pilot.agent.shutdown(wait=True, timeout=0.2)
        assert sorted(stranded) == sorted([running.uid, queued.uid])
        evs = [e for e in pilot.store.events_snapshot()
               if e.get("event") == "SHUTDOWN_STRANDED"]
        assert evs and evs[0]["count"] == 2
    finally:
        gate.set()
        pilot.close()


# ------------------------------ chaos soak ------------------------------- #

@pytest.mark.timeout(300)
def test_chaos_soak_exactly_once_completion():
    """Seeded storm (pilot crash + worker kills + slot failures) over a
    3-pilot pool under a 200-task burst: every task completes exactly
    once, and the injected pilot loss is visible in the event stream."""
    pool = PilotPool(
        [PilotDescription(n_slots=4, name="s0", straggler_factor=1e9),
         PilotDescription(n_slots=4, name="s1", straggler_factor=1e9,
                          transport="proc"),
         PilotDescription(n_slots=4, name="s2", straggler_factor=1e9)],
        heartbeat_timeout_s=0.8)
    tmgr = TaskManager(pool)
    inj = FaultInjector(pool, seed=7)
    inj.storm(duration_s=2.5, pilot_crashes=1, worker_kills=2,
              slot_failures=2, task_hangs=0, warmup_s=0.4)
    try:
        pol = RetryPolicy(max_retries=6, backoff_base_s=0.01,
                          backoff_max_s=0.1, quarantine_after=None)
        completions = []   # the record arriving at the cb may be a same-
        lock = threading.Lock()   # uid recovery clone: read results here

        def cb(rec):
            with lock:
                completions.append((rec.uid, rec.state, rec.result))

        tasks = [translate(lambda i=i: time.sleep(0.04) or i * i, (), {},
                           retry_policy=pol)
                 for i in range(200)]
        inj.start()
        tmgr.submit_bulk(tasks, done_cb=cb)
        assert tmgr.wait(timeout=180), "soak never drained"
        inj.stop()

        assert len(completions) == 200
        assert len({u for u, _, _ in completions}) == 200   # exactly once
        assert all(s == TaskState.DONE for _, s, _ in completions)
        want = {t.uid: i * i for i, t in enumerate(tasks)}
        for u, _, res in completions:
            assert res == want[u]
        assert inj.events, "storm injected nothing"
        if any(e["kind"] == "pilot-crash" and "pilot" in e
               for e in inj.events):
            assert any(e["event"] == "PILOT_LOST" for e in pool.events())
    finally:
        inj.stop()
        pool.close()


def test_fault_injector_schedule_is_deterministic():
    pool = PilotPool([PilotDescription(n_slots=1, name="d0")])
    try:
        a = FaultInjector(pool, seed=42)
        a.storm(duration_s=5.0, pilot_crashes=1, worker_kills=3,
                slot_failures=2, task_hangs=1)
        b = FaultInjector(pool, seed=42)
        b.storm(duration_s=5.0, pilot_crashes=1, worker_kills=3,
                slot_failures=2, task_hangs=1)
        assert [(at, lbl) for at, _, _, lbl in a._schedule] == \
               [(at, lbl) for at, _, _, lbl in b._schedule]
        c = FaultInjector(pool, seed=43)
        c.storm(duration_s=5.0, pilot_crashes=1, worker_kills=3,
                slot_failures=2, task_hangs=1)
        assert [(at, lbl) for at, _, _, lbl in a._schedule] != \
               [(at, lbl) for at, _, _, lbl in c._schedule]
    finally:
        pool.close()
